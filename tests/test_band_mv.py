"""band_mv kernel: interpret-mode validation vs the dense oracle."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.band_mv.ops import band_mv
from repro.kernels.band_mv.ref import (band_mv_ref, band_to_dense,
                                       dense_to_band)


def _band_problem(n, w, key):
    k1, k2 = jax.random.split(key)
    M = jax.random.normal(k1, (n, n), jnp.float64)
    A = 0.5 * (M + M.T)
    mask = jnp.abs(jnp.arange(n)[:, None] - jnp.arange(n)[None, :]) <= w
    A = jnp.where(mask, A, 0.0)
    band = dense_to_band(A, w)
    x = jax.random.normal(k2, (n,), jnp.float64)
    return A, band, x


@pytest.mark.parametrize("n,w,bm", [(64, 4, 16), (128, 8, 32), (96, 3, 32),
                                    (256, 16, 64)])
def test_band_mv_matches_dense(n, w, bm):
    A, band, x = _band_problem(n, w, jax.random.PRNGKey(n + w))
    got = band_mv(band, x, w=w, bm=bm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(A @ x),
                               rtol=1e-12, atol=1e-12)


def test_band_roundtrip():
    n, w = 48, 5
    A, band, _ = _band_problem(n, w, jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(band_to_dense(band)),
                               np.asarray(A), atol=1e-14)


@settings(max_examples=12, deadline=None)
@given(n=st.sampled_from([32, 64, 80]), w=st.integers(1, 8),
       seed=st.integers(0, 2**20))
def test_band_mv_property(n, w, seed):
    A, band, x = _band_problem(n, w, jax.random.PRNGKey(seed))
    got = band_mv(band, x, w=w, bm=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(A @ x),
                               rtol=1e-11, atol=1e-11)
