"""band_mv kernel: interpret-mode validation vs the dense oracle."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.band_mv.ops import band_mv
from repro.kernels.band_mv.ref import (band_mv_ref, band_to_dense,
                                       dense_to_band)


def _band_problem(n, w, key):
    k1, k2 = jax.random.split(key)
    M = jax.random.normal(k1, (n, n), jnp.float64)
    A = 0.5 * (M + M.T)
    mask = jnp.abs(jnp.arange(n)[:, None] - jnp.arange(n)[None, :]) <= w
    A = jnp.where(mask, A, 0.0)
    band = dense_to_band(A, w)
    x = jax.random.normal(k2, (n,), jnp.float64)
    return A, band, x


@pytest.mark.parametrize("n,w,bm", [(64, 4, 16), (128, 8, 32), (96, 3, 32),
                                    (256, 16, 64)])
def test_band_mv_matches_dense(n, w, bm):
    A, band, x = _band_problem(n, w, jax.random.PRNGKey(n + w))
    got = band_mv(band, x, w=w, bm=bm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(A @ x),
                               rtol=1e-12, atol=1e-12)


def test_band_roundtrip():
    n, w = 48, 5
    A, band, _ = _band_problem(n, w, jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(band_to_dense(band)),
                               np.asarray(A), atol=1e-14)


# deterministic stand-in for the former hypothesis sweep: fixed seeds over
# the same (n, w) envelope, so tier-1 collects on a bare jax install
@pytest.mark.parametrize("n,w,seed", [
    (32, 1, 0), (32, 8, 11), (64, 2, 222), (64, 5, 3_333),
    (64, 7, 44_444), (80, 1, 555_555), (80, 4, 65_521), (80, 8, 1_048_575),
    (32, 3, 7), (64, 8, 99), (80, 6, 2**20), (32, 5, 12_345),
])
def test_band_mv_property(n, w, seed):
    A, band, x = _band_problem(n, w, jax.random.PRNGKey(seed))
    got = band_mv(band, x, w=w, bm=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(A @ x),
                               rtol=1e-11, atol=1e-11)
