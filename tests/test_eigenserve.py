"""Batched eigensolver + serving engine: vmapped-pipeline parity with the
single-pencil driver, shape-bucket cache reuse, bucket dispatch / flush
semantics, and the oversized-request router fallback."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import solve, solve_batched
from repro.core.batched import cache_stats, get_pipeline
from repro.core.residuals import accuracy_report
from repro.data.problems import dft_like, md_like
from repro.serve.eigen_engine import EigenEngine

N, S, BATCH = 32, 3, 4


def _pencils(gen, n, k, seed=100):
    return [gen(n, key=jax.random.PRNGKey(seed + i)) for i in range(k)]


def _stack(probs):
    return (jnp.stack([p.A for p in probs]),
            jnp.stack([p.B for p in probs]))


@pytest.mark.parametrize("variant", ["TD", "TT", "KE", "KI"])
def test_solve_batched_matches_exact_spectrum(variant):
    probs = _pencils(md_like, N, BATCH)
    A, B = _stack(probs)
    # the paper's MD trick for the Krylov variants (md_like's A is SPD):
    # the direct smallest end converges too slowly to serve
    invert = variant in ("KE", "KI")
    res = solve_batched(A, B, S, variant=variant, band_width=4,
                        invert=invert, max_restarts=300)
    assert res.evals.shape == (BATCH, S) and res.X.shape == (BATCH, N, S)
    for i, p in enumerate(probs):
        np.testing.assert_allclose(np.asarray(res.evals[i]),
                                   np.asarray(p.exact_evals[:S]),
                                   rtol=1e-7, atol=1e-9)
        acc = accuracy_report(p.A, p.B, res.X[i], res.evals[i])
        assert float(acc.relative_residual) < 1e-9
        assert float(acc.b_orthogonality) < 1e-9


def test_solve_batched_parity_with_single_solve():
    """Pencil i of the batched TD program == solve() on pencil i alone."""
    probs = _pencils(dft_like, N, BATCH)
    A, B = _stack(probs)
    res = solve_batched(A, B, S, variant="TD")
    for i, p in enumerate(probs):
        ref = solve(p.A, p.B, S, variant="TD")
        np.testing.assert_allclose(np.asarray(res.evals[i]),
                                   np.asarray(ref.evals),
                                   rtol=1e-10, atol=1e-10)


def test_pipeline_cache_bucket_reuse():
    """Same (n, s, variant, which) bucket -> the same compiled pipeline;
    a different shape -> a new cache entry."""
    before = cache_stats()
    fn1, key1 = get_pipeline(N, S, "TD", "smallest")
    fn2, key2 = get_pipeline(N, S, "TD", "smallest")
    assert fn1 is fn2 and key1 == key2
    fn3, key3 = get_pipeline(N + 8, S, "TD", "smallest")
    assert fn3 is not fn1 and key3 != key1
    after = cache_stats()
    assert after["hits"] >= before["hits"] + 1
    assert after["entries"] >= before["entries"] + 1


def test_engine_bucket_dispatch_and_latency():
    probs32 = _pencils(md_like, 32, 2, seed=7)
    probs48 = _pencils(md_like, 48, 2, seed=17)
    eng = EigenEngine(slots=2, bucket_shapes=[32, 48], variant="TD")
    uids = {}
    for p in probs32 + probs48:
        uids[eng.submit(p.A, p.B, S)] = p
    done = eng.run_until_drained()
    assert len(done) == 4
    assert eng.n_dispatches == 2  # one vmapped dispatch per full bucket
    for req in done:
        p = uids[req.uid]
        assert req.info["path"] == "batched" and req.info["batch"] == 2
        assert req.info["latency_s"] >= 0.0
        np.testing.assert_allclose(req.evals,
                                   np.asarray(p.exact_evals[:S]),
                                   rtol=1e-7, atol=1e-9)
    summary = eng.summary()
    assert summary["requests"] == 4 and summary["dispatches"] == 2


def test_engine_flush_drains_partial_buckets():
    probs = _pencils(md_like, 32, 3, seed=31)
    eng = EigenEngine(slots=4, bucket_shapes=[32], variant="TD")
    for p in probs:
        eng.submit(p.A, p.B, S)
    eng.tick()                       # bucket not full: nothing dispatches
    assert not eng.done and eng.pending() == 3
    done = eng.run_until_drained(flush=True)
    assert len(done) == 3 and done[0].info["batch"] == 3


def test_engine_oversized_goes_through_router():
    """A pencil above max_batched_n falls through to the variant='auto'
    cost-model router; the routing decision lands in req.info."""
    small = _pencils(md_like, 32, 1, seed=43)[0]
    big = _pencils(md_like, 64, 1, seed=47)[0]
    eng = EigenEngine(slots=1, bucket_shapes=None, max_batched_n=48,
                      variant="TD")
    uid_small = eng.submit(small.A, small.B, S)
    uid_big = eng.submit(big.A, big.B, S)
    done = {r.uid: r for r in eng.run_until_drained()}
    assert done[uid_small].info["path"] == "batched"
    assert done[uid_big].info["path"] == "direct"
    assert "router" in done[uid_big].info  # auto-routed, decision recorded
    np.testing.assert_allclose(done[uid_big].evals,
                               np.asarray(big.exact_evals[:S]),
                               rtol=1e-7, atol=1e-9)


def test_solve_batched_cold_warm_cache_hit():
    """Cold call: cache_hit=False, compile time reported SEPARATELY from
    the execution wall (the old wall_s swallowed XLA compilation, so
    cold-bucket pencils_per_s was wildly wrong). Warm call: cache_hit=True."""
    from repro.core.batched import clear_pipeline_cache
    clear_pipeline_cache()
    probs = _pencils(md_like, N, BATCH, seed=300)
    A, B = _stack(probs)
    r1 = solve_batched(A, B, S, variant="TD")
    assert r1.info["cache_hit"] is False
    assert r1.info["compile_s"] > 0.0
    # execution-only wall: the cold call's wall_s must not include the
    # compile (compilation of the vmapped pipeline dwarfs one n=32 batch)
    assert r1.info["wall_s"] < r1.info["compile_s"]
    r2 = solve_batched(A, B, S, variant="TD")
    assert r2.info["cache_hit"] is True
    assert r2.info["compile_s"] == 0.0
    np.testing.assert_allclose(np.asarray(r1.evals), np.asarray(r2.evals))


def test_solve_batched_surfaces_unconverged():
    """A tiny restart budget must be reported, not dropped on the floor."""
    probs = _pencils(md_like, N, BATCH, seed=400)
    A, B = _stack(probs)
    res = solve_batched(A, B, S, variant="KE", max_restarts=1)
    n_unconv = res.info["n_unconverged"]
    assert n_unconv == int(np.sum(~np.asarray(res.converged)))
    assert n_unconv > 0
    assert any("restart budget" in w for w in res.info["warnings"])
    # and a healthy budget reports zero without warnings
    ok = solve_batched(A, B, S, variant="KE", invert=True, max_restarts=300)
    assert ok.info["n_unconverged"] == 0 and "warnings" not in ok.info


def test_engine_surfaces_unconverged_and_cache_metadata():
    # on_failure='warn' retires unconverged lanes with a warning instead
    # of quarantining them (the quarantine path has its own tests in
    # test_resilience.py)
    probs = _pencils(md_like, N, 2, seed=500)
    eng = EigenEngine(slots=2, bucket_shapes=[N], variant="KE",
                      max_restarts=1, on_failure="warn")
    for p in probs:
        eng.submit(p.A, p.B, S)
    done = eng.run_until_drained()
    assert len(done) == 2
    for req in done:
        assert "cache_hit" in req.info and "compile_s" in req.info
        assert not req.info["converged"]
        assert any("restart budget" in w for w in req.info["warnings"])
        # every retired request carries the uniform resilience fields
        assert isinstance(req.info["warnings"], list)
        assert req.info["health"]["healthy"] is True
