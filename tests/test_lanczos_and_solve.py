"""Lanczos (KE/KI) correctness + end-to-end GSYEIG solve for all 4 variants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    ExplicitC,
    ImplicitC,
    accuracy_report,
    cholesky_upper,
    lanczos_solve,
    lanczos_solve_jit,
    solve,
    to_standard_two_trsm,
)
from repro.data.problems import dft_like, md_like

KEY = jax.random.PRNGKey(42)
K1, K2, K3 = jax.random.split(KEY, 3)


def _sym_with_known_spectrum(n, key):
    lam = jnp.sort(jax.random.normal(key, (n,), jnp.float64)) * 10.0
    M = jax.random.normal(jax.random.fold_in(key, 1), (n, n), jnp.float64)
    Q, _ = jnp.linalg.qr(M)
    C = (Q * lam[None, :]) @ Q.T
    return 0.5 * (C + C.T), lam


@pytest.mark.parametrize("which", ["SA", "LA"])
def test_lanczos_explicit(which):
    n, s = 128, 6
    C, lam = _sym_with_known_spectrum(n, K1)
    res = lanczos_solve(ExplicitC(C), s, which=which)
    assert res.converged
    want = np.asarray(lam[:s]) if which == "SA" else np.asarray(lam[-s:][::-1])
    np.testing.assert_allclose(np.asarray(res.evals), want, rtol=1e-10,
                               atol=1e-10)
    # Ritz vectors: residual check
    V = np.asarray(res.evecs)
    R = np.asarray(C) @ V - V * np.asarray(res.evals)[None, :]
    assert np.linalg.norm(R) / np.linalg.norm(np.asarray(C)) < 1e-10
    np.testing.assert_allclose(V.T @ V, np.eye(s), atol=1e-10)


def test_lanczos_implicit_matches_explicit():
    # paper's MD setup: both A and B SPD -> solve the INVERSE pair (B, A) for
    # its largest eigenpairs (fast convergence), exactly like the paper.
    n, s = 96, 5
    prob = md_like(n)
    U = cholesky_upper(prob.A)  # inverse pair: roles swapped
    C = to_standard_two_trsm(prob.B, U)
    r_e = lanczos_solve(ExplicitC(C), s, which="LA")
    r_i = lanczos_solve(ImplicitC(prob.B, U), s, which="LA")
    assert r_e.converged and r_i.converged
    np.testing.assert_allclose(np.asarray(r_e.evals), np.asarray(r_i.evals),
                               rtol=1e-9, atol=1e-9)
    lam = np.sort(1.0 / np.asarray(r_e.evals))
    np.testing.assert_allclose(lam, np.asarray(prob.exact_evals[:s]),
                               rtol=1e-8, atol=1e-9)


def test_lanczos_jit_driver_matches_host():
    n, s = 96, 4
    C, lam = _sym_with_known_spectrum(n, K2)
    v0 = jax.random.normal(K3, (n,), jnp.float64)
    m = 24
    evals, evecs, k, conv, healthy = lanczos_solve_jit(ExplicitC(C), v0, s, m,
                                              which="SA", max_restarts=200)
    assert bool(conv)
    np.testing.assert_allclose(np.asarray(evals), np.asarray(lam[:s]),
                               rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("variant", ["TD", "TT", "KE", "KI"])
def test_solve_md_like(variant):
    n, s = 80, 6
    prob = md_like(n)
    # Krylov variants use the paper's inverse-problem trick (valid: A SPD)
    invert = variant in ("KE", "KI")
    res = solve(prob.A, prob.B, s, variant=variant, which="smallest",
                band_width=8, invert=invert)
    np.testing.assert_allclose(np.asarray(res.evals),
                               np.asarray(prob.exact_evals[:s]),
                               rtol=1e-7, atol=1e-9)
    acc = accuracy_report(prob.A, prob.B, res.X, res.evals)
    assert float(acc.b_orthogonality) < 1e-10
    assert float(acc.relative_residual) < 1e-10
    assert res.stage_times["Tot."] > 0
    assert "GS1" in res.stage_times
    if variant == "KI":
        assert "GS2" not in res.stage_times  # KI never builds C
    else:
        assert "GS2" in res.stage_times


@pytest.mark.parametrize("variant", ["TD", "KE"])
def test_solve_dft_like(variant):
    n, s = 100, 10
    prob = dft_like(n)
    res = solve(prob.A, prob.B, s, variant=variant, which="smallest")
    np.testing.assert_allclose(np.asarray(res.evals),
                               np.asarray(prob.exact_evals[:s]),
                               rtol=1e-6, atol=1e-8)
    acc = accuracy_report(prob.A, prob.B, res.X, res.evals)
    assert float(acc.b_orthogonality) < 1e-9
    assert float(acc.relative_residual) < 1e-9


def test_solve_inverse_trick():
    """Paper's MD acceleration: largest of (B, A) == smallest of (A, B)."""
    n, s = 64, 5
    prob = md_like(n)
    res_direct = solve(prob.A, prob.B, s, variant="KE", which="smallest")
    res_inv = solve(prob.A, prob.B, s, variant="KE", which="smallest",
                    invert=True)
    np.testing.assert_allclose(np.asarray(res_inv.evals),
                               np.asarray(res_direct.evals), rtol=1e-7)
    np.testing.assert_allclose(np.asarray(res_inv.evals),
                               np.asarray(prob.exact_evals[:s]), rtol=1e-7)


def test_solve_largest_end():
    n, s = 64, 4
    prob = md_like(n)
    res = solve(prob.A, prob.B, s, variant="TD", which="largest")
    np.testing.assert_allclose(np.asarray(res.evals),
                               np.asarray(prob.exact_evals[-s:]), rtol=1e-8)


def test_gs2_sygst_pipeline():
    n, s = 72, 5
    prob = md_like(n)
    res = solve(prob.A, prob.B, s, variant="TD", gs2="sygst", block=24)
    np.testing.assert_allclose(np.asarray(res.evals),
                               np.asarray(prob.exact_evals[:s]), rtol=1e-7,
                               atol=1e-9)


# ------------------------------------------------- dispatch-count guard ---

class _CountingMatvec:
    """Callable op wrapper counting Python-level invocations (= traces)."""

    def __init__(self, C):
        self.C = C
        self.calls = 0

    def __call__(self, v):
        self.calls += 1
        return self.C @ v


def test_lanczos_dispatch_count_per_restart():
    """The restart loop must not regress to one device call per matvec.

    Each restart is one jitted m-step segment + one jitted restart-math +
    a single-scalar device_get — so total dispatches stay <= m + O(1) per
    restart by a wide margin (we assert the registry's much tighter
    ``lanczos_single_dispatch_budget``), and the matvec closure itself is
    only ever called at trace time.
    """
    from repro.analysis.static_audit import lanczos_single_dispatch_budget
    from repro.core import lanczos
    n, s, m = 96, 4, 24
    C, _ = _sym_with_known_spectrum(n, K1)
    op = _CountingMatvec(C)
    v0 = jax.random.normal(K3, (n,), jnp.float64)
    lanczos.reset_dispatch_count()
    res = lanczos.lanczos_solve(op, s, which="SA", m=m, v0=v0,
                                max_restarts=200)
    assert res.converged
    n_restart = res.n_restart
    # 2 jitted calls per restart; m + O(1) would be the old per-step budget
    assert lanczos.dispatch_count() <= lanczos_single_dispatch_budget(
        n_restart)
    assert lanczos.dispatch_count() <= n_restart * (m + 4)
    # the matvec traces once for the per-solve segment jit, never per step
    assert op.calls <= 2
    # and the counters in the result reflect real work
    assert res.n_matvec >= m


def test_lanczos_dispatch_budget_block_and_filtered():
    """The block (p=4) + Chebyshev-filtered path keeps the same O(1)-per-
    restart dispatch budget as the plain driver: 2 jitted programs per
    restart plus 2 for the bounds-probe / filter prep — and the matvec
    closure still only ever runs at trace time (once each for the probe,
    the filter, and the segment program)."""
    from repro.analysis.static_audit import lanczos_block_dispatch_budget
    from repro.core import lanczos
    n, s, p = 96, 4, 4
    C, _ = _sym_with_known_spectrum(n, K1)
    op = _CountingMatvec(C)
    lanczos.reset_dispatch_count()
    res = lanczos.lanczos_solve(op, s, which="SA", n=n, p=p,
                                filter_degree=8, max_restarts=200)
    assert res.converged
    assert lanczos.dispatch_count() <= lanczos_block_dispatch_budget(
        res.n_restart)
    assert op.calls <= 6
    # the filter work is accounted: probe steps + degree * p extra matvecs
    assert res.n_matvec > 8 * p


# ---------------------------------------------- block / filtered parity ---

@pytest.mark.parametrize("p", [1, 4])
def test_block_parity_md_inverse(p):
    """Block (p=4) and single-vector (p=1) drivers agree with the dense
    eigensolver to 1e-10 on the paper's MD inverse pair — odd n exercises
    the non-block-divisible subspace clamping."""
    n, s = 97, 5
    prob = md_like(n)
    U = cholesky_upper(prob.A)           # inverse pair (B, A), largest end
    C = to_standard_two_trsm(prob.B, U)
    lam = np.linalg.eigvalsh(np.asarray(C))[-s:][::-1]
    res = lanczos_solve(ExplicitC(C), s, which="LA", p=p, tol=1e-12)
    assert res.converged
    np.testing.assert_allclose(np.asarray(res.evals), lam, rtol=1e-10,
                               atol=1e-10)
    V = np.asarray(res.evecs)
    np.testing.assert_allclose(V.T @ V, np.eye(s), atol=1e-10)


@pytest.mark.parametrize("p", [1, 4])
def test_block_parity_dft_clustered(p):
    """Same parity on the clustered DFT-like spectrum, direct smallest end
    (the hard case the Chebyshev filter exists for)."""
    n, s = 97, 5
    prob = dft_like(n)
    U = cholesky_upper(prob.B)
    C = to_standard_two_trsm(prob.A, U)
    lam = np.linalg.eigvalsh(np.asarray(C))[:s]
    res = lanczos_solve(ExplicitC(C), s, which="SA", p=p, tol=1e-12)
    assert res.converged
    np.testing.assert_allclose(np.asarray(res.evals), lam, rtol=1e-10,
                               atol=1e-10)


def test_chebyshev_filter_cuts_restarts():
    """A Chebyshev-filtered starting block must converge in strictly fewer
    restarts than the unfiltered driver on the clustered DFT spectrum, at
    the same accuracy (deterministic: fixed seed, fixed schedule)."""
    n, s = 120, 6
    prob = dft_like(n)
    U = cholesky_upper(prob.B)
    C = to_standard_two_trsm(prob.A, U)
    lam = np.linalg.eigvalsh(np.asarray(C))[:s]
    r0 = lanczos_solve(ExplicitC(C), s, which="SA", tol=1e-10,
                       max_restarts=300)
    rf = lanczos_solve(ExplicitC(C), s, which="SA", tol=1e-10,
                       max_restarts=300, filter_degree=32)
    assert r0.converged and rf.converged
    assert rf.n_restart < r0.n_restart, (rf.n_restart, r0.n_restart)
    np.testing.assert_allclose(np.asarray(rf.evals), lam, rtol=1e-10,
                               atol=1e-10)


def test_jit_driver_block_filtered_matches_host():
    """``lanczos_solve_jit`` (one XLA program) agrees with the host loop in
    block + filtered mode — the two drivers share the segment/restart core
    so this pins the while_loop plumbing, not the math."""
    n, s, p, m = 96, 4, 4, 32
    C, lam = _sym_with_known_spectrum(n, K2)
    v0 = jax.random.normal(K3, (n, p), jnp.float64)
    evals, evecs, k, conv, healthy = lanczos_solve_jit(ExplicitC(C), v0, s, m,
                                              which="SA", max_restarts=200,
                                              p=p, filter_degree=8)
    assert bool(conv)
    np.testing.assert_allclose(np.asarray(evals), np.asarray(lam[:s]),
                               rtol=1e-9, atol=1e-9)


def test_lanczos_callable_matches_operator_path():
    """The callable-op segment path returns the same Ritz values as the
    Operator-pytree path (same v0, same subspace)."""
    from repro.core import ExplicitC, lanczos_solve
    n, s, m = 80, 3, 20
    C, lam = _sym_with_known_spectrum(n, K2)
    v0 = jax.random.normal(K3, (n,), jnp.float64)
    r_op = lanczos_solve(ExplicitC(C), s, which="SA", m=m, v0=v0)
    r_fn = lanczos_solve(lambda v: C @ v, s, which="SA", m=m, v0=v0)
    assert r_op.converged and r_fn.converged
    np.testing.assert_allclose(np.asarray(r_fn.evals),
                               np.asarray(r_op.evals), rtol=1e-10,
                               atol=1e-10)
