"""Training loop + data pipeline: determinism, resume-exactness, loss
decrease, spectral probe sanity."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.data.tokens import DataConfig, TokenPipeline
from repro.dist import checkpoint as ckpt
from repro.models.model import forward
from repro.train.loss import ce_loss
from repro.train.optimizer import (OptimizerConfig, adamw_update,
                                   init_opt_state, lr_schedule)
from repro.train.spectral import curvature_spectrum
from repro.train.train_step import init_train_state, make_train_step


def test_pipeline_deterministic_and_seekable():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=4)
    p1 = TokenPipeline(cfg)
    batches = [p1.next_batch() for _ in range(5)]
    p2 = TokenPipeline(cfg)
    p2.seek(3)
    b3 = p2.next_batch()
    np.testing.assert_array_equal(b3["tokens"], batches[3]["tokens"])
    np.testing.assert_array_equal(b3["labels"], batches[3]["labels"])


def test_pipeline_host_sharding_disjoint():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=8)
    h0 = TokenPipeline(cfg, host_id=0, n_hosts=2).next_batch()
    h1 = TokenPipeline(cfg, host_id=1, n_hosts=2).next_batch()
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_lr_schedule_shape():
    oc = OptimizerConfig(lr=1e-3, warmup_steps=10, decay_steps=100)
    lrs = [float(lr_schedule(jnp.asarray(s), oc)) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1e-3) < 1e-9          # end of warmup
    assert lrs[-1] <= lrs[1]
    assert lrs[-1] >= 1e-4 * 0.999            # min_lr floor


def test_adamw_moves_toward_gradient():
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.asarray([1.0, -1.0, 0.0, 2.0])}
    st = init_opt_state(params)
    oc = OptimizerConfig(lr=0.1, warmup_steps=0, decay_steps=10,
                         weight_decay=0.0)
    new, st, m = adamw_update(grads, st, params, oc)
    delta = np.asarray(new["w"] - params["w"])
    assert delta[0] < 0 and delta[1] > 0 and delta[3] < 0
    assert m["grad_norm"] > 0


def test_loss_decreases_short_run():
    cfg = smoke_config("xlstm-125m")
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=4))
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    oc = OptimizerConfig(lr=3e-3, warmup_steps=3, decay_steps=40)
    step_fn = jax.jit(make_train_step(cfg, oc))
    losses = []
    for _ in range(30):
        batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_checkpoint_resume_bitexact(tmp_path):
    """Stop at step 5, resume, and land on identical params at step 8."""
    cfg = smoke_config("gemma3-1b")
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=2)
    oc = OptimizerConfig(lr=1e-3, warmup_steps=2, decay_steps=20)
    step_fn = jax.jit(make_train_step(cfg, oc))

    def run(state, pipe, n):
        for _ in range(n):
            b = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
            state, _ = step_fn(state, b)
        return state

    pipe = TokenPipeline(dcfg)
    state = init_train_state(jax.random.PRNGKey(1), cfg)
    state = run(state, pipe, 5)
    ckpt.save(str(tmp_path), 5, state, extra={"cursor": pipe.step})
    ref = run(state, pipe, 3)  # continue to step 8 directly

    like = init_train_state(jax.random.PRNGKey(1), cfg)
    step, restored, extra = ckpt.load_latest(str(tmp_path), like)
    pipe2 = TokenPipeline(dcfg)
    pipe2.seek(extra["cursor"])
    resumed = run(restored, pipe2, 3)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        ref.params, resumed.params)


def test_spectral_probe_finite_and_symmetric_psd_at_minimum():
    cfg = smoke_config("xlstm-125m").scaled(n_layers=4, d_model=32,
                                            vocab_size=64, head_dim=8)
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=8,
                                    global_batch=2))
    state = init_train_state(jax.random.PRNGKey(2), cfg)
    batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}

    def probe_loss(params, b):
        logits, _ = forward(params, b["tokens"], cfg, remat=False)
        return ce_loss(logits, b["labels"])[0]

    spec = curvature_spectrum(probe_loss, state.params, batch, m=8)
    assert np.isfinite(spec["sharpness"]) and np.isfinite(spec["lambda_min"])
    assert spec["sharpness"] >= spec["lambda_min"]
    assert spec["dim"] > 1000
