"""Unit tests for every stage of the GSYEIG pipelines vs numpy/LAPACK oracles."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    apply_q,
    apply_qt,
    back_transform_generalized,
    band_to_tridiag,
    bisect_eigenvalues,
    cholesky_blocked,
    cholesky_upper,
    eigh_tridiag_selected,
    inverse_iteration,
    reduce_to_band,
    sturm_count,
    to_standard_sygst,
    to_standard_two_trsm,
    tridiagonalize,
)
from repro.core.linalg_utils import householder, householder_masked, qr_wy
from repro.data.problems import dft_like, md_like


def _rand_spd(n, key, jitter=None):
    M = jax.random.normal(key, (n, n), jnp.float64)
    B = M @ M.T + n * jnp.eye(n)
    return 0.5 * (B + B.T)


def _rand_sym(n, key):
    M = jax.random.normal(key, (n, n), jnp.float64)
    return 0.5 * (M + M.T)


KEY = jax.random.PRNGKey(0)
K1, K2, K3, K4 = jax.random.split(KEY, 4)


# ---------------------------------------------------------------- helpers --

def test_householder_annihilates():
    x = jax.random.normal(K1, (17,), jnp.float64)
    v, tau, beta = householder(x)
    y = x - tau * v * (v @ x)
    np.testing.assert_allclose(float(y[0]), float(beta), rtol=1e-13)
    np.testing.assert_allclose(np.asarray(y[1:]), 0.0, atol=1e-13)
    # norm preserved
    np.testing.assert_allclose(abs(float(beta)), float(jnp.linalg.norm(x)),
                               rtol=1e-13)


def test_householder_masked_matches_dense():
    x = jax.random.normal(K2, (23,), jnp.float64)
    p = 7
    v, tau, beta = householder_masked(x, jnp.asarray(p))
    vd, taud, betad = householder(x[p:])
    np.testing.assert_allclose(np.asarray(v[p:]), np.asarray(vd), rtol=1e-13)
    np.testing.assert_allclose(float(tau), float(taud), rtol=1e-13)
    np.testing.assert_allclose(np.asarray(v[:p]), 0.0)


@pytest.mark.parametrize("p,w", [(16, 4), (40, 8), (8, 8), (5, 8)])
def test_qr_wy(p, w):
    E = jax.random.normal(K3, (p, w), jnp.float64)
    V, T, R = qr_wy(E)
    Q = jnp.eye(p) - V @ T @ V.T
    np.testing.assert_allclose(np.asarray(Q.T @ Q), np.eye(p), atol=1e-12)
    np.testing.assert_allclose(np.asarray(Q.T @ E), np.asarray(R), atol=1e-12)
    # R upper trapezoidal
    np.testing.assert_allclose(np.tril(np.asarray(R), -1), 0.0, atol=1e-12)


# ------------------------------------------------------------------- GS1 --

@pytest.mark.parametrize("n,block", [(65, 16), (128, 32), (50, 64)])
def test_cholesky_blocked(n, block):
    B = _rand_spd(n, K1)
    U = cholesky_blocked(B, block=block)
    np.testing.assert_allclose(np.asarray(U.T @ U), np.asarray(B), rtol=1e-12,
                               atol=1e-10)
    np.testing.assert_allclose(np.tril(np.asarray(U), -1), 0.0)
    Uref = cholesky_upper(B)
    np.testing.assert_allclose(np.asarray(U), np.asarray(Uref), rtol=1e-10,
                               atol=1e-10)


# ------------------------------------------------------------------- GS2 --

@pytest.mark.parametrize("n,block", [(48, 16), (96, 32), (70, 33)])
def test_standard_form_variants_agree(n, block):
    A = _rand_sym(n, K2)
    B = _rand_spd(n, K3)
    U = cholesky_upper(B)
    C1 = to_standard_two_trsm(A, U)
    C2 = to_standard_sygst(A, U, block=block)
    # numpy oracle
    Uinv = np.linalg.inv(np.asarray(U))
    Cref = Uinv.T @ np.asarray(A) @ Uinv
    np.testing.assert_allclose(np.asarray(C1), Cref, atol=1e-10)
    np.testing.assert_allclose(np.asarray(C2), Cref, atol=1e-10)


def test_standard_form_preserves_eigenvalues():
    n = 64
    A = _rand_sym(n, K2)
    B = _rand_spd(n, K3)
    U = cholesky_upper(B)
    C = to_standard_two_trsm(A, U)
    w_c = np.linalg.eigvalsh(np.asarray(C))
    # generalized eigenvalues via scipy-equivalent numpy route
    Binv_A = np.linalg.solve(np.asarray(B), np.asarray(A))
    w_g = np.sort(np.linalg.eigvals(Binv_A).real)
    np.testing.assert_allclose(w_c, w_g, rtol=1e-8, atol=1e-8)


# ------------------------------------------------------------------- TD1 --

@pytest.mark.parametrize("n", [5, 33, 96])
def test_tridiagonalize(n):
    C = _rand_sym(n, K4)
    res = tridiagonalize(C)
    # same eigenvalues
    T = np.diag(np.asarray(res.d)) + np.diag(np.asarray(res.e), 1) \
        + np.diag(np.asarray(res.e), -1)
    np.testing.assert_allclose(np.linalg.eigvalsh(T),
                               np.linalg.eigvalsh(np.asarray(C)),
                               rtol=1e-10, atol=1e-10)


def test_apply_q_orthogonal_and_consistent():
    n = 48
    C = _rand_sym(n, K1)
    res = tridiagonalize(C)
    I = jnp.eye(n, dtype=jnp.float64)
    Q = apply_q(res, I)
    np.testing.assert_allclose(np.asarray(Q.T @ Q), np.eye(n), atol=1e-12)
    # Q^T C Q should be tridiagonal T
    T = np.asarray(Q.T @ C @ Q)
    np.testing.assert_allclose(np.diag(T), np.asarray(res.d), atol=1e-10)
    np.testing.assert_allclose(np.diag(T, -1), np.asarray(res.e), atol=1e-10)
    off = T - np.diag(np.diag(T)) - np.diag(np.diag(T, 1), 1) \
        - np.diag(np.diag(T, -1), -1)
    np.testing.assert_allclose(off, 0.0, atol=1e-10)
    # qt is the inverse of q
    Z = jax.random.normal(K2, (n, 7), jnp.float64)
    np.testing.assert_allclose(np.asarray(apply_qt(res, apply_q(res, Z))),
                               np.asarray(Z), atol=1e-12)


# --------------------------------------------------------------- TT1/TT2 --

@pytest.mark.parametrize("n,w", [(40, 4), (65, 8), (96, 16)])
def test_reduce_to_band(n, w):
    C = _rand_sym(n, K3)
    band = reduce_to_band(C, w=w)
    # Q1 orthogonal
    np.testing.assert_allclose(np.asarray(band.Q1.T @ band.Q1), np.eye(n),
                               atol=1e-12)
    # W = Q1^T C Q1 and banded (Wb is the packed (w+1, n) storage; its
    # dense expansion is band-masked by construction, so the off-band part
    # of Q1^T C Q1 must be negligible)
    W = np.asarray(band.dense())
    assert band.Wb.shape == (w + 1, n)
    Wref = np.asarray(band.Q1.T @ C @ band.Q1)
    np.testing.assert_allclose(W, Wref, atol=1e-9)
    i, j = np.meshgrid(np.arange(n), np.arange(n), indexing="ij")
    assert np.all(np.abs(Wref[np.abs(i - j) > w]) < 1e-10)
    # eigenvalues preserved
    np.testing.assert_allclose(np.linalg.eigvalsh(W),
                               np.linalg.eigvalsh(np.asarray(C)),
                               rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("n,w", [(40, 4), (64, 8)])
def test_band_to_tridiag(n, w):
    C = _rand_sym(n, K4)
    band = reduce_to_band(C, w=w)
    tri = band_to_tridiag(band.Wb, band.Q1, w)
    # Q orthogonal
    np.testing.assert_allclose(np.asarray(tri.Q.T @ tri.Q), np.eye(n),
                               atol=1e-11)
    # Q^T C Q = T
    T = np.diag(np.asarray(tri.d)) + np.diag(np.asarray(tri.e), 1) \
        + np.diag(np.asarray(tri.e), -1)
    np.testing.assert_allclose(np.asarray(tri.Q.T @ C @ tri.Q), T, atol=1e-9)
    np.testing.assert_allclose(np.linalg.eigvalsh(T),
                               np.linalg.eigvalsh(np.asarray(C)),
                               rtol=1e-9, atol=1e-9)


# ------------------------------------------------------------------- TD2 --

def test_sturm_count_matches_numpy():
    n = 64
    d = jax.random.normal(K1, (n,), jnp.float64)
    e = jax.random.normal(K2, (n - 1,), jnp.float64)
    T = np.diag(np.asarray(d)) + np.diag(np.asarray(e), 1) \
        + np.diag(np.asarray(e), -1)
    w = np.linalg.eigvalsh(T)
    for x in [-3.0, -1.0, 0.0, 0.5, 2.0, w[10] + 1e-8]:
        cnt = int(sturm_count(d, e, jnp.asarray(x)))
        assert cnt == int(np.sum(w < x)), (x, cnt, int(np.sum(w < x)))


@pytest.mark.parametrize("n,s,end", [(64, 8, "low"), (64, 8, "high"),
                                     (128, 13, "low")])
def test_bisect_eigenvalues(n, s, end):
    d = jax.random.normal(K3, (n,), jnp.float64)
    e = jax.random.normal(K4, (n - 1,), jnp.float64)
    T = np.diag(np.asarray(d)) + np.diag(np.asarray(e), 1) \
        + np.diag(np.asarray(e), -1)
    w = np.linalg.eigvalsh(T)
    ks = jnp.arange(s) if end == "low" else jnp.arange(n - s, n)
    lam = bisect_eigenvalues(d, e, ks)
    np.testing.assert_allclose(np.asarray(lam), w[np.asarray(ks)], rtol=1e-12,
                               atol=1e-12)


def test_inverse_iteration_eigenvectors():
    n, s = 96, 10
    d = jax.random.normal(K1, (n,), jnp.float64)
    e = jax.random.normal(K2, (n - 1,), jnp.float64)
    lam, Z = eigh_tridiag_selected(d, e, jnp.arange(s))
    T = np.diag(np.asarray(d)) + np.diag(np.asarray(e), 1) \
        + np.diag(np.asarray(e), -1)
    R = T @ np.asarray(Z) - np.asarray(Z) * np.asarray(lam)[None, :]
    assert np.linalg.norm(R) / np.linalg.norm(T) < 1e-12
    G = np.asarray(Z).T @ np.asarray(Z)
    np.testing.assert_allclose(G, np.eye(s), atol=1e-10)


def test_inverse_iteration_clustered():
    # nearly-degenerate eigenvalues: the glued-Wilkinson trap
    n = 40
    d = jnp.concatenate([jnp.full((n // 2,), 1.0),
                         jnp.full((n // 2,), 1.0 + 1e-10)])
    e = jnp.full((n - 1,), 1e-8, jnp.float64).at[n // 2 - 1].set(1e-12)
    lam, Z = eigh_tridiag_selected(d, e, jnp.arange(6))
    G = np.asarray(Z.T @ Z)
    np.testing.assert_allclose(G, np.eye(6), atol=1e-8)


# ------------------------------------------------------------------- BT1 --

def test_back_transform_roundtrip():
    n, s = 32, 4
    B = _rand_spd(n, K1)
    U = cholesky_upper(B)
    Y = jax.random.normal(K2, (n, s), jnp.float64)
    X = back_transform_generalized(U, Y)
    np.testing.assert_allclose(np.asarray(U @ X), np.asarray(Y), atol=1e-11)


@pytest.mark.parametrize("n,panel", [(64, 8), (96, 32)])
def test_tridiagonalize_blocked_matches_unblocked(n, panel):
    from repro.core import tridiagonalize_blocked
    C = _rand_sym(n, K2)
    ref = tridiagonalize(C)
    blk = tridiagonalize_blocked(C, panel=panel)
    Tb = np.diag(np.asarray(blk.d)) + np.diag(np.asarray(blk.e), 1) \
        + np.diag(np.asarray(blk.e), -1)
    np.testing.assert_allclose(np.linalg.eigvalsh(Tb),
                               np.linalg.eigvalsh(np.asarray(C)),
                               rtol=1e-10, atol=1e-10)
    I = jnp.eye(n, dtype=jnp.float64)
    Q = apply_q(blk, I)
    np.testing.assert_allclose(np.asarray(Q.T @ Q), np.eye(n), atol=1e-12)
    np.testing.assert_allclose(np.asarray(Q.T @ C @ Q), Tb, atol=1e-9)


def test_solve_td_blocked_path():
    from repro.core import solve as solve_fn
    from repro.data.problems import md_like
    prob = md_like(72)
    res = solve_fn(prob.A, prob.B, 5, variant="TD", td1="blocked")
    np.testing.assert_allclose(np.asarray(res.evals),
                               np.asarray(prob.exact_evals[:5]),
                               rtol=1e-8, atol=1e-10)
