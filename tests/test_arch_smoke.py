"""Per-architecture smoke tests: reduced config, one forward + one train
step + one decode step on CPU; asserts output shapes and finiteness.

These exercise the exact code paths the dry-run lowers at full scale.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, arch_shapes, get_config, smoke_config
from repro.models.model import (decode_step, forward, init_decode_state,
                                init_params, layer_plan, encode)
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import (init_train_state, make_serve_step,
                                    make_train_step)

B, S = 2, 16


def _batch(cfg, key):
    kt, kl, ke = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab_size,
                                     jnp.int32),
        "labels": jax.random.randint(kl, (B, S), 0, cfg.vocab_size,
                                     jnp.int32),
    }
    if cfg.encoder_decoder:
        batch["embeds"] = jax.random.normal(ke, (B, S, cfg.d_model),
                                            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    batch = _batch(cfg, jax.random.fold_in(key, 1))
    memory = encode(params, batch["embeds"], cfg) if cfg.encoder_decoder \
        else None
    logits, aux = jax.jit(
        lambda p, t: forward(p, t, cfg, memory=memory))(
            params, batch["tokens"])
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux)), f"{arch}: non-finite moe aux"


# jamba's train-step smoke (mamba grads through the longest scan period) is
# the single heaviest arch cell (~30s); forward + decode coverage for it
# stays in the fast lane, the train step runs nightly
_TRAIN_ARCHS = [pytest.param(a, marks=(pytest.mark.slow,)
                             if a == "jamba-1.5-large-398b" else ())
                for a in ARCH_IDS]


@pytest.mark.parametrize("arch", _TRAIN_ARCHS)
def test_train_step(arch):
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(1)
    state = init_train_state(key, cfg)
    step_fn = jax.jit(make_train_step(cfg, OptimizerConfig(warmup_steps=2,
                                                           decay_steps=10)))
    batch = _batch(cfg, jax.random.fold_in(key, 2))
    state2, metrics = step_fn(state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch}: loss not finite"
    assert int(state2.step) == 1
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()),
                     state.params, state2.params))
    assert delta > 0.0, f"{arch}: no parameter update"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    memory = None
    if cfg.encoder_decoder:
        memory = encode(params, jax.random.normal(
            jax.random.fold_in(key, 9), (B, S, cfg.d_model), jnp.float32),
            cfg)
    state = init_decode_state(cfg, B, capacity=32, memory=memory)
    serve = jax.jit(make_serve_step(cfg))
    toks = jax.random.randint(jax.random.fold_in(key, 3), (B, 1), 0,
                              cfg.vocab_size, jnp.int32)
    logits, state = serve(params, toks, state)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite decode"
    assert state.pos.shape == (B,)  # per-slot positions (continuous batching)
    assert [int(p) for p in state.pos] == [1] * B
    # a second step advances the cache
    logits2, state = serve(params, toks, state)
    assert [int(p) for p in state.pos] == [2] * B
    assert bool(jnp.isfinite(logits2).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_prefill(arch):
    """Greedy decode logits == full-forward logits at the same position."""
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(3)
    params = init_params(key, cfg)
    T = 6
    toks = jax.random.randint(jax.random.fold_in(key, 4), (B, T), 0,
                              cfg.vocab_size, jnp.int32)
    memory = None
    if cfg.encoder_decoder:
        memory = encode(params, jax.random.normal(
            jax.random.fold_in(key, 8), (B, S, cfg.d_model), jnp.float32),
            cfg)
    full_logits, _ = forward(params, toks, cfg, memory=memory, remat=False)
    state = init_decode_state(cfg, B, capacity=16, memory=memory)
    serve = jax.jit(make_serve_step(cfg))
    outs = []
    for t in range(T):
        lg, state = serve(params, toks[:, t:t + 1], state)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), rtol=2e-3, atol=2e-3)


def test_layer_plan_covers_all_layers():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        kinds, P, R, tail = layer_plan(cfg)
        assert P * R + tail == cfg.n_layers == len(kinds)
        scfg = smoke_config(arch)
        k2, P2, R2, t2 = layer_plan(scfg)
        assert P2 == P, f"{arch}: smoke config changed the period"


def test_param_counts_sane():
    """Full-config param counts are within 40% of the advertised sizes."""
    approx = {
        "mistral-large-123b": 123e9,
        "chameleon-34b": 34e9,
        "qwen1.5-32b": 32e9,
        "gemma3-27b": 27e9,
        "xlstm-125m": 125e6,
        "arctic-480b": 480e9,
        "jamba-1.5-large-398b": 398e9,
    }
    for arch, target in approx.items():
        n = get_config(arch).param_count()
        assert 0.6 * target < n < 1.4 * target, \
            f"{arch}: {n/1e9:.1f}B vs target {target/1e9:.1f}B"
