"""Continuous-batching serve engine: admission, retirement, correctness."""
import numpy as np
import jax

from repro.configs import smoke_config
from repro.models.model import init_params
from repro.serve.engine import ServeEngine


def _engine(slots=2):
    cfg = smoke_config("gemma3-1b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, ServeEngine(cfg, params, batch_slots=slots, capacity=64)


def test_engine_drains_queue():
    cfg, eng = _engine(slots=2)
    rng = np.random.default_rng(0)
    uids = [eng.submit(rng.integers(0, cfg.vocab_size, size=5),
                       max_new_tokens=4) for _ in range(5)]
    done = eng.run_until_drained()
    assert sorted(r.uid for r in done) == sorted(uids)
    for r in done:
        assert len(r.output) == 4
        assert all(0 <= t < cfg.vocab_size for t in r.output)
        assert r.finished_at >= r.submitted_at


def test_engine_continuous_batching_overlaps():
    """A short request admitted later must finish while a long one runs."""
    cfg, eng = _engine(slots=2)
    rng = np.random.default_rng(1)
    long_uid = eng.submit(rng.integers(0, cfg.vocab_size, size=3),
                          max_new_tokens=20)
    short_uid = eng.submit(rng.integers(0, cfg.vocab_size, size=3),
                           max_new_tokens=2)
    third_uid = eng.submit(rng.integers(0, cfg.vocab_size, size=3),
                           max_new_tokens=2)
    done = eng.run_until_drained()
    order = [r.uid for r in done]
    # the short request retires first and frees its slot for the third
    assert order.index(short_uid) < order.index(long_uid)
    assert order.index(third_uid) < order.index(long_uid)


def test_staggered_admits_match_solo_runs():
    """Regression for the slot-reuse state leak: a request admitted into a
    freed slot mid-stream of another request must reproduce its solo-run
    output token-for-token. Before per-slot positions + admission-time
    cache reset, the new occupant started writing at the long-running
    request's position and attended to the previous occupant's cached
    keys/values."""
    cfg, eng = _engine(slots=2)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, size=4) for _ in range(3)]
    lens = [16, 3, 3]

    def solo(prompt, n_new):
        e = ServeEngine(cfg, eng.params, batch_slots=2, capacity=64)
        uid = e.submit(prompt, max_new_tokens=n_new)
        (r,) = e.run_until_drained()
        assert r.uid == uid
        return r.output

    expect = [solo(p, n) for p, n in zip(prompts, lens)]

    uid0 = eng.submit(prompts[0], max_new_tokens=lens[0])  # long occupant
    uid1 = eng.submit(prompts[1], max_new_tokens=lens[1])
    for _ in range(100):
        eng.tick()
        if any(r.uid == uid1 for r in eng.done):
            break
    # slot freed mid-stream of the long request: admit the third request
    # into it while the long request keeps decoding
    uid2 = eng.submit(prompts[2], max_new_tokens=lens[2])
    out = {r.uid: r.output for r in eng.run_until_drained()}
    assert out[uid1] == expect[1]
    assert out[uid2] == expect[2], "freed-slot re-admit diverged from solo"
    assert out[uid0] == expect[0], "long-running occupant was disturbed"


def test_engine_eos_stops_early():
    cfg, eng = _engine(slots=1)
    rng = np.random.default_rng(2)
    # probe: discover what greedy emits first for this prompt
    prompt = rng.integers(0, cfg.vocab_size, size=4)
    eng.submit(prompt, max_new_tokens=1)
    first_tok = eng.run_until_drained()[0].output[0]
    # fresh engine state, same params: eos on that token stops at length 1
    eng2 = ServeEngine(eng.cfg, eng.params, batch_slots=1, capacity=64)
    uid = eng2.submit(prompt, max_new_tokens=50, eos_id=first_tok)
    done = eng2.run_until_drained()
    assert done[-1].uid == uid and len(done[-1].output) == 1
