"""Tests for the static program auditor (analysis.static_audit).

Three layers:

* **walker units** — in-process checks of the jaxpr walk's counting
  semantics (scan multiplication, unroll, nesting, convert tracking,
  dynamic-while reporting) on tiny synthetic programs;
* **contract machinery** — a seeded precision leak the linter must catch,
  a deliberately impossible budget that must fail, and the Pallas
  tile/signature lint;
* **golden profiles** — the 2-device audit payload (session-scoped
  ``audit_report`` fixture, which subprocesses ``launch/audit.py``)
  pinned against the hand-verified program shapes of the distributed
  KE restart segment and the TT1 band sweep.
"""
import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro.analysis.static_audit import (
    AuditEntry, AuditSpec, BudgetContract, ProgramSpec,
    KE_COLLECTIVES_PER_BLOCK_STEP, TT1_COLLECTIVES_PER_PANEL,
    check_entry, hlo_counts, lint_signature_parity, profile_fn)
from repro.analysis.static_audit.pallas_lint import (
    _lint_block_shape, errors)


# --------------------------------------------------------------------------
# walker units
# --------------------------------------------------------------------------

def test_scan_length_multiplies_static_counts():
    def prog(x):
        def body(c, _):
            return c.astype(jnp.float32).astype(jnp.float64) + 1.0, None
        c, _ = lax.scan(body, x, None, length=5)
        return c

    prof = profile_fn(prog, jnp.zeros((), jnp.float64), with_hlo=False)
    # one downcast + one upcast site, each executed once per trip
    assert prof.converts["float64->float32"] == 5
    assert prof.converts["float32->float64"] == 5
    assert prof.loop_steps_static == 5
    assert len(prof.loops) == 1 and prof.loops[0].length == 5


def test_scan_unroll_reduces_sequential_steps():
    def prog(x):
        def body(c, _):
            return c + 1.0, None
        c, _ = lax.scan(body, x, None, length=6, unroll=2)
        return c

    prof = profile_fn(prog, jnp.zeros((), jnp.float64), with_hlo=False)
    # 6 trips at unroll=2 -> 3 sequential steps (what variant_model prices)
    assert prof.loop_steps_static == 3
    assert prof.loops[0].unroll == 2


def test_nested_scans_multiply():
    def prog(x):
        def outer(c, _):
            def inner(d, _):
                return d.astype(jnp.float32).astype(jnp.float64), None
            d, _ = lax.scan(inner, c, None, length=3)
            return d, None
        c, _ = lax.scan(outer, x, None, length=4)
        return c

    prof = profile_fn(prog, jnp.zeros((), jnp.float64), with_hlo=False)
    assert prof.converts["float64->float32"] == 12   # 4 outer x 3 inner
    assert prof.loop_steps_static == 4 + 4 * 3


def test_dynamic_while_reported_not_multiplied():
    def prog(x):
        return lax.while_loop(lambda c: c < 100.0, lambda c: c * 2.0, x)

    prof = profile_fn(prog, jnp.asarray(1.0, jnp.float64), with_hlo=False)
    assert prof.dynamic_whiles == 1
    assert any(lp.kind == "while" and lp.steps is None for lp in prof.loops)


def test_hlo_counts_on_lowered_text():
    def prog(x):
        c, _ = lax.scan(lambda c, _: (c + 1.0, None), x, None, length=4)
        return c

    prof = profile_fn(prog, jnp.zeros((), jnp.float64))
    # the scan lowers to exactly one stablehlo.while in the module text
    assert prof.hlo_counts["stablehlo.while"] == 1
    assert hlo_counts("stablehlo.all_gather x stablehlo.all_gather")[
        "stablehlo.all_gather"] == 2


# --------------------------------------------------------------------------
# contract machinery
# --------------------------------------------------------------------------

def _entry(fn, args, contract, name="synthetic"):
    return AuditEntry(
        name=name,
        build=lambda: [ProgramSpec(name=name, fn=fn, args=args,
                                   with_hlo=False)],
        contract=contract)


def test_seeded_precision_leak_is_caught():
    """Regression seed for satellite 2: core/ and dist/ audit clean today
    (AUDIT.json shows zero f64 downcasts), so prove the linter *would*
    catch one by injecting the classic accidental-demotion pattern."""
    def leaky(x):
        return (x.astype(jnp.float32) * 2).astype(jnp.float64)

    x = jnp.zeros((4, 4), jnp.float64)
    prof = profile_fn(leaky, x, with_hlo=False)
    assert prof.f64_downcasts() == {"float64->float32": 1}

    rep = check_entry(_entry(leaky, (x,), BudgetContract(
        forbid_f64_downcasts=True,
        # float32 intentionally outside the allowed set too
    )))
    assert not rep.ok
    assert any("precision leak" in v for v in rep.violations)
    assert any("float32" in v and "outside allowed set" in v
               for v in rep.violations)


def test_clean_program_passes_same_contract():
    def clean(x):
        return x * 2.0

    x = jnp.zeros((4, 4), jnp.float64)
    rep = check_entry(_entry(clean, (x,), BudgetContract(
        max_dispatches=1, forbid_f64_downcasts=True)))
    assert rep.ok, rep.violations


def test_impossible_budget_fails():
    def prog(x):
        c, _ = lax.scan(lambda c, _: (c + 1.0, None), x, None, length=4)
        return c

    x = jnp.zeros((), jnp.float64)
    rep = check_entry(_entry(prog, (x,), BudgetContract(
        max_dispatches=0, exact_collectives=999)))
    assert not rep.ok
    assert any("dispatches 1 > budget 0" in v for v in rep.violations)
    assert any("!= pinned 999" in v for v in rep.violations)


def test_pallas_tile_lint_rules():
    assert _lint_block_shape("k", (8, 128)) == []
    assert _lint_block_shape("k", (16, 256)) == []
    lane_err = _lint_block_shape("k", (8, 130))
    assert [f.severity for f in lane_err] == ["error"]
    sub_err = _lint_block_shape("k", (12, 128))
    assert [f.severity for f in sub_err] == ["error"]
    # sub-tile lanes are warnings (Mosaic pads small operands)
    assert all(f.severity == "warn" for f in _lint_block_shape("k", (8, 64)))


def test_kernel_signature_parity_holds():
    findings = lint_signature_parity()
    assert errors(findings) == [], [f.detail for f in errors(findings)]


# --------------------------------------------------------------------------
# recompile hazard: same bucket shape must hit the pipeline cache
# --------------------------------------------------------------------------

def test_same_bucket_hits_pipeline_cache():
    from repro.core import batched

    kwargs = dict(band_width=4, m=12, max_restarts=8, p=2)
    fn1, key1 = batched.get_pipeline(32, 3, "KE", "smallest", **kwargs)
    before = batched.cache_stats()
    fn2, key2 = batched.get_pipeline(32, 3, "KE", "smallest", **kwargs)
    after = batched.cache_stats()
    assert key1 == key2
    assert fn2 is fn1, "identical bucket recompiled (jit cache miss hazard)"
    assert after["hits"] == before["hits"] + 1
    # a genuinely different bucket must NOT alias the cached program
    fn3, key3 = batched.get_pipeline(32, 3, "KE", "largest", **kwargs)
    assert key3 != key1 and fn3 is not fn1


# --------------------------------------------------------------------------
# golden profiles (2-device audit subprocess via the session fixture)
# --------------------------------------------------------------------------

def test_audit_payload_overall_ok(audit_report):
    assert audit_report["ok"], audit_report["summary"]
    assert audit_report["summary"]["budget_violations"] == 0
    assert audit_report["summary"]["precision_leaks"] == 0
    assert audit_report["summary"]["crosscheck_failures"] == 0


def test_golden_profile_ke_restart(assert_program_budget):
    """The fused KE restart segment: ONE dispatch, exactly 2 collectives
    (psum + all_gather) per block step, m/p = 6 steps at the audit spec."""
    spec = AuditSpec()
    entry = assert_program_budget("dist/ke_restart_program")
    assert entry["dispatches"] == 1
    steps = spec.m // spec.p
    assert entry["max_collectives_per_step"] == KE_COLLECTIVES_PER_BLOCK_STEP
    assert entry["total_collectives"] == KE_COLLECTIVES_PER_BLOCK_STEP * steps
    (prog,) = entry["programs"]
    assert prog["collective_counts"] == {"all_reduce": steps,
                                         "all_gather": steps}
    scans = [lp for lp in prog["loops"] if lp["kind"] == "scan"]
    assert any(lp["length"] == steps for lp in scans)
    assert prog["dynamic_whiles"] == 0
    assert prog["f64_downcasts"] == {}


def test_golden_profile_band_sweep(assert_program_budget):
    """The fused TT1 sweep: gather(panel) + psum(coupling) + gather(Z)
    = 3 collectives per panel, times n/w = 7 panels, plus the band
    repack as a second (collective-free) dispatch."""
    spec = AuditSpec()
    entry = assert_program_budget("dist/band_sweep_program")
    n_panels = 7                       # _n_panels(n=64, w=8)
    assert entry["dispatches"] == 2    # sweep program + band repack
    assert entry["max_collectives_per_step"] == TT1_COLLECTIVES_PER_PANEL
    assert entry["total_collectives"] == TT1_COLLECTIVES_PER_PANEL * n_panels
    sweep = next(p for p in entry["programs"]
                 if p["name"] == "band_sweep_program")
    assert sweep["collective_counts"] == {"all_gather": 2 * n_panels,
                                          "all_reduce": n_panels}
    assert sweep["dynamic_whiles"] == 0
    assert sweep["f64_downcasts"] == {}


def test_golden_tt3_collective_structure(assert_program_budget):
    """Distributed TT3 is 1 + iters collectives: one cluster all_gather
    up front, one merge all_gather per refinement iteration."""
    spec = AuditSpec()
    entry = assert_program_budget("dist/tt3_program")
    assert entry["total_collectives"] == 1 + spec.tt3_iters
    assert entry["max_collectives_per_step"] == 1


def test_crosscheck_model_vs_counted(audit_report):
    """Every StageCost cross-check agreed — and the exact ones really
    are exact (TT2/TT4 loop ladders, KE dispatch structure)."""
    checks = {(c["stage"], c["field"]): c for c in audit_report["crosscheck"]}
    assert all(c["ok"] for c in checks.values()), [
        k for k, c in checks.items() if not c["ok"]]
    for key in [("TT2", "loop_steps"), ("TT4", "loop_steps"),
                ("KE", "dispatches"), ("TT1", "collectives_per_panel")]:
        assert key in checks and checks[key]["relation"] == "exact"
