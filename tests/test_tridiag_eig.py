"""TT3/TD2 tridiagonal eigensolver: core + kernels/tridiag_eig parity.

Covers the three execution paths of ``eigh_tridiag_selected`` ('scan'
baseline, fused 'batched', Pallas 'kernel' in interpret mode), the
shuffled-``ks`` clustering regression (sort-and-restore), clustered /
graded spectra vs the LAPACK oracle, and the n=1 / s=n edges.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.tridiag_eig import (bisect_eigenvalues, eigh_tridiag_selected,
                                    inverse_iteration)
from repro.kernels.tridiag_eig.ops import (bisect_sturm, invit_batched,
                                           tridiag_eig_batched,
                                           tridiag_eig_kernel)
from repro.kernels.tridiag_eig.ref import bisect_sturm_ref, invit_ref

KEY = jax.random.PRNGKey(0)


def _rand_tridiag(n, key):
    kd, ke = jax.random.split(key)
    d = jax.random.normal(kd, (n,), jnp.float64)
    e = jax.random.normal(ke, (max(n - 1, 0),), jnp.float64)
    return d, e


def _dense(d, e):
    T = np.diag(np.asarray(d))
    if np.asarray(e).size:
        T += np.diag(np.asarray(e), 1) + np.diag(np.asarray(e), -1)
    return T


def _wilkinson(m=10):
    """W(2m+1)+: the top eigenvalue pairs agree to ~machine precision —
    the canonical cluster fixture for inverse-iteration reorthogonalization."""
    n = 2 * m + 1
    d = jnp.asarray(np.abs(np.arange(n) - m), jnp.float64)
    e = jnp.ones((n - 1,), jnp.float64)
    return d, e


def _graded(n=40):
    """Graded spectrum spanning ~12 decades (d_i = 10^{-i/3})."""
    d = jnp.asarray(10.0 ** (-np.arange(n) / 3.0), jnp.float64)
    e = 1e-4 * jnp.asarray(10.0 ** (-np.arange(n - 1) / 3.0), jnp.float64)
    return d, e


# ------------------------------------------------------------ eigenvalues --

@pytest.mark.parametrize("fixture", ["random", "clustered", "graded"])
def test_bisection_matches_eigvalsh(fixture):
    if fixture == "random":
        d, e = _rand_tridiag(48, KEY)
        ks = jnp.arange(8)
        tol = 1e-12
    elif fixture == "clustered":
        d, e = _wilkinson(10)
        ks = jnp.arange(d.shape[0] - 8, d.shape[0])
        tol = 1e-12
    else:
        d, e = _graded(40)
        ks = jnp.arange(8)
        tol = 1e-12
    ref = np.linalg.eigvalsh(_dense(d, e))
    lam = bisect_eigenvalues(d, e, ks)
    assert np.abs(np.asarray(lam) - ref[np.asarray(ks)]).max() < tol


def test_bisection_unroll_is_bitwise_neutral():
    d, e = _rand_tridiag(37, KEY)
    ks = jnp.arange(6)
    base = np.asarray(bisect_eigenvalues(d, e, ks))
    for unroll in (4, 16):
        assert np.array_equal(
            base, np.asarray(bisect_eigenvalues(d, e, ks, unroll=unroll)))


# ----------------------------------------------------------- eigenvectors --

def _check_pairs(d, e, lam, Z, rtol=1e-10):
    T = _dense(d, e)
    Z = np.asarray(Z)
    lam = np.asarray(lam)
    scale = max(np.abs(T).max(), 1.0)
    assert np.abs(T @ Z - Z * lam).max() < rtol * scale
    assert np.abs(Z.T @ Z - np.eye(Z.shape[1])).max() < rtol


def test_inverse_iteration_residual_orthogonality():
    d, e = _rand_tridiag(48, KEY)
    lam = bisect_eigenvalues(d, e, jnp.arange(8))
    Z = inverse_iteration(d, e, lam, jax.random.PRNGKey(3))
    _check_pairs(d, e, lam, Z)


def test_inverse_iteration_clustered_orthogonality():
    d, e = _wilkinson(10)
    n = d.shape[0]
    lam, Z = eigh_tridiag_selected(d, e, jnp.arange(n - 6, n))
    _check_pairs(d, e, lam, Z)


# ---------------------------------------------- shuffled-ks regression ----

def test_eigh_selected_shuffled_ks_regression():
    """Unsorted ``ks`` used to feed unsorted shifts into the gap-based
    clustering: the Wilkinson top pair landed in different clusters, MGS
    skipped them, and the returned 'eigenvectors' overlapped at ~1e-3.
    ``eigh_tridiag_selected`` must sort-and-restore."""
    d, e = _wilkinson(10)
    n = d.shape[0]
    ks = jnp.asarray([n - 1, n - 3, n - 2, n - 4])  # interleaves the pair
    lam, Z = eigh_tridiag_selected(d, e, ks)
    _check_pairs(d, e, lam, Z)
    # and the output order answers ks as given
    ref = np.linalg.eigvalsh(_dense(d, e))
    assert np.abs(np.asarray(lam) - ref[np.asarray(ks)]).max() < 1e-12


def test_eigh_selected_shuffled_matches_sorted():
    d, e = _rand_tridiag(32, jax.random.PRNGKey(7))
    ks = jnp.arange(6)
    perm = jnp.asarray([4, 0, 5, 2, 1, 3])
    lam_s, Z_s = eigh_tridiag_selected(d, e, ks)
    lam_p, Z_p = eigh_tridiag_selected(d, e, ks[perm])
    assert np.array_equal(np.asarray(lam_s)[np.asarray(perm)],
                          np.asarray(lam_p))
    assert np.array_equal(np.asarray(Z_s)[:, np.asarray(perm)],
                          np.asarray(Z_p))


# ------------------------------------------------------------------ edges --

@pytest.mark.parametrize("method", ["scan", "batched", "kernel"])
def test_n_equals_1(method):
    lam, Z = eigh_tridiag_selected(jnp.asarray([2.5]), jnp.zeros((0,)),
                                   jnp.asarray([0]), method=method)
    assert np.allclose(np.asarray(lam), [2.5])
    assert np.allclose(np.abs(np.asarray(Z)), [[1.0]])


@pytest.mark.parametrize("method", ["scan", "batched", "kernel"])
def test_s_equals_n(method):
    d, e = _rand_tridiag(12, jax.random.PRNGKey(5))
    lam, Z = eigh_tridiag_selected(d, e, jnp.arange(12), method=method)
    ref = np.linalg.eigvalsh(_dense(d, e))
    assert np.abs(np.asarray(lam) - ref).max() < 1e-12
    _check_pairs(d, e, lam, Z)


# -------------------------------------------------- batched/kernel parity --

def test_batched_path_bitwise_equals_scan():
    d, e = _rand_tridiag(45, KEY)
    ks = jnp.arange(7)
    key = jax.random.PRNGKey(11)
    lam_s, Z_s = eigh_tridiag_selected(d, e, ks, key, method="scan")
    lam_b, Z_b = eigh_tridiag_selected(d, e, ks, key, method="batched")
    assert np.array_equal(np.asarray(lam_s), np.asarray(lam_b))
    assert np.array_equal(np.asarray(Z_s), np.asarray(Z_b))


@pytest.mark.parametrize("n,s", [(33, 5), (24, 6)])
def test_bisect_kernel_interpret_bitwise_vs_ref(n, s):
    """Pallas bisection (interpret) reproduces the scan oracle BITWISE —
    same Gershgorin start, same splits, same clamped recurrence; odd n
    exercises the sublane padding."""
    if n == 24:
        d, e = _wilkinson(11)
        d, e = d[:24], e[:23]
    else:
        d, e = _rand_tridiag(n, KEY)
    ks = jnp.arange(s)
    lam_ref = bisect_sturm_ref(d, e, ks)
    lam_k = bisect_sturm(d, e, ks, force_kernel=True)
    assert np.array_equal(np.asarray(lam_ref), np.asarray(lam_k))


def test_invit_kernel_interpret_parity_random():
    d, e = _rand_tridiag(33, KEY)  # odd n: sublane padding in play
    lam = bisect_eigenvalues(d, e, jnp.arange(5))
    key = jax.random.PRNGKey(9)
    Z_ref = invit_ref(d, e, lam, key)
    Z_k = invit_batched(d, e, lam, key, force_kernel=True)
    # same start block, same algorithm; kernel reductions may reassociate
    assert np.abs(np.asarray(Z_ref) - np.asarray(Z_k)).max() < 1e-12
    _check_pairs(d, e, lam, Z_k)


def test_invit_kernel_interpret_parity_clustered():
    """Duplicate-eigenvalue clusters: the kernel's lane-masked MGS must
    orthogonalize the Wilkinson twin pairs exactly like the oracle."""
    d, e = _wilkinson(10)
    n = d.shape[0]
    lam = bisect_eigenvalues(d, e, jnp.arange(n - 6, n))
    key = jax.random.PRNGKey(9)
    Z_ref = invit_ref(d, e, lam, key)
    Z_k = invit_batched(d, e, lam, key, force_kernel=True)
    # within a machine-precision-degenerate pair, eps-level reduction
    # reassociation rotates the basis inside the invariant subspace by
    # O(sqrt(eps)) — elementwise parity is bounded accordingly, and the
    # residual/orthogonality bars below are the strict check
    assert np.abs(np.asarray(Z_ref) - np.asarray(Z_k)).max() < 2e-6
    _check_pairs(d, e, lam, Z_k)


def test_tridiag_eig_kernel_end_to_end():
    d, e = _rand_tridiag(33, jax.random.PRNGKey(21))
    ks = jnp.arange(5)
    lam, Z = tridiag_eig_kernel(d, e, ks, jax.random.PRNGKey(2))
    ref = np.linalg.eigvalsh(_dense(d, e))
    assert np.abs(np.asarray(lam) - ref[:5]).max() < 1e-12
    _check_pairs(d, e, lam, Z)


def test_default_method_autodetects_backend(monkeypatch):
    """``method=None`` resolves per backend: the compiled Pallas kernels on
    a real TPU, the fused-XLA batched program everywhere else — and the
    dispatch structure (which underlying path runs) follows the resolved
    choice, not a hard-coded default."""
    from repro.core import tridiag_eig as te

    # the resolver itself: pure function of the backend name (patching
    # jax.default_backend here runs no jax computation)
    assert te.default_tridiag_method() in ("kernel", "batched")
    monkeypatch.setattr(te.jax, "default_backend", lambda: "tpu")
    assert te.default_tridiag_method() == "kernel"
    monkeypatch.setattr(te.jax, "default_backend", lambda: "cpu")
    assert te.default_tridiag_method() == "batched"
    monkeypatch.undo()

    # dispatch structure: method=None must route through whatever the
    # resolver picked — spy on the two underlying entry points
    import repro.kernels.tridiag_eig.ops as ops
    calls = []
    real_batched, real_kernel = ops.tridiag_eig_batched, ops.tridiag_eig_kernel
    monkeypatch.setattr(ops, "tridiag_eig_batched",
                        lambda *a, **k: calls.append("batched")
                        or real_batched(*a, **k))
    # off-TPU the kernel route must still run (interpret mode)
    monkeypatch.setattr(ops, "tridiag_eig_kernel",
                        lambda *a, **k: calls.append("kernel")
                        or real_kernel(*a, force_interpret=True, **k))

    d, e = _rand_tridiag(16, jax.random.PRNGKey(3))
    monkeypatch.setattr(te, "default_tridiag_method", lambda: "batched")
    te.eigh_tridiag_selected(d, e, jnp.arange(3))
    assert calls == ["batched"]
    monkeypatch.setattr(te, "default_tridiag_method", lambda: "kernel")
    te.eigh_tridiag_selected(d, e, jnp.arange(3))
    assert calls == ["batched", "kernel"]


def test_tridiag_eig_batched_vmaps():
    """The fused path must vmap — it is what core.batched buckets run."""
    batch, n, s = 3, 16, 4
    keys = jax.random.split(jax.random.PRNGKey(17), batch)
    ds = jax.random.normal(keys[0], (batch, n), jnp.float64)
    es = jax.random.normal(keys[1], (batch, n - 1), jnp.float64)
    ks = jnp.arange(s)
    lam, Z = jax.vmap(lambda d, e, k: tridiag_eig_batched(d, e, ks, k))(
        ds, es, keys)
    for i in range(batch):
        ref = np.linalg.eigvalsh(_dense(ds[i], es[i]))
        assert np.abs(np.asarray(lam[i]) - ref[:s]).max() < 1e-12
