"""`GSyEigResult.info` must be JSON-serializable at the boundary: the
benchmark scripts `json.dump` it verbatim, and a jax array smuggled into
`info` (resid_bounds used to be one) breaks them at write time."""
import json

import pytest

from repro.core import solve
from repro.data.problems import md_like

N, S = 64, 4


@pytest.fixture(scope="module")
def ke_result():
    prob = md_like(N)
    return solve(prob.A, prob.B, S, variant="KE")


def test_info_json_roundtrip(ke_result):
    payload = json.dumps(ke_result.info)          # must not raise
    back = json.loads(payload)
    assert back["variant"] == "KE"
    assert back["n"] == N and back["s"] == S
    assert back["n_matvec"] == ke_result.info["n_matvec"]


def test_resid_bounds_plain_lists(ke_result):
    rb = ke_result.info["resid_bounds"]
    assert isinstance(rb, list) and len(rb) == S
    assert all(isinstance(x, float) for x in rb)


def test_stage_times_json_clean(ke_result):
    times = json.loads(json.dumps(ke_result.stage_times))
    assert "Tot." in times
    assert all(isinstance(v, float) for v in times.values())


def test_health_and_recovery_json_roundtrip(ke_result):
    """Every solve carries the resilience fields, JSON-clean end to end
    (the serving engine and the bench scripts dump them verbatim)."""
    back = json.loads(json.dumps(ke_result.info))
    assert back["health"]["healthy"] is True
    assert back["health"]["first_unhealthy_stage"] is None
    stages = back["health"]["stages"]
    assert stages.get("GS1") is True and stages.get("OUT") is True
    assert back["recovery"] == []


def test_auto_router_info_json_clean():
    prob = md_like(48)
    res = solve(prob.A, prob.B, 3, variant="auto")
    back = json.loads(json.dumps(res.info))
    assert back["router"]["variant"] == back["variant"]
    assert set(back["router"]["table"]) >= {"TD", "TT"}
