"""Band storage + wavefront bulge chase: parity against the dense oracle.

The packed chase executes the SAME rotation sequence as
``band_to_tridiag_dense`` (the wavefront schedule only reorders
provably-disjoint rotations), so d, e, the accumulated Q, and Q2-applied
eigenvector slabs must agree to ~1e-12 on well-scaled inputs. Invariants
(orthogonality, reduction residual) are checked at 1e-12 on every case —
including the degenerate n <= w+2 ones where the chase partially or fully
skips.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.band_storage import (band_extract_tridiag, clean_band,
                                     from_band_mv_layout, pack_band,
                                     to_band_mv_layout, unpack_band)
from repro.core.sbr import (accumulate_q2, apply_q2, band_chase,
                            band_to_tridiag, band_to_tridiag_dense,
                            reduce_to_band)

KEY = jax.random.PRNGKey(20260729)


def _rand_sym(n, key):
    M = jax.random.normal(key, (n, n), jnp.float64)
    return 0.5 * (M + M.T)


# ------------------------------------------------------------- storage ----

@pytest.mark.parametrize("n,w", [(17, 3), (32, 8), (5, 7), (1, 2)])
def test_pack_unpack_roundtrip(n, w):
    C = _rand_sym(n, jax.random.fold_in(KEY, n * 31 + w))
    band = pack_band(C, w)
    # band-masked part of C survives the round trip
    dist = np.abs(np.arange(n)[:, None] - np.arange(n)[None, :])
    masked = np.where(dist <= w, np.asarray(C), 0.0)
    np.testing.assert_allclose(np.asarray(unpack_band(band)), masked,
                               atol=1e-15)
    # tail entries (i + d >= n) are zero by construction
    np.testing.assert_array_equal(np.asarray(band),
                                  np.asarray(clean_band(band)))
    # symmetrize=True averages the triangles: symmetric input is unchanged
    np.testing.assert_allclose(np.asarray(pack_band(C, w, symmetrize=True)),
                               np.asarray(band), atol=1e-15)
    d, e = band_extract_tridiag(band)
    np.testing.assert_allclose(np.asarray(d), np.diag(np.asarray(C)),
                               atol=1e-15)
    if n > 1:
        np.testing.assert_allclose(np.asarray(e),
                                   np.diag(np.asarray(C), -1), atol=1e-15)


def test_band_mv_layout_conversion():
    """(w+1, n) lower-packed <-> kernels/band_mv's (n, w+1) upper layout."""
    from repro.kernels.band_mv.ref import dense_to_band as mv_pack
    n, w = 24, 5
    C = _rand_sym(n, jax.random.fold_in(KEY, 7))
    band = pack_band(C, w)
    np.testing.assert_allclose(np.asarray(to_band_mv_layout(band)),
                               np.asarray(mv_pack(C, w)), atol=1e-15)
    np.testing.assert_array_equal(
        np.asarray(from_band_mv_layout(to_band_mv_layout(band))),
        np.asarray(band))


def test_pack_band_vmaps():
    n, w, batch = 12, 3, 4
    Cs = jnp.stack([_rand_sym(n, jax.random.fold_in(KEY, i))
                    for i in range(batch)])
    packed = jax.vmap(lambda c: pack_band(c, w))(Cs)
    dense = jax.vmap(unpack_band)(packed)
    for i in range(batch):
        np.testing.assert_allclose(np.asarray(packed[i]),
                                   np.asarray(pack_band(Cs[i], w)),
                                   atol=1e-15)
        np.testing.assert_allclose(np.asarray(dense[i]),
                                   np.asarray(unpack_band(packed[i])),
                                   atol=1e-15)


# ----------------------------------------------- chase parity vs dense ----

# odd/even n, w | n and w not | n, and the n <= w+2 degenerate corner
PARITY_GRID = [(40, 4), (41, 5), (64, 8), (65, 8), (37, 7), (96, 16),
               (9, 7), (10, 8), (6, 8)]


@pytest.mark.parametrize("n,w", PARITY_GRID)
def test_band_chase_matches_dense_reference(n, w):
    s = min(4, n)
    C = _rand_sym(n, jax.random.fold_in(KEY, n * 100 + w))
    band = reduce_to_band(C, w=w)
    ref = band_to_tridiag_dense(unpack_band(band.Wb), band.Q1, w)
    got = band_to_tridiag(band.Wb, band.Q1, w)
    np.testing.assert_allclose(np.asarray(got.d), np.asarray(ref.d),
                               atol=1e-12)
    np.testing.assert_allclose(np.asarray(got.e), np.asarray(ref.e),
                               atol=1e-12)
    np.testing.assert_allclose(np.asarray(got.Q), np.asarray(ref.Q),
                               atol=1e-12)
    # Q2-applied eigenvector slab: the production back-transform path
    # (band_chase + apply_q2, no explicit Q2) against the dense oracle
    chase = band_chase(band.Wb, w)
    from repro.core.tridiag_eig import eigh_tridiag_selected
    lam, Z = eigh_tridiag_selected(ref.d, ref.e, jnp.arange(s), KEY)
    X_ref = ref.Q @ Z
    X_got = band.Q1 @ apply_q2(chase, Z, w)
    np.testing.assert_allclose(np.asarray(X_got), np.asarray(X_ref),
                               atol=1e-12)


@pytest.mark.parametrize("n,w", PARITY_GRID)
def test_band_chase_invariants(n, w):
    """Backend-independent guarantees: Q orthogonal, Q^T C Q tridiagonal."""
    C = _rand_sym(n, jax.random.fold_in(KEY, n * 17 + w))
    band = reduce_to_band(C, w=w)
    tri = band_to_tridiag(band.Wb, band.Q1, w)
    Q = np.asarray(tri.Q)
    np.testing.assert_allclose(Q.T @ Q, np.eye(n), atol=1e-12)
    T = np.diag(np.asarray(tri.d))
    if n > 1:
        T += np.diag(np.asarray(tri.e), 1) + np.diag(np.asarray(tri.e), -1)
    np.testing.assert_allclose(Q.T @ np.asarray(C) @ Q, T, atol=1e-11)
    np.testing.assert_allclose(np.linalg.eigvalsh(T),
                               np.linalg.eigvalsh(np.asarray(C)),
                               rtol=1e-9, atol=1e-9)


def test_accumulate_and_apply_are_consistent():
    """Q1 @ (Q2 @ Z) == (Q1 Q2) @ Z through the two replay directions."""
    n, w, s = 48, 6, 5
    C = _rand_sym(n, jax.random.fold_in(KEY, 4242))
    band = reduce_to_band(C, w=w)
    chase = band_chase(band.Wb, w)
    Z = jax.random.normal(jax.random.fold_in(KEY, 1), (n, s), jnp.float64)
    lhs = band.Q1 @ apply_q2(chase, Z, w)
    rhs = accumulate_q2(chase, band.Q1, w) @ Z
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), atol=1e-13)


def test_reduce_to_band_window_matches_full():
    """The shrinking-window ladder reproduces the full-(n, n) masked path."""
    n, w = 80, 8
    C = _rand_sym(n, jax.random.fold_in(KEY, 99))
    full = reduce_to_band(C, w=w, n_chunks=1)
    win = reduce_to_band(C, w=w, n_chunks=4)
    np.testing.assert_allclose(np.asarray(win.Wb), np.asarray(full.Wb),
                               atol=1e-11)
    np.testing.assert_allclose(np.asarray(win.Q1), np.asarray(full.Q1),
                               atol=1e-11)
    # and both satisfy the reduction invariant
    np.testing.assert_allclose(
        np.asarray(win.Q1.T @ C @ win.Q1), np.asarray(unpack_band(win.Wb)),
        atol=1e-9)


def test_band_chase_under_vmap():
    """The batched TT pipeline vmaps the chase; spot-check parity there."""
    n, w, batch = 32, 4, 3
    Cs = jnp.stack([_rand_sym(n, jax.random.fold_in(KEY, 50 + i))
                    for i in range(batch)])
    bands = jax.vmap(lambda c: reduce_to_band(c, w=w))(Cs)
    tris = jax.vmap(lambda wb, q: band_to_tridiag(wb, q, w))(bands.Wb,
                                                             bands.Q1)
    for i in range(batch):
        solo = band_to_tridiag(bands.Wb[i], bands.Q1[i], w)
        np.testing.assert_allclose(np.asarray(tris.d[i]),
                                   np.asarray(solo.d), atol=1e-12)
        np.testing.assert_allclose(np.asarray(tris.Q[i]),
                                   np.asarray(solo.Q), atol=1e-12)
