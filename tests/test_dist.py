"""Distribution layer: checkpoint round-trip/atomicity, error-feedback
compression, straggler monitor, elastic remesh plans, partitioning rules."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.dist import checkpoint as ckpt
from repro.dist.compression import (compress_with_feedback, decompress,
                                    init_ef_state)
from repro.dist.elastic import plan_remesh
from repro.dist.straggler import StragglerMonitor


# ------------------------------------------------------------ checkpoint --

def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (4, 8)),
            "nested": {"b": jax.random.normal(k2, (3,)),
                       "step": jnp.asarray(7)}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree(jax.random.PRNGKey(0))
    ckpt.save(str(tmp_path), 12, t, extra={"cursor": 34})
    out = ckpt.load_latest(str(tmp_path), t)
    assert out is not None
    step, restored, extra = out
    assert step == 12 and extra["cursor"] == 34
    jax.tree.map(lambda a, b: np.testing.assert_allclose(np.asarray(a),
                                                         np.asarray(b)),
                 t, restored)


def test_checkpoint_retention_and_latest(tmp_path):
    t = _tree(jax.random.PRNGKey(1))
    for s in [1, 2, 3, 4, 5]:
        ckpt.save(str(tmp_path), s, t, keep=3)
    assert ckpt.latest_step(str(tmp_path)) == 5
    kept = sorted(os.listdir(tmp_path))
    assert len([d for d in kept if d.startswith("step_")]) == 3


def test_checkpoint_skips_corrupt(tmp_path):
    t = _tree(jax.random.PRNGKey(2))
    ckpt.save(str(tmp_path), 1, t)
    # simulate a crash mid-write: tmp dir without manifest
    os.makedirs(tmp_path / "step_00000002.tmp")
    # and a finalized-looking dir without manifest
    os.makedirs(tmp_path / "step_00000003")
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_lanczos_checkpoint_resume(tmp_path):
    """A preempted eigensolve resumes from the persisted factorization."""
    from repro.core import ExplicitC, lanczos_solve
    n, s = 64, 4
    key = jax.random.PRNGKey(3)
    lam = jnp.sort(jax.random.normal(key, (n,), jnp.float64)) * 5
    Q, _ = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(key, 1),
                                           (n, n), jnp.float64))
    C = 0.5 * ((Q * lam[None, :]) @ Q.T + ((Q * lam[None, :]) @ Q.T).T)
    cb = ckpt.lanczos_callback(str(tmp_path), every=1)
    res = lanczos_solve(ExplicitC(C), s, which="SA", callback=cb)
    assert res.converged
    saved = ckpt.load_latest(str(tmp_path),
                             {"V": jnp.zeros((n, 21)),
                              "T": jnp.zeros((21, 21))})
    assert saved is not None
    _, fact, extra = saved
    assert extra["kind"] == "lanczos"
    assert fact["V"].shape[0] == n


# ----------------------------------------------------------- compression --

def test_ef_compression_bounded_error():
    key = jax.random.PRNGKey(4)
    g = {"w": jax.random.normal(key, (64, 64), jnp.float32)}
    ef = init_ef_state(g)
    q, s, ef = compress_with_feedback(g, ef)
    deq = decompress(q, s)
    # int8 quantization error <= scale/2 per element + EF carries the rest
    err = np.abs(np.asarray(deq["w"]) - np.asarray(g["w"])).max()
    assert err <= float(s["w"]) * 0.5 + 1e-6
    assert q["w"].dtype == jnp.int8


def test_ef_accumulates_small_signals():
    """EF telescopes: sum of transmissions = sum of gradients - final error,
    so even signals far below one quantization step get through eventually."""
    g = {"w": jnp.full((8, 8), 1e-4, jnp.float32)
         .at[0, 0].set(1.0)}  # scale ~ 1/127 >> 1e-4
    ef = init_ef_state(g)
    total = jnp.zeros((8, 8), jnp.float32)
    last_scale = 0.0
    for _ in range(100):
        q, s, ef = compress_with_feedback(g, ef)
        total = total + decompress(q, s)["w"]
        last_scale = float(s["w"])
    # telescoping: |total - 100 g| = |e_final| <= one quantization step
    err = float(jnp.abs(total[1, 1] - 100 * 1e-4))
    assert err <= last_scale, (err, last_scale)
    # and without EF nothing would ever be transmitted for this element
    q0, s0 = jnp.round(g["w"][1, 1] / last_scale), last_scale
    assert float(q0) == 0.0


# -------------------------------------------------------------- straggler --

def test_straggler_detection_and_rebalance():
    mon = StragglerMonitor(n_hosts=8)
    for step in range(5):
        for h in range(8):
            mon.record(h, 1.0 if h != 3 else 2.5)  # host 3 is slow
    assert mon.stragglers() == [3]
    plan = mon.rebalance_plan(microbatches_per_host=4)
    assert sum(plan.values()) == 32
    assert plan[3] < 4           # slow host sheds load
    assert max(plan.values()) <= 6


def test_straggler_none_when_uniform():
    mon = StragglerMonitor(n_hosts=4)
    for _ in range(4):
        for h in range(4):
            mon.record(h, 1.0)
    assert mon.stragglers() == []
    plan = mon.rebalance_plan(2)
    assert all(v == 2 for v in plan.values())


# ---------------------------------------------------------------- elastic --

def test_plan_remesh_keeps_tp():
    p = plan_remesh(512, model_parallel=16, pods=2)
    assert p.new_shape == (2, 16, 16)
    p2 = plan_remesh(480, model_parallel=16)  # lost 32 chips
    assert p2.new_shape == (30, 16)
    p3 = plan_remesh(500, model_parallel=16)  # ragged: drop remainder
    assert p3.new_shape == (31, 16)
    assert "dropping" in p3.note


def test_plan_remesh_rejects_impossible():
    with pytest.raises(ValueError):
        plan_remesh(8, model_parallel=16)


# ---------------------------------------------------------- partitioning --

@pytest.mark.slow
def test_partitioning_rules_shape_aware():
    """Run in a subprocess with 8 host devices to exercise a real mesh."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, functools
        from jax.sharding import PartitionSpec as P
        from repro.configs import smoke_config
        from repro.dist.partitioning import (param_shardings,
                                             decode_state_shardings,
                                             batch_shardings)
        from repro.models.model import init_params, init_decode_state
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = smoke_config("qwen2-moe-a2.7b")
        shapes = jax.eval_shape(functools.partial(init_params, cfg=cfg),
                                jax.random.PRNGKey(0))
        sh = param_shardings(mesh, shapes)
        flat = jax.tree_util.tree_leaves_with_path(sh)
        specs = {"/".join(str(k) for k in p): s.spec for p, s in flat}
        # experts sharded over model (EP)
        ep = [v for k, v in specs.items() if "w_gate" in k]
        assert any("model" in str(s) for s in ep), ep
        st = jax.eval_shape(lambda: init_decode_state(cfg, 8, capacity=32))
        dsh = decode_state_shardings(mesh, st)
        bsh = batch_shardings(mesh, {
            "tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)})
        assert "data" in str(bsh["tokens"].spec)
        # B=1 batch must NOT get sharded over data
        bsh1 = batch_shardings(mesh, {
            "tokens": jax.ShapeDtypeStruct((1, 1), jnp.int32)})
        assert bsh1["tokens"].spec == P(None, None)
        print("PARTITION_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert "PARTITION_OK" in out.stdout, out.stdout + out.stderr


@pytest.mark.slow
def test_sharded_la_multidevice():
    """Distributed symv/gemm/cholesky/trsm on an 8-device subprocess mesh."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        jax.config.update("jax_enable_x64", True)
        import numpy as np
        from repro.dist.sharded_la import (dist_symv, dist_gemm, dist_gemm_rs,
                                           dist_cholesky, dist_trsm_left_t)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        n = 64
        key = jax.random.PRNGKey(0)
        M = jax.random.normal(key, (n, n), jnp.float64)
        A = 0.5 * (M + M.T)
        x = jax.random.normal(jax.random.fold_in(key, 1), (n,), jnp.float64)
        y = dist_symv(mesh, A, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(A @ x),
                                   rtol=1e-12)
        Bm = jax.random.normal(jax.random.fold_in(key, 2), (n, 16),
                               jnp.float64)
        np.testing.assert_allclose(np.asarray(dist_gemm(mesh, A, Bm)),
                                   np.asarray(A @ Bm), rtol=1e-11, atol=1e-11)
        np.testing.assert_allclose(np.asarray(dist_gemm_rs(mesh, A, Bm)),
                                   np.asarray(A @ Bm), rtol=1e-11, atol=1e-11)
        SPD = A @ A.T + n * jnp.eye(n)
        U = dist_cholesky(mesh, SPD)
        np.testing.assert_allclose(np.asarray(U.T @ U), np.asarray(SPD),
                                   rtol=1e-10, atol=1e-8)
        W = dist_trsm_left_t(mesh, U, Bm)
        np.testing.assert_allclose(np.asarray(U.T @ W), np.asarray(Bm),
                                   rtol=1e-10, atol=1e-8)
        print("SHARDED_LA_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert "SHARDED_LA_OK" in out.stdout, out.stdout + out.stderr[-3000:]


_TT_PARITY_TEMPLATE = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
    import jax, jax.numpy as jnp
    jax.config.update("jax_enable_x64", True)
    import numpy as np
    from repro.data.problems import md_like
    from repro.core import solve
    from repro.dist.eigensolver import solve_tt_distributed
    mesh = jax.make_mesh({mesh_shape}, ("data", "model"))
    prob = md_like({n})
    ref = solve(prob.A, prob.B, {s}, variant="TT", band_width={w})
    evals, X = solve_tt_distributed(mesh, prob.A, prob.B, {s},
                                    band_width={w})
    np.testing.assert_allclose(np.asarray(evals), np.asarray(ref.evals),
                               rtol=0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(evals),
                               np.asarray(prob.exact_evals[:{s}]),
                               rtol=1e-7, atol=1e-9)
    R = np.asarray(prob.A @ X - (prob.B @ X) * np.asarray(evals)[None, :])
    rel = np.linalg.norm(R) / np.linalg.norm(np.asarray(prob.A))
    assert rel < 1e-10, rel
    # the auto router must dispatch onto a distributed variant and agree
    res_auto = solve(prob.A, prob.B, {s}, variant="auto", mesh=mesh,
                     band_width={w})
    assert res_auto.info["variant"] in ("TT", "KE"), res_auto.info
    assert res_auto.info["router"]["n_devices"] == {ndev}
    np.testing.assert_allclose(np.asarray(res_auto.evals),
                               np.asarray(prob.exact_evals[:{s}]),
                               rtol=1e-6, atol=1e-8)
    print("DIST_TT_OK")
"""


def _run_tt_parity(ndev, mesh_shape, n, s, w):
    code = textwrap.dedent(_TT_PARITY_TEMPLATE.format(
        ndev=ndev, mesh_shape=mesh_shape, n=n, s=s, w=w))
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert "DIST_TT_OK" in out.stdout, out.stdout + out.stderr[-3000:]


def test_distributed_tt1_fused_sweep_two_device():
    """Fast lane: the fused one-program ``dist_reduce_to_band`` on a
    2-device (2, 1) mesh — data=2, so the row collectives are real —
    (a) is numerically at parity with the local
    ``reduce_to_band`` band, (b) satisfies the reduction invariants, and
    (c) issues O(1) host dispatches per sweep (the registry's
    ``TT1_FUSED_MAX_DISPATCHES``) — while the stepwise per-panel baseline
    pays O(n/w), proving the counter counts."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import jax, jax.numpy as jnp
        jax.config.update("jax_enable_x64", True)
        import numpy as np
        from repro.analysis.static_audit import (
            TT1_FUSED_MAX_DISPATCHES, TT1_STEPWISE_DISPATCHES_PER_PANEL)
        from repro.core.band_storage import unpack_band
        from repro.core.sbr import reduce_to_band
        from repro.dist import eigensolver as de
        # data=2: the row collectives (all_gather/psum) are real, not no-ops
        mesh = jax.make_mesh((2, 1), ("data", "model"))
        n, w = 32, 4
        M = jax.random.normal(jax.random.PRNGKey(3), (n, n), jnp.float64)
        C = 0.5 * (M + M.T)
        de.reset_dispatch_count()
        W, Q1 = de.dist_reduce_to_band(mesh, C, w)
        jax.block_until_ready((W, Q1))
        fused = de.dispatch_count()
        assert fused <= TT1_FUSED_MAX_DISPATCHES, fused
        Wl, Q1l = np.asarray(W), np.asarray(Q1)
        Wsym = 0.5 * (Wl + Wl.T)
        # invariants: orthogonal Q1, exact band mask, Q1^T C Q1 = W
        np.testing.assert_allclose(Q1l.T @ Q1l, np.eye(n), atol=1e-12)
        d = np.abs(np.arange(n)[:, None] - np.arange(n)[None, :])
        assert np.abs(np.where(d > w, Wl, 0.0)).max() == 0.0
        np.testing.assert_allclose(Q1l.T @ np.asarray(C) @ Q1l, Wsym,
                                   atol=1e-11)
        # numerical parity with the local fused sweep (same reflectors,
        # same SYR2K update form -> agreement far below the invariant tol)
        band = reduce_to_band(C, w=w)
        np.testing.assert_allclose(Wsym, np.asarray(unpack_band(band.Wb)),
                                   atol=1e-11)
        np.testing.assert_allclose(np.abs(Q1l), np.abs(np.asarray(band.Q1)),
                                   atol=1e-10)
        de.reset_dispatch_count()
        Ws, Q1s = de.dist_reduce_to_band_stepwise(mesh, C, w)
        jax.block_until_ready((Ws, Q1s))
        n_panels = len(range(0, n - w - 1, w))
        assert de.dispatch_count() >= (
            TT1_STEPWISE_DISPATCHES_PER_PANEL * n_panels), de.dispatch_count()
        np.testing.assert_allclose(np.asarray(Ws), Wsym, atol=1e-11)
        # odd n (not divisible by the 2 row shards): the identity-padding
        # path must stay one fused dispatch and match the local reduction
        n2 = 33
        M2 = jax.random.normal(jax.random.PRNGKey(4), (n2, n2), jnp.float64)
        C2 = 0.5 * (M2 + M2.T)
        de.reset_dispatch_count()
        W2, Q12 = de.dist_reduce_to_band(mesh, C2, w)
        jax.block_until_ready((W2, Q12))
        assert de.dispatch_count() <= TT1_FUSED_MAX_DISPATCHES, (
            de.dispatch_count())
        assert W2.shape == (n2, n2) and Q12.shape == (n2, n2)
        W2l, Q12l = np.asarray(W2), np.asarray(Q12)
        band2 = reduce_to_band(C2, w=w)
        np.testing.assert_allclose(0.5 * (W2l + W2l.T),
                                   np.asarray(unpack_band(band2.Wb)),
                                   atol=1e-11)
        np.testing.assert_allclose(Q12l.T @ Q12l, np.eye(n2), atol=1e-12)
        print("DIST_TT1_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert "DIST_TT1_OK" in out.stdout, out.stdout + out.stderr[-3000:]


def test_distributed_tt_parity_two_device():
    """Fast lane: the distributed two-stage (TT) pipeline on a 2-device
    (1, 2) mesh matches the local TT eigenvalues to 1e-6. (n kept small:
    the replicated bulge chase dominates subprocess time; the 8-device
    nightly run covers the larger shape.)"""
    _run_tt_parity(2, (1, 2), n=32, s=4, w=4)


_INVERT_PARITY_TEMPLATE = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax, jax.numpy as jnp
    jax.config.update("jax_enable_x64", True)
    import numpy as np
    from repro.data.problems import md_like
    from repro.core import solve
    from repro.core.residuals import accuracy_report
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    prob = md_like(48)  # A SPD: the inverse-pair trick is valid
    variant = {variant!r}
    ref = solve(prob.A, prob.B, 4, variant=variant, invert=True,
                band_width=4, max_restarts=300)
    res = solve(prob.A, prob.B, 4, variant=variant, invert=True,
                band_width=4, max_restarts=300, mesh=mesh)
    np.testing.assert_allclose(np.asarray(res.evals),
                               np.asarray(ref.evals), rtol=0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(res.evals),
                               np.asarray(prob.exact_evals[:4]),
                               rtol=1e-7, atol=1e-9)
    # the epilogue must hand back ORIGINAL-problem metrics:
    # unit-B-norm columns and a small generalized residual
    acc = accuracy_report(prob.A, prob.B, res.X, res.evals)
    assert float(acc.relative_residual) < 1e-9, variant
    colnorm = np.einsum("is,is->s", np.asarray(res.X),
                        np.asarray(prob.B @ res.X))
    np.testing.assert_allclose(colnorm, 1.0, rtol=1e-10)
    print("DIST_INVERT_OK")
"""


def _run_invert_parity(variant):
    """invert=True combined with mesh= dispatch: the distributed KE/TT
    paths return through ``_finalize``'s inverse-pair epilogue (1/lam,
    re-sort, b_normalize against the original B). Parity against the local
    variant on a 2-device mesh — previously untested."""
    code = textwrap.dedent(_INVERT_PARITY_TEMPLATE.format(variant=variant))
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert "DIST_INVERT_OK" in out.stdout, out.stdout + out.stderr[-3000:]


def test_distributed_invert_parity_two_device_ke():
    _run_invert_parity("KE")


@pytest.mark.slow
def test_distributed_invert_parity_two_device_tt():
    """TT variant of the invert parity check (the replicated bulge chase
    makes this the pricier half; nightly)."""
    _run_invert_parity("TT")


def test_distributed_ke_collective_and_dispatch_budget_two_device():
    """Communication-avoiding regression pins, fast lane (2 devices):

    1. The registered ``dist/ke_restart_program`` budget contract holds on
       both mesh orientations — at most 2 collectives per block step
       (psum + all_gather), an exact static total, zero dynamic whiles —
       and its StableHLO cross-reference stays within the published
       ``KE_HLO_*`` caps (the whole segment is one fori_loop, so the body
       appears once in the text). A regression to per-matvec or per-column
       communication would break the contract.
    2. The host issues at most ``ke_dispatch_budget(n_restart)`` dispatches
       for the whole Krylov stage (one fused program per restart + prep).
    3. The solve actually converges at the benchmark settings (invert +
       tol=1e-9) and matches the exact spectrum.
    """
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import jax, jax.numpy as jnp
        jax.config.update("jax_enable_x64", True)
        import numpy as np
        from repro.analysis.static_audit import (
            AuditSpec, KE_HLO_ALL_GATHER_MAX, KE_HLO_ALL_REDUCE_MAX,
            check_entry, get_entry, ke_dispatch_budget, register_all)
        from repro.data.problems import md_like
        from repro.dist import eigensolver as de

        spec = AuditSpec()                  # n=64, s=4, p=4, m=24
        n, s, p, m = spec.n, spec.s, spec.p, spec.m
        prob = md_like(n)
        for shape in ((1, 2), (2, 1)):
            mesh = jax.make_mesh(shape, ("data", "model"))
            # 1. the registered budget contract, on this orientation
            register_all(spec, mesh=mesh)
            rep = check_entry(get_entry("dist/ke_restart_program"))
            assert rep.ok, (shape, rep.violations)
            hlo = rep.profiles[0].hlo_counts
            assert hlo["stablehlo.all_reduce"] <= KE_HLO_ALL_REDUCE_MAX, hlo
            assert hlo["stablehlo.all_gather"] <= KE_HLO_ALL_GATHER_MAX, hlo
            # 2 + 3. dispatch budget and convergence at benchmark settings
            de.reset_dispatch_count()
            evals, X, info = de.solve_ke_distributed(
                mesh, prob.A, prob.B, s=s, m=m, p=p, tol=1e-9,
                filter_degree=8, invert=True, return_info=True)
            assert info["converged"], info
            assert info["fused"], info
            assert de.dispatch_count() <= ke_dispatch_budget(
                info["n_restart"]), (de.dispatch_count(), info)
            np.testing.assert_allclose(np.asarray(evals),
                                       np.asarray(prob.exact_evals[:s]),
                                       rtol=1e-8, atol=1e-10)
        print("DIST_KE_BUDGET_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert "DIST_KE_BUDGET_OK" in out.stdout, out.stdout + out.stderr[-3000:]


def test_distributed_tt3_spectrum_partition_two_device():
    """Fast lane: the spectrum-partitioned TT3 (``dist_tridiag_eig``) on a
    2-device mesh

    (a) matches the replicated 'batched' path — lam BITWISE, Z to 1e-12
        (the column-norm reduction may reassociate at ulp level on the
        narrow local slices) — for even and uneven (padded) index counts
        and shuffled ``ks``,
    (b) satisfies the registered ``dist/tt3_program`` contract at this
        shape — exactly ``tt3_dist_collectives(iters)`` static collectives
        (1 lam all_gather + one Z all_gather per refinement round) with the
        ``TT3_HLO_ALL_GATHER_MAX`` StableHLO cross-reference — and
    (c) drives ``solve_tt_distributed``: sharded vs replicated TT3 end to
        end, Z assembled from per-shard index slices, err <= 1e-10.
    """
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import jax, jax.numpy as jnp
        jax.config.update("jax_enable_x64", True)
        import numpy as np
        from repro.analysis.static_audit import (
            AuditSpec, TT3_HLO_ALL_GATHER_MAX, check_entry, get_entry,
            register_all, tt3_dist_collectives)
        from repro.core.tridiag_eig import eigh_tridiag_selected
        from repro.data.problems import md_like
        from repro.dist import eigensolver as de
        mesh = jax.make_mesh((2, 1), ("data", "model"))
        n = 48
        kd, ke = jax.random.split(jax.random.PRNGKey(0))
        d = jax.random.normal(kd, (n,), jnp.float64)
        e = jax.random.normal(ke, (n - 1,), jnp.float64)
        key = jax.random.PRNGKey(3)
        # (a) bitwise parity: even s, uneven s (pads in play), shuffled ks
        for ks in (jnp.arange(8), jnp.arange(7),
                   jnp.asarray([5, 1, 3, 0])):
            lam_d, Z_d = de.dist_tridiag_eig(mesh, d, e, ks, key)
            lam_r, Z_r = eigh_tridiag_selected(d, e, ks, key,
                                               method="batched")
            assert np.array_equal(np.asarray(lam_d), np.asarray(lam_r))
            assert np.abs(np.asarray(Z_d)
                          - np.asarray(Z_r)).max() <= 1e-12
        # (b) the registered collective contract at THIS shape: the lam
        # gather plus one Z gather per round, exactly — a regression to
        # per-shift or per-round-unrolled communication breaks the pin
        tt3_spec = AuditSpec(n=n, s=8)
        register_all(tt3_spec, mesh=mesh)
        rep = check_entry(get_entry("dist/tt3_program"))
        assert rep.ok, rep.violations
        assert rep.total_collectives == tt3_dist_collectives(
            tt3_spec.tt3_iters), rep.total_collectives
        hlo = rep.profiles[0].hlo_counts
        assert hlo["stablehlo.all_gather"] <= TT3_HLO_ALL_GATHER_MAX, hlo
        # (c) end to end: sharded vs replicated TT3 through the full
        # two-stage pipeline (s=3 exercises the uneven padding there too)
        prob = md_like(32)
        for s in (4, 3):
            evals_s, X_s, info_s = de.solve_tt_distributed(
                mesh, prob.A, prob.B, s, band_width=4, return_info=True)
            evals_r, X_r, info_r = de.solve_tt_distributed(
                mesh, prob.A, prob.B, s, band_width=4, return_info=True,
                shard_tt3=False)
            assert info_s["tt3_sharded"] and not info_r["tt3_sharded"]
            assert np.abs(np.asarray(evals_s)
                          - np.asarray(evals_r)).max() <= 1e-10
            assert np.abs(np.asarray(X_s) - np.asarray(X_r)).max() <= 1e-10
            np.testing.assert_allclose(np.asarray(evals_s),
                                       np.asarray(prob.exact_evals[:s]),
                                       rtol=1e-7, atol=1e-9)
        print("DIST_TT3_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert "DIST_TT3_OK" in out.stdout, out.stdout + out.stderr[-3000:]


@pytest.mark.slow
def test_distributed_tt_parity_eight_device():
    """The full 8-device (4, 2) mesh variant of the TT parity check (TT3
    spectrum-partitioned over all 8 devices, s=4 < 8 so padding is live)."""
    _run_tt_parity(8, (4, 2), n=64, s=4, w=8)


@pytest.mark.slow
def test_distributed_ke_pipeline_end_to_end():
    """The full distributed KE solve matches the exact spectrum (8 devices).

    Runs at the settings where the MD generator actually converges — the
    paper's inverse-pair trick + tol=1e-9 (the machine-eps default
    criterion is unreachable on this log-spaced spectrum, and the old
    retire-at-max_restarts configuration is exactly what the block
    rework stopped racing) — and asserts convergence, not just accuracy.
    """
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        jax.config.update("jax_enable_x64", True)
        import numpy as np
        from repro.data.problems import md_like
        from repro.dist.eigensolver import solve_ke_distributed
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        prob = md_like(64)
        evals, X, info = solve_ke_distributed(mesh, prob.A, prob.B, s=4,
                                              m=24, tol=1e-9,
                                              max_restarts=300,
                                              invert=True,
                                              return_info=True)
        assert info["converged"], info
        np.testing.assert_allclose(np.asarray(evals),
                                   np.asarray(prob.exact_evals[:4]),
                                   rtol=1e-8, atol=1e-10)
        # residual of the generalized problem
        R = np.asarray(prob.A @ X - (prob.B @ X) * np.asarray(evals)[None, :])
        rel = np.linalg.norm(R) / np.linalg.norm(np.asarray(prob.A))
        assert rel < 1e-8, rel
        print("DIST_KE_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert "DIST_KE_OK" in out.stdout, out.stdout + out.stderr[-3000:]
