"""Cross-variant accuracy harness — the paper's Table-3 metrics, enforced
uniformly over every variant x problem generator x spectrum end.

One shared tolerance table (TABLE3_TOLERANCES) governs all 16 cells; no
per-test ad-hoc tolerances. The metrics are exactly ``core.residuals``'s:

    relative_residual = ||A X - B X Lambda||_F / max(||A||_F, ||B||_F)
    b_orthogonality   = ||X^T B X - I||_F / ||B||_F

(the paper reports ~1e-15 in double precision; the table below leaves two
orders of headroom for the clustered DFT low end, uniformly).
"""
import numpy as np
import pytest

from repro.core import VARIANTS, accuracy_report, solve
from repro.data.problems import dft_like, md_like

# n shrunk from 96 (same spectra, same tolerances — the metrics are
# n-relative) to keep the 16-cell sweep inside the CI fast-lane budget
N, S = 64, 6

# the single shared Table-3 tolerance table — every cell below must meet it
TABLE3_TOLERANCES = {
    "relative_residual": 1e-12,
    "b_orthogonality": 1e-12,
}

PROBLEMS = {"md_like": md_like, "dft_like": dft_like}


def _heavy(variant, problem, which):
    """The clustered DFT low end is the paper's slow-Lanczos regime (Exp. 2's
    thousands of iterations): the Krylov cells there dominate the fast lane,
    so they run nightly behind the `slow` marker. Direct variants and every
    other spectrum end stay in the fast lane."""
    return (problem == "dft_like" and which == "smallest"
            and variant in ("KE", "KI"))


CELLS = [pytest.param(v, p, w,
                      marks=(pytest.mark.slow,) if _heavy(v, p, w) else (),
                      id=f"{w}-{p}-{v}")
         for v in VARIANTS for p in sorted(PROBLEMS)
         for w in ("smallest", "largest")]


@pytest.mark.parametrize("variant,problem,which", CELLS)
def test_table3_metrics(variant, problem, which):
    prob = PROBLEMS[problem](N)
    # the paper's MD methodology, not a tolerance tweak: Krylov variants
    # solve the inverse pair (valid — md_like's A is SPD) for the smallest
    # end, where the direct spectrum's relative gaps are tiny (Sec. 4.1)
    invert = (problem == "md_like" and variant in ("KE", "KI")
              and which == "smallest")
    res = solve(prob.A, prob.B, S, variant=variant, which=which,
                band_width=8, max_restarts=800, invert=invert)
    acc = accuracy_report(prob.A, prob.B, res.X, res.evals)
    metrics = {"relative_residual": float(acc.relative_residual),
               "b_orthogonality": float(acc.b_orthogonality)}
    for name, tol in TABLE3_TOLERANCES.items():
        assert metrics[name] <= tol, (
            f"{variant}/{problem}/{which}: {name}={metrics[name]:.3e} "
            f"exceeds the shared Table-3 tolerance {tol:.1e}")
    # the harness also pins the spectrum: eigenvalues must be the known
    # ground truth of the generator (ascending, correct end)
    exact = np.asarray(prob.exact_evals)
    want = exact[:S] if which == "smallest" else exact[-S:]
    np.testing.assert_allclose(np.asarray(res.evals), want,
                               rtol=1e-8, atol=1e-8)


def test_tolerance_table_is_shared():
    """Guard against per-test tolerance drift: the table is the only
    tolerance source and keeps the paper's two metrics, nothing else."""
    assert set(TABLE3_TOLERANCES) == {"relative_residual",
                                      "b_orthogonality"}
    assert all(0 < t <= 1e-9 for t in TABLE3_TOLERANCES.values())


# ---------------------------------------------------------------------------
# precision axis: the mixed/fast pipelines must pass the SAME Table-3
# tolerances as fp64 — that is the whole contract of the fp64 iterative
# refinement (core.refinement): demote the GEMM-heavy stages, then buy
# every digit back against the original pencil.
# ---------------------------------------------------------------------------

PRECISION_CELLS = [
    pytest.param(v, p, prec,
                 marks=(pytest.mark.slow,)
                 if _heavy(v, p, "smallest") else (),
                 id=f"{prec}-{p}-{v}")
    for v in VARIANTS for p in sorted(PROBLEMS)
    for prec in ("fp64", "mixed", "fast")]


@pytest.mark.parametrize("variant,problem,precision", PRECISION_CELLS)
def test_table3_metrics_precision(variant, problem, precision):
    prob = PROBLEMS[problem](N)
    invert = (problem == "md_like" and variant in ("KE", "KI"))
    res = solve(prob.A, prob.B, S, variant=variant, which="smallest",
                band_width=8, max_restarts=800, invert=invert,
                precision=precision)
    acc = accuracy_report(prob.A, prob.B, res.X, res.evals)
    metrics = {"relative_residual": float(acc.relative_residual),
               "b_orthogonality": float(acc.b_orthogonality)}
    for name, tol in TABLE3_TOLERANCES.items():
        assert metrics[name] <= tol, (
            f"{variant}/{problem}/{precision}: {name}={metrics[name]:.3e} "
            f"exceeds the shared Table-3 tolerance {tol:.1e}")
    if precision == "fp64":
        assert "refinement" not in res.info
    else:
        rinfo = res.info["refinement"]
        assert rinfo["converged"]
        # the refinement ran against the ORIGINAL fp64 pencil and stopped
        # at the Table-3 tolerance
        assert rinfo["tol"] <= TABLE3_TOLERANCES["relative_residual"]


def test_weak_typed_pencil_is_promoted_at_the_api_boundary():
    """Negative test of the weak-type recompile/precision hazard: a
    Python-scalar-born pencil (``jnp.full`` and friends carry
    ``weak_type=True``) must be promoted to committed fp64 at the
    ``solve`` / ``solve_batched`` boundary — identical results to the
    committed-dtype call, strong outputs."""
    import jax
    import jax.numpy as jnp

    from repro.core.batched import solve_batched
    from repro.core.precision import ensure_strong

    n, s = 16, 2
    ii = jnp.arange(n)
    # scalar-born SPD pencil: every constituent is a Python float, so the
    # weak_type flag survives the whole construction
    A_weak = jnp.full((n, n), 0.01).at[ii, ii].add(2.0)
    B_weak = jnp.full((n, n), 0.0).at[ii, ii].set(1.0)
    assert A_weak.weak_type and B_weak.weak_type      # the hazard is real

    prom = ensure_strong(A_weak)
    assert not prom.weak_type and prom.dtype == jnp.float64

    A_strong = jnp.asarray(np.asarray(A_weak))
    B_strong = jnp.asarray(np.asarray(B_weak))
    assert not A_strong.weak_type

    res_w = solve(A_weak, B_weak, s, variant="TD")
    res_s = solve(A_strong, B_strong, s, variant="TD")
    assert res_w.evals.dtype == jnp.float64 and not res_w.evals.weak_type
    assert not res_w.X.weak_type
    np.testing.assert_array_equal(np.asarray(res_w.evals),
                                  np.asarray(res_s.evals))

    key = jax.random.PRNGKey(0)
    bat_w = solve_batched(A_weak[None], B_weak[None], s, key=key)
    bat_s = solve_batched(A_strong[None], B_strong[None], s, key=key)
    assert bat_w.evals.dtype == jnp.float64 and not bat_w.evals.weak_type
    np.testing.assert_array_equal(np.asarray(bat_w.evals),
                                  np.asarray(bat_s.evals))


def test_refinement_converges_on_ill_conditioned_pencil():
    """Unit test of core.refinement alone: start from fp32-quality
    eigenpairs of a pencil with cond(B) ~ 1e8 and check the corrector
    iteration restores full fp64 accuracy."""
    import jax
    import jax.numpy as jnp

    from repro.core.refinement import refine_eigenpairs

    n, s = 64, 4
    key = jax.random.PRNGKey(42)
    kq, kb = jax.random.split(key)
    Q, _ = jnp.linalg.qr(jax.random.normal(kq, (n, n), jnp.float64))
    lam_a = jnp.logspace(-2.0, 2.0, n, dtype=jnp.float64)
    A = (Q * lam_a) @ Q.T
    # B SPD with an 8-decade spread: the fp32 Cholesky of this pencil
    # loses ~half the digits, which is exactly what refinement must fix
    Qb, _ = jnp.linalg.qr(jax.random.normal(kb, (n, n), jnp.float64))
    lam_b = jnp.logspace(-4.0, 4.0, n, dtype=jnp.float64)
    B = (Qb * lam_b) @ Qb.T

    # fp32-quality starting pairs: solve in fp32 and round-trip
    from repro.core import solve as _solve
    res32 = _solve(A, B, s, variant="TD", which="smallest",
                   precision="mixed", refine=False)
    lam0 = res32.evals
    X0 = res32.X.astype(jnp.float32).astype(jnp.float64)

    lam, X, info = refine_eigenpairs(A, B, lam0, X0, which="smallest",
                                     tol=1e-12, max_steps=60)
    assert info["converged"], info
    # trajectories start at the unrefined input and end below tolerance
    assert info["relative_residual"][-1] <= 1e-12
    assert info["b_orthogonality"][-1] <= 1e-12
    assert info["steps"] >= 1
    assert info["relative_residual"][-1] < info["relative_residual"][0]
