"""Cross-variant accuracy harness — the paper's Table-3 metrics, enforced
uniformly over every variant x problem generator x spectrum end.

One shared tolerance table (TABLE3_TOLERANCES) governs all 16 cells; no
per-test ad-hoc tolerances. The metrics are exactly ``core.residuals``'s:

    relative_residual = ||A X - B X Lambda||_F / max(||A||_F, ||B||_F)
    b_orthogonality   = ||X^T B X - I||_F / ||B||_F

(the paper reports ~1e-15 in double precision; the table below leaves two
orders of headroom for the clustered DFT low end, uniformly).
"""
import numpy as np
import pytest

from repro.core import VARIANTS, accuracy_report, solve
from repro.data.problems import dft_like, md_like

# n shrunk from 96 (same spectra, same tolerances — the metrics are
# n-relative) to keep the 16-cell sweep inside the CI fast-lane budget
N, S = 64, 6

# the single shared Table-3 tolerance table — every cell below must meet it
TABLE3_TOLERANCES = {
    "relative_residual": 1e-12,
    "b_orthogonality": 1e-12,
}

PROBLEMS = {"md_like": md_like, "dft_like": dft_like}


def _heavy(variant, problem, which):
    """The clustered DFT low end is the paper's slow-Lanczos regime (Exp. 2's
    thousands of iterations): the Krylov cells there dominate the fast lane,
    so they run nightly behind the `slow` marker. Direct variants and every
    other spectrum end stay in the fast lane."""
    return (problem == "dft_like" and which == "smallest"
            and variant in ("KE", "KI"))


CELLS = [pytest.param(v, p, w,
                      marks=(pytest.mark.slow,) if _heavy(v, p, w) else (),
                      id=f"{w}-{p}-{v}")
         for v in VARIANTS for p in sorted(PROBLEMS)
         for w in ("smallest", "largest")]


@pytest.mark.parametrize("variant,problem,which", CELLS)
def test_table3_metrics(variant, problem, which):
    prob = PROBLEMS[problem](N)
    # the paper's MD methodology, not a tolerance tweak: Krylov variants
    # solve the inverse pair (valid — md_like's A is SPD) for the smallest
    # end, where the direct spectrum's relative gaps are tiny (Sec. 4.1)
    invert = (problem == "md_like" and variant in ("KE", "KI")
              and which == "smallest")
    res = solve(prob.A, prob.B, S, variant=variant, which=which,
                band_width=8, max_restarts=800, invert=invert)
    acc = accuracy_report(prob.A, prob.B, res.X, res.evals)
    metrics = {"relative_residual": float(acc.relative_residual),
               "b_orthogonality": float(acc.b_orthogonality)}
    for name, tol in TABLE3_TOLERANCES.items():
        assert metrics[name] <= tol, (
            f"{variant}/{problem}/{which}: {name}={metrics[name]:.3e} "
            f"exceeds the shared Table-3 tolerance {tol:.1e}")
    # the harness also pins the spectrum: eigenvalues must be the known
    # ground truth of the generator (ascending, correct end)
    exact = np.asarray(prob.exact_evals)
    want = exact[:S] if which == "smallest" else exact[-S:]
    np.testing.assert_allclose(np.asarray(res.evals), want,
                               rtol=1e-8, atol=1e-8)


def test_tolerance_table_is_shared():
    """Guard against per-test tolerance drift: the table is the only
    tolerance source and keeps the paper's two metrics, nothing else."""
    assert set(TABLE3_TOLERANCES) == {"relative_residual",
                                      "b_orthogonality"}
    assert all(0 < t <= 1e-9 for t in TABLE3_TOLERANCES.values())
