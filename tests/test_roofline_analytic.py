"""Cross-check the analytic cost model against XLA cost_analysis on small
UNROLLED configs (no scan => XLA counts every op exactly once)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.analysis.analytic import fwd_flops
from repro.configs import smoke_config
from repro.models.model import forward, init_params


def _hlo_flops(cfg, B, S):
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    toks = jnp.zeros((B, S), jnp.int32)

    def f(params, toks):
        logits, _ = forward(params, toks, cfg, remat=False, unroll=True)
        return logits

    compiled = jax.jit(f).lower(params, toks).compile()
    from repro.analysis.roofline import cost_analysis_dict
    return float(cost_analysis_dict(compiled).get("flops", 0.0))


@pytest.mark.parametrize("arch", ["mistral-large-123b", "qwen1.5-32b"])
def test_analytic_flops_match_hlo_dense(arch):
    cfg = smoke_config(arch).scaled(dtype="float32")
    B, S = 2, 128
    hlo = _hlo_flops(cfg, B, S)
    # sequence scans don't exist in dense attention configs at S=128 (no
    # chunking), so the comparison is exact-ish; allow fusion slack.
    ana = fwd_flops(cfg, B, S)
    assert hlo > 0
    ratio = ana / hlo
    assert 0.5 < ratio < 2.0, (ana, hlo, ratio)


def test_analytic_flops_scale_with_seq():
    cfg = smoke_config("chameleon-34b")
    f1 = fwd_flops(cfg, 2, 256)
    f2 = fwd_flops(cfg, 2, 512)
    # attention is quadratic but projections linear: 2x seq => 2-4x flops
    assert 2.0 <= f2 / f1 <= 4.0


def test_decode_flops_much_smaller_than_prefill():
    from repro.analysis.analytic import cell_cost
    from repro.configs import get_config
    from repro.models.config import shape_by_name
    cfg = get_config("mistral-large-123b")
    dec = cell_cost(cfg, shape_by_name("decode_32k"), 256)
    pre = cell_cost(cfg, shape_by_name("prefill_32k"), 256)
    assert dec.flops < pre.flops / 1000.0
    # decode is never compute-bound
    assert dec.hbm_bytes / 819e9 > dec.flops / 197e12


def test_moe_active_vs_total_flops():
    from repro.analysis.analytic import fwd_flops
    from repro.configs import get_config
    cfg = get_config("arctic-480b")
    n_total = cfg.param_count()
    n_active = cfg.param_count(active_only=True)
    assert n_active < 0.2 * n_total  # 2-of-128 routing
    f = fwd_flops(cfg, 1, 1024)
    # flops track ACTIVE params (2*N_active*D), within attention/embed slack
    assert f < 2 * 2 * n_active * 1024 * 1.5
