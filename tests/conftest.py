import jax

# The eigensolver core targets LAPACK-grade accuracy (paper Tables 3/7 are
# ~1e-15): run the numeric tests in float64. Model smoke tests request their
# dtypes explicitly so this does not affect them.
# NOTE: do NOT set XLA_FLAGS / device counts here — the 512-device setup is
# exclusive to launch/dryrun.py (see system design); multi-device tests spawn
# subprocesses with their own XLA_FLAGS.
jax.config.update("jax_enable_x64", True)
