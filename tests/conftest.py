import json
import os
import subprocess
import sys

import jax
import pytest

# The eigensolver core targets LAPACK-grade accuracy (paper Tables 3/7 are
# ~1e-15): run the numeric tests in float64. Model smoke tests request their
# dtypes explicitly so this does not affect them.
# NOTE: do NOT set XLA_FLAGS / device counts here — the 512-device setup is
# exclusive to launch/dryrun.py (see system design); multi-device tests spawn
# subprocesses with their own XLA_FLAGS.
jax.config.update("jax_enable_x64", True)

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="session")
def audit_report():
    """The static program audit, run ONCE per session in a subprocess.

    A subprocess because the distributed contracts need forced host
    devices, which must be set via XLA_FLAGS before jax imports — exactly
    what this conftest must not do (see NOTE above). ``launch/audit.py``
    owns the early-device idiom; the payload here is its ``--json`` output
    (2 forced devices, quick lane, no artifact write).
    """
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.audit", "--quick", "--json",
         "-o", ""],
        capture_output=True, text=True, env=env, cwd=_ROOT)
    assert out.returncode in (0, 1), out.stdout[-2000:] + out.stderr[-2000:]
    return json.loads(out.stdout)


@pytest.fixture(scope="session")
def assert_program_budget(audit_report):
    """Enforce a registered budget contract in one line:

        entry = assert_program_budget("dist/tt3_program")

    Asserts the entry was audited (not skipped) and every contract check
    passed, then returns the entry's AUDIT payload (profiles included) for
    any further, test-specific assertions.
    """
    by_name = {e["name"]: e for e in audit_report["entries"]}

    def check(name: str) -> dict:
        assert name in by_name, (name, sorted(by_name))
        entry = by_name[name]
        assert not entry["skipped"], f"{name}: skipped (no mesh?)"
        assert entry["ok"], (name, entry["violations"])
        return entry

    return check
