"""Cost-model variant router: golden decision table + auto-dispatch sweep."""
import json

import numpy as np
import pytest

from repro.analysis.variant_model import (DISTRIBUTED_VARIANTS, MachineParams,
                                          VARIANTS, choose_variant,
                                          estimate_lanczos_iters,
                                          estimate_lanczos_restarts,
                                          predict_stage_times, stage_costs)
from repro.core import solve
from repro.data.problems import dft_like, md_like

# ------------------------------------------------- golden decision table --
# Frozen over a fixed (n, s, mesh, clustered) grid with the default
# (multicore) MachineParams. The entries encode the paper's conclusions:
# KE wins the MD regime (s << n, separated spectrum, moderate iterations),
# the two-stage reduction wins the clustered-DFT regime and large s, and a
# mesh narrows the race to the two distributed pipelines.
GOLDEN = [
    # (n,     s,    mesh_shape, clustered) -> variant
    ((9997, 100, None, False), "KE"),     # paper Exp. 1 (MD/iMod)
    ((9997, 100, None, True), "TT"),
    ((17243, 448, None, False), "TT"),    # paper Exp. 2 (DFT/FLEUR)
    ((17243, 448, None, True), "TT"),
    # knife-edge cell: since the model charges TT4 for replaying the TT2
    # rotation stream over the Ritz slab (the cost the lazy-Q2 chase
    # actually pays), KE edges out TT here by <1%
    ((512, 8, None, False), "KE"),
    # few iterations at moderate n: skipping GS2 (KI) beats paying 2n^3
    # to make the matvec cheaper (KE)
    ((4096, 32, None, False), "KI"),
    ((4096, 512, None, False), "TT"),     # s no longer << n
    ((2048, 2000, None, False), "TT"),
    ((128, 4, None, False), "TT"),
    ((9997, 100, (4, 2), False), "KE"),
    ((9997, 100, (4, 2), True), "TT"),
    ((17243, 448, (4, 2), False), "TT"),
    ((512, 8, (4, 2), False), "KE"),
    # the BENCH_variant_race config (n=128, s=4, 8 host devices): TT on
    # both generators — at this tiny n the 2-dispatch fused TT1 sweep wins
    # on raw roofline. The old rationale ("KE pays ~3 dispatches x 300
    # restarts") is gone: the fused per-restart program pays restarts + 2
    # dispatches and the block matvec 2 collectives per block step, but a
    # 128x128 pencil is still cheaper to reduce outright.
    ((128, 4, (4, 2), False), "TT"),
    ((128, 4, (4, 2), True), "TT"),
    # block-KE entries (optional 5th tuple element = choose_variant kwargs):
    # with p=4 dividing the collective-latency term and a degree-16
    # Chebyshev start filter cutting the clustered iteration estimate to
    # ~1/3, the Krylov side wins the clustered s << n regime it used to
    # auto-lose — the headline flip of the communication-avoiding rework
    ((17243, 100, (4, 2), True, {"krylov_block": 4, "filter_degree": 16}),
     "KE"),
    ((17243, 100, None, True, {"krylov_block": 4, "filter_degree": 16}),
     "KE"),
]


def _golden_args(args):
    n, s, mesh_shape, clustered = args[:4]
    kw = args[4] if len(args) > 4 else {}
    return n, s, mesh_shape, clustered, kw


@pytest.mark.parametrize("args,expected", GOLDEN,
                         ids=[f"n{a[0]}_s{a[1]}_mesh{a[2]}_cl{a[3]}"
                              + ("_blk" if len(a) > 4 else "")
                              for a, _ in GOLDEN])
def test_golden_decision_table(args, expected):
    n, s, mesh_shape, clustered, kw = _golden_args(args)
    choice = choose_variant(n, s, mesh_shape=mesh_shape, clustered=clustered,
                            **kw)
    assert choice.variant == expected, choice.table


def test_choice_invariants():
    for args, _ in GOLDEN:
        n, s, mesh_shape, clustered, kw = _golden_args(args)
        c = choose_variant(n, s, mesh_shape=mesh_shape, clustered=clustered,
                           **kw)
        allowed = (DISTRIBUTED_VARIANTS
                   if mesh_shape and np.prod(mesh_shape) > 1 else VARIANTS)
        assert set(c.table) == set(allowed)
        assert c.variant in c.table
        assert c.predicted_s == min(c.table.values())
        # the decision payload must be JSON-clean (it rides in solve().info)
        json.dumps(c.as_json_dict())


def test_model_reflects_blas_levels():
    """The structural claims behind the router: TD1 is memory-bound at any
    bandwidth, TT1 turns compute-bound once the band is wide enough (the
    arithmetic intensity of the trailing update grows with w — the paper
    runs w=32), and TT does more flops than TD."""
    mach = MachineParams()
    n, s = 8192, 64
    td = stage_costs("TD", n, s, machine=mach)
    tt = stage_costs("TT", n, s, band_width=32, machine=mach)
    assert tt["TT1"].flops > td["TD1"].flops
    # roofline terms: TD1 time is set by bytes, TT1 (w=32) by flops
    assert td["TD1"].bytes / mach.mem_bw > td["TD1"].flops / mach.peak_flops
    assert tt["TT1"].bytes / mach.mem_bw < tt["TT1"].flops / mach.peak_flops
    # intensity grows with w: halving the band doubles the byte traffic
    tt8 = stage_costs("TT", n, s, band_width=8, machine=mach)
    assert tt8["TT1"].bytes > tt["TT1"].bytes
    # and the router's consequence: TT beats TD at either bandwidth
    t_td = predict_stage_times("TD", n, s, machine=mach)["Tot."]
    for w in (8, 32):
        t_tt = predict_stage_times("TT", n, s, band_width=w,
                                   machine=mach)["Tot."]
        assert t_tt < t_td


def test_iteration_estimate_monotone():
    base = estimate_lanczos_iters(4096, 32)
    clustered = estimate_lanczos_iters(4096, 32, clustered=True)
    assert clustered > base
    assert estimate_lanczos_iters(4096, 128) >= base


def test_block_and_filter_knobs_move_ke():
    """The communication-avoiding knobs do what the model claims: raising
    the Lanczos block size p divides the collective count (2 per p-column
    block step) without inflating the matvec work proportionally, and a
    Chebyshev start filter cuts the clustered-spectrum iteration estimate.
    Dispatches follow the fused per-restart program: restarts + 2."""
    n, s = 17243, 100
    ke1 = stage_costs("KE", n, s, clustered=True)["KE_iter"]
    ke4 = stage_costs("KE", n, s, clustered=True, p=4)["KE_iter"]
    assert ke4.collectives < 0.6 * ke1.collectives
    assert ke4.flops < 1.5 * ke1.flops
    it_plain = estimate_lanczos_iters(n, s, clustered=True)
    it_filt = estimate_lanczos_iters(n, s, clustered=True, filter_degree=16)
    assert it_filt < it_plain
    # dispatch count is restart-shaped, not matvec-shaped
    ke_known = stage_costs("KE", 128, 4, m=48, n_iter=6626)["KE_iter"]
    assert ke_known.dispatches == pytest.approx(
        2 + estimate_lanczos_restarts(6626, 4, 48))


def test_more_devices_never_slower():
    for v in ("TT", "KE"):
        t1 = predict_stage_times(v, 8192, 64, mesh_shape=(1, 1))["Tot."]
        t8 = predict_stage_times(v, 8192, 64, mesh_shape=(4, 2))["Tot."]
        assert t8 < t1


# ------------------------------------------------------- auto dispatch ----

AUTO_GRID = [(md_like, 64, 4, "smallest"), (md_like, 48, 3, "largest"),
             (dft_like, 64, 4, "largest")]


@pytest.mark.parametrize("gen,n,s,which", AUTO_GRID,
                         ids=[f"{g.__name__}_n{n}_s{s}_{w}"
                              for g, n, s, w in AUTO_GRID])
def test_auto_matches_explicit(gen, n, s, which):
    """variant='auto' never raises and returns the same eigenvalues as the
    explicitly-chosen variant."""
    prob = gen(n)
    res_auto = solve(prob.A, prob.B, s, variant="auto", which=which)
    picked = res_auto.info["variant"]
    assert picked in VARIANTS
    assert res_auto.info["router"]["variant"] == picked
    res_explicit = solve(prob.A, prob.B, s, variant=picked, which=which)
    np.testing.assert_allclose(np.asarray(res_auto.evals),
                               np.asarray(res_explicit.evals),
                               rtol=1e-12, atol=1e-12)


# --------------------------------------- measurement-calibrated machine ---

def _race_artifact_path():
    import os
    return os.path.join(os.path.dirname(__file__), "..", "artifacts",
                        "BENCH_variant_race.json")


def test_from_artifact_returns_calibrated_params():
    mach = MachineParams.from_artifact(_race_artifact_path())
    base = MachineParams()
    assert mach.peak_flops > 0 and mach.mem_bw > 0
    # the host-mesh measurements are orders of magnitude off the modeled
    # multicore rates; calibration must actually move the params
    assert mach.peak_flops != base.peak_flops
    assert mach.dtype_bytes == base.dtype_bytes
    # the host mesh pays O(ms) per shard_map dispatch, and the artifact's
    # 300-restart KE run is dispatch-dominated: the fit must attribute a
    # strictly positive (and plausibly-sized) per-dispatch latency
    assert 1e-5 < mach.t_dispatch < 1.0, mach.t_dispatch


def test_dispatch_term_separates_ke_from_tt():
    """The structural claim behind t_dispatch: at the race config the
    Krylov path issues ~2 orders of magnitude more dispatches than the
    fused one-program TT pipeline, so a millisecond-scale t_dispatch moves
    KE's predicted total by seconds while TT's barely moves."""
    n, s, m = 128, 4, 48
    n_iter = 6626   # the race artifact's measured matvec count (300 restarts)
    ke = stage_costs("KE", n, s, m=m, n_iter=n_iter)
    tt = stage_costs("TT", n, s, band_width=8)
    d_ke = sum(c.dispatches for c in ke.values())
    d_tt = sum(c.dispatches for c in tt.values())
    assert d_tt <= 10, d_tt                      # fused pipelines: O(1) each
    assert d_ke >= 10 * d_tt, (d_ke, d_tt)       # restart loop dominates
    mach = MachineParams(t_dispatch=5e-3)
    base = MachineParams()
    for costs, d_total in ((ke, d_ke), (tt, d_tt)):
        tot = sum(c.seconds(mach, 8) for c in costs.values())
        tot0 = sum(c.seconds(base, 8) for c in costs.values())
        np.testing.assert_allclose(tot - tot0, d_total * 5e-3, rtol=1e-9)


def test_calibrated_ordering_matches_measured():
    """The router's predicted TT-vs-KE ordering under the calibrated
    machine must agree with the measured ordering in the race artifact —
    whenever the measurement itself resolves an ordering (races decided by
    less than 20% on a dispatch-dominated host mesh are ties; asserting an
    order there would test noise). Always asserted: calibration pulls every
    predicted total to within 2 orders of magnitude of its measurement —
    the uncalibrated model sits ~10^6 off (19us predicted vs 16s measured
    was this issue's headline gap), so this pins the fit doing real work."""
    path = _race_artifact_path()
    with open(path) as f:
        art = json.load(f)
    mach = MachineParams.from_artifact(path)
    base = MachineParams()
    n, s = art["n"], art["s"]
    mesh_shape = (art["n_devices"],)
    for race in art["races"]:
        measured = {r["variant"]: r["wall_s_median"] for r in race["measured"]}
        n_iter = next((r["n_matvec"] for r in race["measured"]
                       if "n_matvec" in r), None)
        w = next((r["band_width"] for r in race["measured"]
                  if "band_width" in r), 8)
        pred, pred_base = {}, {}
        for v in measured:
            kw = {"n_iter": n_iter} if v in ("KE", "KI") else {}
            kw.update(mesh_shape=mesh_shape, band_width=w)
            pred[v] = predict_stage_times(v, n, s, machine=mach,
                                          **kw)["Tot."]
            pred_base[v] = predict_stage_times(v, n, s, machine=base,
                                               **kw)["Tot."]
        for v, t_meas in measured.items():
            ratio = pred[v] / t_meas
            base_ratio = pred_base[v] / t_meas
            assert 1e-2 <= ratio <= 1e2, (race["problem"], v, pred, measured)
            # strictly closer than the uncalibrated model, which is off
            # by orders of magnitude on the host mesh
            assert abs(np.log10(ratio)) < abs(np.log10(base_ratio))
        t_sorted = sorted(measured.values())
        if t_sorted[0] < 0.8 * t_sorted[1]:   # ordering is resolvable
            meas_order = sorted(measured, key=measured.get)
            pred_order = sorted(pred, key=pred.get)
            assert pred_order == meas_order, (race["problem"], pred,
                                              measured)


def test_calibrated_router_picks_converged_winner():
    """End-to-end router regression against the regenerated artifact:
    ``choose_variant`` under the artifact-calibrated machine must pick the
    converged-aware ``measured_winner`` the race recorded (an unconverged
    KE is annotated and ineligible no matter its wall clock — the
    satellite fix this PR; the fused TT1 makes TT the winner outright)."""
    path = _race_artifact_path()
    with open(path) as f:
        art = json.load(f)
    mach = MachineParams.from_artifact(path)
    assert art["races"], "artifact has no races"
    for race in art["races"]:
        assert "unconverged" in race, "race missing the converged annotation"
        n_iter = next((r["n_matvec"] for r in race["measured"]
                       if "n_matvec" in r), None)
        w = next((r["band_width"] for r in race["measured"]
                  if "band_width" in r), 8)
        choice = choose_variant(art["n"], art["s"], band_width=w,
                                n_iter=n_iter, machine=mach,
                                mesh_shape=(art["n_devices"],),
                                allow=("TT", "KE"))
        assert choice.variant == race["measured_winner"], (
            race["problem"], choice.table, race["measured_winner"])
