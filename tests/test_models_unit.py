"""Model-layer unit/property tests: chunked-vs-full equivalences, masks,
RoPE, MoE routing invariants, recurrent-state consistency."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.models.attention import (_causal_window_mask, _sdpa, _sdpa_chunked,
                                    apply_rope, rope_angles)
from repro.models.config import ModelConfig
from repro.models.layers import chunked_scan
from repro.models.moe import init_moe, moe_ffn

CFG = smoke_config("mistral-large-123b")


def _qkv(key, B, S, H, hd, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, H, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, H, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("S,chunk,window", [(64, 16, None), (128, 32, 24),
                                            (96, 32, None)])
def test_chunked_attention_equals_full(S, chunk, window):
    import repro.models.attention as attn_mod
    B, H, hd = 2, 4, 16
    q, k, v = _qkv(jax.random.PRNGKey(0), B, S, H, hd)
    mask = _causal_window_mask(S, S, window)
    full = _sdpa(q, k, v, mask, CFG)
    old = attn_mod._CHUNK_Q
    try:
        chunked = _sdpa_chunked(q, k, v, CFG, causal=True, window=window,
                                chunk=chunk)
    finally:
        attn_mod._CHUNK_Q = old
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               rtol=2e-5, atol=2e-5)


def test_causal_window_mask_semantics():
    m = np.asarray(_causal_window_mask(6, 6, window=3))
    for i in range(6):
        for j in range(6):
            expect = (j <= i) and (j > i - 3)
            assert m[i, j] == expect, (i, j)


def test_rope_preserves_norm_and_relativity():
    hd = 32
    pos = jnp.arange(16)[None, :]
    cos, sin = rope_angles(pos, hd, 10_000.0)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 2, hd))
    y = apply_rope(x, cos, sin)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = jax.random.normal(jax.random.PRNGKey(2), (hd,))
    k = jax.random.normal(jax.random.PRNGKey(3), (hd,))

    def dot_at(p, d):
        cos1, sin1 = rope_angles(jnp.asarray([p]), hd, 10_000.0)
        cos2, sin2 = rope_angles(jnp.asarray([p + d]), hd, 10_000.0)
        qr = apply_rope(q[None, None, None, :], cos1[None], sin1[None])
        kr = apply_rope(k[None, None, None, :], cos2[None], sin2[None])
        return float(jnp.sum(qr * kr))

    np.testing.assert_allclose(dot_at(0, 5), dot_at(7, 5), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("S,chunk,seed", [
    (32, 8, 0), (32, 32, 11), (64, 16, 222), (64, 8, 3_333),
    (128, 32, 44_444), (128, 16, 2**20), (64, 32, 7), (32, 16, 99),
    (128, 8, 555_555), (64, 16, 1_048_575),
])
def test_chunked_scan_equals_scan(S, chunk, seed):
    key = jax.random.PRNGKey(seed)
    xs = jax.random.normal(key, (S, 4))

    def step(c, x):
        c = 0.9 * c + x
        return c, c * 2.0

    c_ref, ys_ref = jax.lax.scan(step, jnp.zeros((4,)), xs)
    c_got, ys_got = chunked_scan(step, jnp.zeros((4,)), xs, chunk=chunk)
    np.testing.assert_allclose(np.asarray(c_got), np.asarray(c_ref),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(ys_got), np.asarray(ys_ref),
                               rtol=1e-6)


def test_chunked_scan_gradients_match():
    xs = jax.random.normal(jax.random.PRNGKey(7), (64, 3))

    def step(c, x):
        c = jnp.tanh(0.5 * c + x)
        return c, c.sum()

    def loss_plain(xs):
        _, ys = jax.lax.scan(step, jnp.zeros((3,)), xs)
        return ys.sum()

    def loss_chunked(xs):
        _, ys = chunked_scan(step, jnp.zeros((3,)), xs, chunk=16)
        return ys.sum()

    g1 = jax.grad(loss_plain)(xs)
    g2 = jax.grad(loss_chunked)(xs)
    np.testing.assert_allclose(np.asarray(g2), np.asarray(g1), rtol=1e-5,
                               atol=1e-6)


# ------------------------------------------------------------------- MoE --

def _moe_cfg(**kw):
    base = dict(name="t", n_layers=1, d_model=32, n_heads=4, n_kv_heads=4,
                head_dim=8, d_ff=64, vocab_size=64, n_experts=8,
                experts_per_token=2, dtype="float32",
                param_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def test_moe_no_drop_processes_every_token():
    cfg = _moe_cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    out, aux = moe_ffn(p, x, cfg, no_drop=True)
    assert out.shape == x.shape
    # every token must receive a nonzero expert mix (no silent drops)
    norms = jnp.linalg.norm(out.reshape(-1, 32), axis=-1)
    assert bool(jnp.all(norms > 0)), norms


def test_moe_aux_loss_balanced_lower_bound():
    """Switch aux loss is minimized (=1) under perfectly uniform routing."""
    cfg = _moe_cfg()
    p = init_moe(jax.random.PRNGKey(2), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 16, 32))
    _, aux = moe_ffn(p, x, cfg, no_drop=True)
    assert float(aux) >= 0.99  # E * sum(f_e * p_e) >= 1 with equality iff uniform


def test_moe_capacity_drops_are_bounded():
    cfg = _moe_cfg(capacity_factor=1.0)
    p = init_moe(jax.random.PRNGKey(4), cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 16, 32))
    out, _ = moe_ffn(p, x, cfg, no_drop=False)
    dropped = float(jnp.mean(
        (jnp.linalg.norm(out.reshape(-1, 32), axis=-1) == 0)))
    assert dropped < 0.9  # sanity: capacity 1.0 should keep most tokens


def test_shared_and_dense_residual_paths():
    cfg = _moe_cfg(n_shared_experts=2, moe_dense_residual=True)
    p = init_moe(jax.random.PRNGKey(6), cfg)
    assert "shared" in p and "dense" in p
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 4, 32))
    out, _ = moe_ffn(p, x, cfg, no_drop=True)
    assert bool(jnp.isfinite(out).all())
