"""Failure containment: chaos suite + the resilience satellites.

Every injected fault must end in a documented recovery (a rung in
``info['recovery']``) or a diagnosed ``SolverError`` — never a silent
NaN eigenpair. The fault harness is ``repro.resilience.faults``
(seeded, deterministic); the ladder is ``repro.resilience.recovery``.

Fast-lane tests cover the adversarial-pencil regressions, the checkpoint
round-trip and the straggler/elastic compose; ``-m chaos`` (the nightly
chaos lane) additionally selects the fault-injection tests; the
multi-device preemption drill is ``slow`` (subprocess with forced host
devices).
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import solve, solve_batched
from repro.data.problems import md_like
from repro.resilience import SolverError, cholesky_shift_taus
from repro.resilience import faults
from repro.resilience.faults import (ForceNonconverge, NanPoison, inject,
                                     near_breakdown_pencil, nonspd_pencil,
                                     slow_then_lost_trace)
from repro.serve.eigen_engine import EigenEngine

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

N, S = 32, 3

VARIANTS = ("TD", "TT", "KE", "KI")
PRECISIONS = ("fp64", "mixed", "fast")


# --------------------------------------------------------------------------
# satellite 1: adversarial pencils (regression, fast lane)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("variant", VARIANTS)
@pytest.mark.parametrize("precision", PRECISIONS)
def test_nonspd_b_raises_diagnosed(variant, precision):
    """Indefinite B (min eig ~ -0.1, beyond every shift rung): every
    variant and precision must raise the diagnosed SolverError, with the
    exhausted shift ladder on record."""
    A, B = nonspd_pencil(N)
    with pytest.raises(SolverError) as exc:
        solve(jnp.asarray(A), jnp.asarray(B), S, variant=variant,
              precision=precision, on_failure="warn")
    d = exc.value.diagnosis
    assert d["stage"] == "GS1"
    assert d["reason"] == "cholesky_breakdown"
    assert d["hint"]
    # one failed rung per shift tau, all on the trail
    shift_rungs = [r for r in d["recovery"]
                   if r["action"] == "cholesky_shift"]
    assert len(shift_rungs) == len(cholesky_shift_taus())
    assert all(r["outcome"] == "failed" for r in shift_rungs)
    json.dumps(d)                                  # diagnosis is JSON-clean


@pytest.mark.parametrize("variant", ["TD", "TT"])
def test_roundoff_indefinite_recovers_via_shift(variant):
    """B with a tiny negative eigenvalue (-1e-8): GS1 breaks down, the
    1e-6 relative shift rung rescues it, and the rung + shift land in
    info — recovery, not silence."""
    A, B = nonspd_pencil(N, min_eig=-1e-8)
    res = solve(jnp.asarray(A), jnp.asarray(B), S, variant=variant,
                on_failure="warn")
    assert np.all(np.isfinite(np.asarray(res.evals)))
    assert np.all(np.isfinite(np.asarray(res.X)))
    assert res.info["health"]["healthy"] is True
    assert res.info["gs1_shift"] > 0.0
    rungs = [r for r in res.info["recovery"]
             if r["action"] == "cholesky_shift"]
    assert rungs and rungs[-1]["outcome"] == "recovered"


@pytest.mark.parametrize("variant", VARIANTS)
@pytest.mark.parametrize("precision", PRECISIONS)
def test_near_breakdown_no_silent_nan(variant, precision):
    """cond(B) ~ 1e10: whatever happens — clean solve, shift rescue or a
    diagnosed failure — the caller never sees a silent NaN eigenpair."""
    A, B = near_breakdown_pencil(N)
    try:
        res = solve(jnp.asarray(A), jnp.asarray(B), S, variant=variant,
                    precision=precision, on_failure="warn",
                    max_restarts=80)
    except SolverError as err:
        assert err.diagnosis["reason"] in (
            "cholesky_breakdown", "nonfinite_stage", "nonfinite_output")
        return
    assert np.all(np.isfinite(np.asarray(res.evals)))
    assert np.all(np.isfinite(np.asarray(res.X)))
    assert "health" in res.info and "recovery" in res.info


# --------------------------------------------------------------------------
# chaos: stage-targeted NaN poisoning
# --------------------------------------------------------------------------

@pytest.mark.chaos
@pytest.mark.parametrize("stage,kwargs", [
    ("GS1", dict(variant="TD")),
    ("GS2", dict(variant="TD")),
    ("TD1", dict(variant="TD")),
    ("TT1", dict(variant="TT")),
    ("KE_iter", dict(variant="KE", invert=True)),
    ("KI_iter", dict(variant="KI", invert=True)),
])
def test_persistent_poison_is_diagnosed(stage, kwargs):
    """A persistent NaN fault at any stage ends in SolverError naming a
    stage at-or-upstream-of the sentinel that caught it."""
    prob = md_like(N)
    with inject(NanPoison(stage)):
        with pytest.raises(SolverError) as exc:
            solve(prob.A, prob.B, S, on_failure="warn", **kwargs)
    d = exc.value.diagnosis
    assert d["reason"] == "nonfinite_stage"
    assert d["stage"] == stage
    assert d.get("health", {}).get("healthy") is False
    assert d["health"]["first_unhealthy_stage"] == stage


@pytest.mark.chaos
def test_transient_poison_retried_under_recover():
    """once=True models a transient corruption: the recover ladder's
    retry rung re-runs with a fresh key and succeeds."""
    prob = md_like(N)
    with inject(NanPoison("GS2", once=True)):
        res = solve(prob.A, prob.B, S, variant="TD", on_failure="recover")
    assert res.info["health"]["healthy"] is True
    retries = [r for r in res.info["recovery"]
               if r["action"] == "transient_retry"]
    assert retries and retries[-1]["outcome"] == "recovered"
    np.testing.assert_allclose(np.asarray(res.evals),
                               np.asarray(md_like(N).exact_evals[:S]),
                               rtol=1e-7, atol=1e-9)


@pytest.mark.chaos
def test_persistent_poison_exhausts_retries():
    """The same fault, persistent: retries burn out and the error keeps
    the full trail (bounded ladder, no infinite retry loop)."""
    prob = md_like(N)
    with inject(NanPoison("GS2")):
        with pytest.raises(SolverError) as exc:
            solve(prob.A, prob.B, S, variant="TD", on_failure="recover",
                  max_retries=2)
    trail = exc.value.diagnosis["recovery"]
    assert sum(1 for r in trail
               if r["action"] == "transient_retry") == 2


# --------------------------------------------------------------------------
# chaos: forced nonconvergence -> escalate -> TT fallback
# --------------------------------------------------------------------------

@pytest.mark.chaos
def test_nonconvergence_ladder_falls_back_to_tt():
    prob = md_like(N)
    with inject(ForceNonconverge()):
        res = solve(prob.A, prob.B, S, variant="KE", invert=True,
                    on_failure="recover")
    actions = [r["action"] for r in res.info["recovery"]]
    assert "escalate_krylov" in actions
    assert "fallback_variant" in actions
    assert res.info["variant"] == "TT"
    assert res.info.get("converged", True)   # direct TT: no Krylov budget
    np.testing.assert_allclose(np.asarray(res.evals),
                               np.asarray(prob.exact_evals[:S]),
                               rtol=1e-7, atol=1e-9)


@pytest.mark.chaos
def test_nonconvergence_warn_mode_retires_with_warning():
    prob = md_like(N)
    with inject(ForceNonconverge()):
        res = solve(prob.A, prob.B, S, variant="KE", invert=True,
                    on_failure="warn")
    assert not res.info["converged"]
    assert any("UNCONVERGED" in w for w in res.info["warnings"])
    assert res.info["recovery"] == []          # warn never climbs the ladder


# --------------------------------------------------------------------------
# chaos: serving-engine quarantine + dead-letter
# --------------------------------------------------------------------------

@pytest.mark.chaos
def test_engine_quarantines_and_recovers_unconverged_lanes():
    """Lanes that miss the bucket's restart budget are retried
    individually up the ladder and retire healthy."""
    probs = [md_like(N, key=jax.random.PRNGKey(900 + i)) for i in range(2)]
    eng = EigenEngine(slots=2, bucket_shapes=[N], variant="KE",
                      max_restarts=1, on_failure="recover")
    uids = {eng.submit(p.A, p.B, S): p for p in probs}
    done = eng.run_until_drained()
    assert len(done) == len(probs) and not eng.dead_letters
    summary = eng.summary()
    assert summary["quarantined"] == len(probs)
    for req in done:
        assert req.info["path"] == "quarantine"
        assert req.info["converged"]
        assert req.info["health"]["healthy"] is True
        p = uids[req.uid]
        np.testing.assert_allclose(req.evals,
                                   np.asarray(p.exact_evals[:S]),
                                   rtol=1e-7, atol=1e-9)


@pytest.mark.chaos
def test_engine_dead_letters_unrecoverable_lane():
    """A non-SPD pencil poisons its bucket lane; the quarantine retries
    end in a dead letter carrying the diagnosis, the healthy lane
    retires normally — no silent drops either way."""
    good = md_like(N, key=jax.random.PRNGKey(31))
    A_bad, B_bad = nonspd_pencil(N)
    eng = EigenEngine(slots=2, bucket_shapes=[N], variant="TD",
                      on_failure="recover", max_retries=1)
    uid_good = eng.submit(good.A, good.B, S)
    uid_bad = eng.submit(jnp.asarray(A_bad), jnp.asarray(B_bad), S)
    done = eng.run_until_drained()
    assert {r.uid for r in done} == {uid_good}
    assert [r.uid for r in eng.dead_letters] == [uid_bad]
    dead = eng.dead_letters[0]
    assert dead.info["path"] == "dead_letter"
    assert dead.info["health"]["healthy"] is False
    assert dead.info["dead_letter"]["reason"] == "cholesky_breakdown"
    json.dumps(dead.info)
    # the no-silent-drop invariant, stated as the summary reports it
    summary = eng.summary()
    assert summary["dead_letter_uids"] == [uid_bad]
    assert summary["requests"] == 2


@pytest.mark.chaos
def test_batched_surfaces_unhealthy_pencils():
    """solve_batched itself (no engine): a poisoned pencil in the stack
    flips its per-pencil healthy flag and the batch-level warning."""
    probs = [md_like(N, key=jax.random.PRNGKey(70 + i)) for i in range(3)]
    A = jnp.stack([p.A for p in probs])
    B_bad = np.asarray(probs[1].B).copy()
    B_bad[0, 0] = np.nan
    B = jnp.stack([probs[0].B, jnp.asarray(B_bad), probs[2].B])
    res = solve_batched(A, B, S, variant="TD")
    healthy = np.asarray(res.healthy)
    assert not healthy[1] and healthy[0] and healthy[2]
    assert res.info["n_unhealthy"] == 1
    assert any("non-finite" in w.lower() for w in res.info["warnings"])


# --------------------------------------------------------------------------
# satellite 3: orphaned robustness modules, wired
# --------------------------------------------------------------------------

def test_checkpoint_roundtrips_thick_restart_state(tmp_path):
    from repro.dist import checkpoint as ckpt
    V = jnp.asarray(np.random.default_rng(0).standard_normal((16, 6)))
    T = jnp.asarray(np.random.default_rng(1).standard_normal((6, 6)))
    ckpt.save(str(tmp_path), 3, {"V": V, "T": T},
              extra={"kind": "ke_dist", "j": 2, "n_matvec": 40}, keep=2)
    ckpt.save(str(tmp_path), 4, {"V": V + 1.0, "T": T},
              extra={"kind": "ke_dist", "j": 3, "n_matvec": 50}, keep=2)
    like = {"V": jnp.zeros_like(V), "T": jnp.zeros_like(T)}
    step, tree, extra = ckpt.load_latest(str(tmp_path), like)
    assert step == 4 and extra["j"] == 3 and extra["n_matvec"] == 50
    np.testing.assert_array_equal(np.asarray(tree["V"]), np.asarray(V + 1.0))
    np.testing.assert_array_equal(np.asarray(tree["T"]), np.asarray(T))


def test_straggler_and_elastic_compose_on_host_loss():
    """The simulated slow-then-lost host trace drives the monitor's
    rebalance while the host limps, then plan_remesh once it is lost."""
    from repro.dist.elastic import plan_remesh
    from repro.dist.straggler import StragglerMonitor
    n_hosts, slow = 4, 2
    trace = slow_then_lost_trace(n_hosts=n_hosts, slow_host=slow)
    mon = StragglerMonitor(n_hosts)
    survivors = n_hosts
    for step in trace:
        if step["lost"]:
            survivors = n_hosts - len(step["lost"])
            break
        for h, t in enumerate(step["times"]):
            mon.record(h, t)
    # while limping: flagged as a straggler, rebalanced below fair share
    assert mon.stragglers() == [slow]
    plan = mon.rebalance_plan(microbatches_per_host=6)
    assert sum(plan.values()) == n_hosts * 6
    assert plan[slow] < 6
    assert all(plan[h] >= 6 for h in range(n_hosts) if h != slow)
    # once lost: the remesh plan drops to the survivors, no devices idle
    rp = plan_remesh(survivors, 1)
    assert rp.new_shape == (survivors, 1)
    assert rp.n_used == survivors and rp.n_dropped == 0


# --------------------------------------------------------------------------
# sentinel budget proof (rides the session audit fixture)
# --------------------------------------------------------------------------

def test_sentinels_are_fused_and_dispatch_free(assert_program_budget):
    """The acceptance criterion in auditor terms: the sentinel-bearing
    contracts hold with a 0-dispatch sentinel allowance, and the fused
    is_finite sites are really in the lowered programs."""
    from repro.analysis.static_audit.contracts import (
        SENTINEL_EXTRA_DISPATCHES)
    assert SENTINEL_EXTRA_DISPATCHES == 0
    for name, min_sites in [("resilience/stage_sentinels", 2),
                            ("core/lanczos_solve_jit", 1),
                            ("serve/solve_batched_TD", 1),
                            ("serve/solve_batched_KE", 1),
                            ("dist/ke_restart_program", 1)]:
        entry = assert_program_budget(name)
        assert entry["isfinite_sites"] >= min_sites, name
        assert entry["contract"]["sentinel_extra_dispatches"] == 0, name


def test_audit_payload_reports_sentinel_summary(audit_report):
    sen = audit_report["sentinels"]
    assert sen["ok"] is True
    assert sen["entries"] >= 5
    assert sen["isfinite_sites"] >= sen["entries"]
    assert sen["extra_dispatches_allowed"] == 0


# --------------------------------------------------------------------------
# chaos (nightly): distributed preemption drill
# --------------------------------------------------------------------------

_PREEMPT_DRILL = textwrap.dedent("""
    import os, shutil, tempfile
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=2")
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np
    from repro.data.problems import md_like
    from repro.dist.eigensolver import solve_ke_distributed
    from repro.dist.elastic import plan_remesh
    from repro.resilience.faults import SimulatedPreemption

    prob = md_like(48, key=jax.random.PRNGKey(5))
    mesh = jax.make_mesh((2, 1), ("data", "model"))
    kw = dict(s=4, p=4, m=8, invert=True, max_restarts=200,
              return_info=True)

    lam_ref, _, info_ref = solve_ke_distributed(mesh, prob.A, prob.B, **kw)
    assert info_ref["healthy"]

    ckdir = tempfile.mkdtemp()
    try:
        try:
            solve_ke_distributed(mesh, prob.A, prob.B,
                                 checkpoint_dir=ckdir, checkpoint_every=1,
                                 preempt_after=2, **kw)
            raise SystemExit("no preemption raised")
        except SimulatedPreemption as e:
            print("PREEMPTED_AT", e.at_restart)
        # one host lost: resume from the checkpoint on the shrunken mesh
        plan = plan_remesh(1, 1)
        mesh_small = jax.make_mesh(plan.new_shape, ("data", "model"))
        lam2, _, info2 = solve_ke_distributed(
            mesh_small, prob.A, prob.B, checkpoint_dir=ckdir,
            resume=True, **kw)
        assert info2["healthy"] and info2["resumed_from"] >= 0
        err = float(np.max(np.abs(np.asarray(lam2) - np.asarray(lam_ref))))
        print("PARITY_ERR", err)
        assert err < 1e-12, err
        print("DRILL_OK")
    finally:
        shutil.rmtree(ckdir, ignore_errors=True)
""")


@pytest.mark.slow
@pytest.mark.chaos
def test_dist_ke_preemption_drill_resumes_to_parity():
    """Checkpoint at restart boundaries, preempt, resume on a
    plan_remesh-shrunken mesh: eigenvalues match the uninterrupted run
    to 1e-12 (the collectives' roundoff floor)."""
    out = subprocess.run(
        [sys.executable, "-c", _PREEMPT_DRILL], capture_output=True,
        text=True, env=dict(os.environ, PYTHONPATH="src"), cwd=_ROOT)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert "DRILL_OK" in out.stdout


# --------------------------------------------------------------------------
# faults module hygiene
# --------------------------------------------------------------------------

def test_inject_disarms_on_exit():
    assert faults.active("nan") is None
    with inject(NanPoison("GS1")):
        assert faults.active("nan") is not None
        with pytest.raises(RuntimeError):
            with inject(ForceNonconverge()):
                assert faults.active("nan") is not None
                assert faults.active("nonconverge") is not None
                raise RuntimeError("boom")
        assert faults.active("nonconverge") is None
    assert faults.active("nan") is None


def test_nan_poison_is_deterministic():
    f1 = NanPoison("GS1", seed=7)
    f2 = NanPoison("GS1", seed=7)
    x = np.ones((8, 8))
    np.testing.assert_array_equal(f1.apply("GS1", x), f2.apply("GS1", x))
    # untouched stage passes through by identity
    assert f1.apply("GS2", x) is x
