"""kernels/house_panel: interpret-mode kernel parity vs the jnp oracle,
panel-factorization invariants, and the stage-1 dispatch-count regression
(the fused one-program sweep must stay O(1) dispatches; the stepwise
per-panel host loop is the counted baseline that proves the counter works).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import sbr
from repro.core.band_storage import unpack_band
from repro.core.linalg_utils import qr_wy_masked
from repro.kernels.house_panel.ops import house_panel, house_panel_ref

KEY = jax.random.PRNGKey(20260729)


def _panel(rows, b, seed):
    return jax.random.normal(jax.random.fold_in(KEY, seed), (rows, b),
                             jnp.float64)


# ------------------------------------------------ kernel vs oracle -------

# odd rows, b not dividing rows, row_start deep enough that the tail panel
# has fewer than b rows below the pivot (rows < b tail), and the fully
# degenerate pivot-past-the-end case
PARITY_GRID = [
    (37, 5, 10),    # odd rows
    (40, 8, 0),     # aligned
    (33, 4, 7),     # b does not divide rows, unaligned start
    (12, 8, 8),     # rows < b tail panel: only 4 live rows
    (21, 16, 9),    # wide panel, short tail
    (33, 4, 32),    # pivot at the last row: all-identity reflectors
]


@pytest.mark.parametrize("rows,b,row_start", PARITY_GRID)
def test_kernel_matches_ref(rows, b, row_start):
    E = _panel(rows, b, rows * 100 + b + row_start)
    Vr, Tr = house_panel_ref(E, row_start)
    Vk, Tk = house_panel(E, row_start, force_kernel=True,
                         force_interpret=True)
    np.testing.assert_allclose(np.asarray(Vk), np.asarray(Vr), atol=1e-13)
    np.testing.assert_allclose(np.asarray(Tk), np.asarray(Tr), atol=1e-13)


@pytest.mark.parametrize("rows,b,row_start", PARITY_GRID)
def test_factorization_invariants(rows, b, row_start):
    """Q = I - V T V^T is orthogonal, annihilates below each pivot, and
    leaves rows above ``row_start`` untouched."""
    E = _panel(rows, b, rows * 31 + b)
    V, T = house_panel(E, row_start, force_kernel=True,
                       force_interpret=True)
    V, T = np.asarray(V), np.asarray(T)
    Q = np.eye(rows) - V @ T @ V.T
    np.testing.assert_allclose(Q.T @ Q, np.eye(rows), atol=1e-12)
    R = Q.T @ np.asarray(E)
    for j in range(b):
        p = row_start + j
        if p + 1 < rows:
            np.testing.assert_allclose(R[p + 1:, j], 0.0, atol=1e-12)
    # rows above the pivot window pass through untouched
    np.testing.assert_allclose(Q[:row_start, :row_start],
                               np.eye(rows)[:row_start, :row_start],
                               atol=1e-14)


def test_ref_matches_qr_wy_masked():
    """The oracle IS qr_wy_masked minus the R output — bit-identical."""
    E = _panel(29, 6, 77)
    V, T = house_panel_ref(E, 12)
    Vm, Tm, _ = qr_wy_masked(E, 12)
    np.testing.assert_array_equal(np.asarray(V), np.asarray(Vm))
    np.testing.assert_array_equal(np.asarray(T), np.asarray(Tm))


def test_traced_row_start_in_fori_loop():
    """The kernel path accepts a traced pivot (the sweep's fori_loop use)."""
    rows, b = 24, 4
    E = _panel(rows, b, 5)

    def body(k, acc):
        V, T = house_panel(E, k * b, force_kernel=True, force_interpret=True)
        return acc + jnp.sum(V) + jnp.sum(T)

    got = jax.lax.fori_loop(0, 3, body, jnp.zeros((), jnp.float64))
    want = sum(float(jnp.sum(a))
               for k in range(3)
               for a in house_panel_ref(E, k * b))
    np.testing.assert_allclose(float(got), want, atol=1e-11)


# ---------------------------------------- dispatch-count regression ------

def test_reduce_to_band_is_dispatch_light():
    """The full stage-1 sweep compiles to O(1) host dispatches (the
    registry's ``TT1_FUSED_MAX_DISPATCHES``); the stepwise baseline pays
    O(n/w) — which also proves the counter counts real per-panel work, so
    the fused bound is not vacuous."""
    from repro.analysis.static_audit import (
        TT1_FUSED_MAX_DISPATCHES, TT1_STEPWISE_DISPATCHES_PER_PANEL)
    n, w = 96, 8
    M = jax.random.normal(jax.random.fold_in(KEY, 9), (n, n), jnp.float64)
    C = 0.5 * (M + M.T)
    n_panels = len(range(0, n - w - 1, w))

    sbr.reset_dispatch_count()
    band = sbr.reduce_to_band(C, w=w)
    jax.block_until_ready(band.Wb)
    fused = sbr.dispatch_count()
    assert fused <= TT1_FUSED_MAX_DISPATCHES, fused

    sbr.reset_dispatch_count()
    band_sw = sbr.reduce_to_band_stepwise(C, w=w)
    jax.block_until_ready(band_sw.Wb)
    stepwise = sbr.dispatch_count()
    assert stepwise >= TT1_STEPWISE_DISPATCHES_PER_PANEL * n_panels, (
        stepwise, n_panels)

    # and the two sweeps agree (same reflectors, same update form)
    np.testing.assert_allclose(np.asarray(unpack_band(band_sw.Wb)),
                               np.asarray(unpack_band(
                                   sbr.reduce_to_band(C, w=w,
                                                      n_chunks=1).Wb)),
                               atol=1e-11)


def test_default_n_chunks_choice():
    """The auto-sized window ladder: full-matrix updates below the size
    threshold (the ladder measured 0.52x at n=128/w=8) and when the
    windows are panel-starved (0.66x at n=256/w=32), the 4-window ladder
    otherwise, and never more chunks than panels."""
    assert sbr.default_n_chunks(128, 8) == 1
    assert sbr.default_n_chunks(128, 32) == 1
    assert sbr.default_n_chunks(255, 8) == 1
    assert sbr.default_n_chunks(256, 8) == 4      # 30 panels: ladder pays
    assert sbr.default_n_chunks(256, 32) == 1     # 6 panels: starved
    assert sbr.default_n_chunks(512, 8) == 4
    assert sbr.default_n_chunks(512, 32) == 4     # big n: always ladder
    assert sbr.default_n_chunks(300, 200) == 1    # 1 panel -> no ladder
    assert sbr.default_n_chunks(300, 128) == 1    # 2 panels, n < 512
    assert sbr.default_n_chunks(16, 8) == 1
    # and reduce_to_band's auto path equals the explicit choice
    n, w = 96, 16
    M = jax.random.normal(jax.random.fold_in(KEY, 10), (n, n), jnp.float64)
    C = 0.5 * (M + M.T)
    auto = sbr.reduce_to_band(C, w=w)
    explicit = sbr.reduce_to_band(C, w=w,
                                  n_chunks=sbr.default_n_chunks(n, w))
    np.testing.assert_array_equal(np.asarray(auto.Wb),
                                  np.asarray(explicit.Wb))
