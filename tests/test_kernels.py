"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle.

Shape/dtype sweeps via parametrization — including deterministic seeded
sweeps (formerly hypothesis property tests) on the invariants that matter
for the eigensolver (one-triangle semantics, padding exactness,
fused-update linearity).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.gemm.ops import gemm
from repro.kernels.gemm.ref import gemm_ref
from repro.kernels.symv.ops import symv
from repro.kernels.symv.ref import symv_ref, symv_upper_ref
from repro.kernels.syr2k.ops import syr2k
from repro.kernels.syr2k.ref import syr2k_ref
from repro.kernels.trsm.ops import trsm
from repro.kernels.trsm.ref import trsm_ref

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else (
        dict(rtol=2e-5, atol=2e-5) if dtype == jnp.float32
        else dict(rtol=1e-12, atol=1e-12))


# ------------------------------------------------------------------ symv --

@pytest.mark.parametrize("n", [8, 64, 100, 129, 256])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_symv_matches_ref(n, dtype):
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, n))
    M = jax.random.normal(k1, (n, n), dtype)
    A = (M + M.T) / 2
    x = jax.random.normal(k2, (n,), dtype)
    got = symv(A, x, block=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(symv_ref(A, x)),
                               **_tol(dtype))


def test_symv_reads_only_upper_triangle():
    """Feed garbage into the strictly-lower triangle: result must not change."""
    n = 96
    k1, k2, k3 = jax.random.split(KEY, 3)
    M = jax.random.normal(k1, (n, n), jnp.float64)
    A = (M + M.T) / 2
    x = jax.random.normal(k2, (n,), jnp.float64)
    garbage = 1e6 * jax.random.normal(k3, (n, n), jnp.float64)
    A_dirty = jnp.triu(A) + jnp.tril(garbage, -1)
    got = symv(A_dirty, x, block=32)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(symv_upper_ref(A, x)),
                               rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("n,seed", [
    (4, 0), (5, 1), (7, 17), (11, 301), (16, 4_242), (23, 86_000),
    (31, 2**20), (33, 9), (47, 123), (57, 777_777), (64, 2**29),
    (71, 31_337), (79, 2**30), (80, 55),
])
def test_symv_property(n, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    M = jax.random.normal(k1, (n, n), jnp.float64)
    A = (M + M.T) / 2
    x = jax.random.normal(k2, (n,), jnp.float64)
    np.testing.assert_allclose(np.asarray(symv(A, x, block=32)),
                               np.asarray(A @ x), rtol=1e-11, atol=1e-11)


# ------------------------------------------------------------------ gemm --

@pytest.mark.parametrize("m,k,n", [(32, 32, 32), (64, 128, 96), (100, 70, 50),
                                   (8, 8, 8), (129, 257, 65)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_gemm_matches_ref(m, k, n, dtype):
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, m * k * n))
    A = jax.random.normal(k1, (m, k), dtype)
    B = jax.random.normal(k2, (k, n), dtype)
    got = gemm(A, B, bm=32, bn=32, bk=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(gemm_ref(A, B)),
                               **_tol(dtype))


def test_gemm_bf16_accumulates_f32():
    m = k = n = 64
    k1, k2 = jax.random.split(KEY)
    A = jax.random.normal(k1, (m, k), jnp.float32).astype(jnp.bfloat16)
    B = jax.random.normal(k2, (k, n), jnp.float32).astype(jnp.bfloat16)
    got = gemm(A, B, bm=32, bn=32, bk=32)
    ref = (A.astype(jnp.float32) @ B.astype(jnp.float32)).astype(jnp.bfloat16)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), rtol=3e-2,
                               atol=3e-1)


# ------------------------------------------------------------------ trsm --

@pytest.mark.parametrize("n,s,block", [(32, 4, 16), (96, 8, 32), (65, 5, 32),
                                       (128, 1, 64)])
@pytest.mark.parametrize("trans", [False, True])
def test_trsm_matches_ref(n, s, block, trans):
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, n * s))
    U = jnp.triu(jax.random.normal(k1, (n, n), jnp.float64)) \
        + n * jnp.eye(n, dtype=jnp.float64)
    B = jax.random.normal(k2, (n, s), jnp.float64)
    got = trsm(U, B, trans=trans, block=block)
    ref = trsm_ref(U, B, trans=trans)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-11,
                               atol=1e-11)


def test_trsm_vector_rhs():
    n = 48
    k1, k2 = jax.random.split(KEY)
    U = jnp.triu(jax.random.normal(k1, (n, n), jnp.float64)) + n * jnp.eye(n)
    b = jax.random.normal(k2, (n,), jnp.float64)
    got = trsm(U, b, block=16)
    np.testing.assert_allclose(np.asarray(U @ got), np.asarray(b), atol=1e-10)


@pytest.mark.parametrize("n,s,seed", [
    (3, 1, 0), (5, 2, 10), (9, 9, 200), (13, 4, 3_000), (17, 1, 40_000),
    (24, 6, 2**18), (31, 3, 7), (37, 8, 99), (45, 5, 2**25), (51, 2, 12_321),
    (57, 7, 2**30), (60, 9, 424_242),
])
def test_trsm_property_roundtrip(n, s, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    U = jnp.triu(jax.random.normal(k1, (n, n), jnp.float64)) + n * jnp.eye(n)
    B = jax.random.normal(k2, (n, s), jnp.float64)
    X = trsm(U, B, block=16)
    np.testing.assert_allclose(np.asarray(U @ X), np.asarray(B), atol=1e-9)
    Xt = trsm(U, B, trans=True, block=16)
    np.testing.assert_allclose(np.asarray(U.T @ Xt), np.asarray(B), atol=1e-9)


# ----------------------------------------------------------------- syr2k --

@pytest.mark.parametrize("n,k", [(32, 4), (64, 16), (100, 8), (72, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_syr2k_matches_ref(n, k, dtype):
    k1, k2, k3 = jax.random.split(jax.random.fold_in(KEY, n * k), 3)
    M = jax.random.normal(k1, (n, n), dtype)
    C = (M + M.T) / 2
    V = jax.random.normal(k2, (n, k), dtype)
    W = jax.random.normal(k3, (n, k), dtype)
    got = syr2k(C, V, W, alpha=-1.0, bm=32)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(syr2k_ref(C, V, W, -1.0)),
                               **_tol(dtype))


def test_syr2k_symmetry_preserved():
    n, k = 64, 8
    k1, k2, k3 = jax.random.split(KEY, 3)
    M = jax.random.normal(k1, (n, n), jnp.float64)
    C = (M + M.T) / 2
    V = jax.random.normal(k2, (n, k), jnp.float64)
    W = jax.random.normal(k3, (n, k), jnp.float64)
    out = np.asarray(syr2k(C, V, W, bm=32))
    np.testing.assert_allclose(out, out.T, atol=1e-12)


# ------------------------------------------- kernel path inside the solver --

def test_lanczos_with_kernel_symv():
    """KE with use_kernel=True routes KE1 through the Pallas symv."""
    from repro.core import ExplicitC, lanczos_solve
    n, s = 96, 4
    k1 = jax.random.fold_in(KEY, 99)
    lam = jnp.sort(jax.random.normal(k1, (n,), jnp.float64)) * 5
    Q, _ = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(KEY, 98),
                                           (n, n), jnp.float64))
    C = (Q * lam[None, :]) @ Q.T
    C = 0.5 * (C + C.T)
    res = lanczos_solve(ExplicitC(C), s, which="SA", use_kernel=True)
    assert res.converged
    np.testing.assert_allclose(np.asarray(res.evals), np.asarray(lam[:s]),
                               rtol=1e-9, atol=1e-9)


# ------------------------------------------------------------- rot_apply --

@pytest.mark.parametrize("G,L", [(1, 8), (5, 37), (8, 128), (13, 200)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_rot_apply_matches_ref(G, L, dtype):
    """Pallas wavefront rotation kernel (interpret mode) vs the jnp oracle,
    including shapes that force tile padding."""
    from repro.kernels.rot_apply.ops import rot_apply
    from repro.kernels.rot_apply.ref import rot_apply_ref
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, 1000 + G * L))
    pairs = jax.random.normal(k1, (G, 2, L), dtype)
    ang = jax.random.uniform(k2, (G,), dtype, 0.0, 6.28)
    cs = jnp.stack([jnp.cos(ang), jnp.sin(ang)], axis=1)
    got = rot_apply(pairs, cs, force_kernel=True, force_interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(rot_apply_ref(pairs, cs)),
                               **_tol(dtype))


def test_rot_apply_orthogonality():
    """Rotations preserve per-pair norms (the invariant TT2 leans on)."""
    from repro.kernels.rot_apply.ops import rot_apply
    G, L = 7, 33
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, 77))
    pairs = jax.random.normal(k1, (G, 2, L), jnp.float64)
    ang = jax.random.uniform(k2, (G,), jnp.float64, 0.0, 6.28)
    cs = jnp.stack([jnp.cos(ang), jnp.sin(ang)], axis=1)
    got = rot_apply(pairs, cs, force_kernel=True, force_interpret=True)
    norms_in = np.linalg.norm(np.asarray(pairs), axis=1)
    norms_out = np.linalg.norm(np.asarray(got), axis=1)
    np.testing.assert_allclose(norms_out, norms_in, rtol=1e-12)
