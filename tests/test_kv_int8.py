"""int8 KV-cache decode: correctness vs the bf16/f32 cache path."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.models.model import decode_step, init_decode_state, init_params


def test_int8_kv_decode_close_to_f32():
    cfg = smoke_config("gemma3-27b")
    cfg8 = cfg.scaled(kv_cache_dtype="int8")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    B, T = 2, 8
    toks = jax.random.randint(jax.random.fold_in(key, 1), (B, T), 0,
                              cfg.vocab_size, jnp.int32)
    st_f = init_decode_state(cfg, B, capacity=16)
    st_q = init_decode_state(cfg8, B, capacity=16)
    assert st_q.block_caches[0].k.dtype == jnp.int8
    # jitted steps: one compile per cache dtype instead of 2T eager traces
    step_f = jax.jit(lambda p, t, s: decode_step(p, t, s, cfg))
    step_q = jax.jit(lambda p, t, s: decode_step(p, t, s, cfg8))
    outs_f, outs_q = [], []
    for t in range(T):
        lf, st_f = step_f(params, toks[:, t:t + 1], st_f)
        lq, st_q = step_q(params, toks[:, t:t + 1], st_q)
        outs_f.append(lf)
        outs_q.append(lq)
    lf = jnp.concatenate(outs_f, axis=1)
    lq = jnp.concatenate(outs_q, axis=1)
    # logits close; argmax (greedy token) identical nearly everywhere
    err = float(jnp.max(jnp.abs(lf - lq)) / jnp.maximum(
        jnp.max(jnp.abs(lf)), 1e-6))
    assert err < 0.05, err
    agree = float(jnp.mean(jnp.argmax(lf, -1) == jnp.argmax(lq, -1)))
    assert agree >= 0.9, agree


def test_int8_cache_memory_halves():
    cfg8 = smoke_config("gemma3-27b").scaled(kv_cache_dtype="int8")
    cfg = smoke_config("gemma3-27b")
    st8 = init_decode_state(cfg8, 2, capacity=64)
    st = init_decode_state(cfg, 2, capacity=64)

    def cache_bytes(st):
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(st.block_caches)
                   if x.ndim >= 3)

    assert cache_bytes(st8) < 0.6 * cache_bytes(st)
