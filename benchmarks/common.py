"""Shared benchmark utilities: scaled problem instances + timing."""
from __future__ import annotations

import time
from functools import lru_cache

import jax

# CI-scale stand-ins for the paper's two experiments (same spectrum *shape*,
# same wanted-fraction; the paper's n=9,997 / n=17,243 run behind --full).
MD_N, MD_S = 384, 4          # ~1% of the spectrum, as in the paper's MD
DFT_N, DFT_S = 512, 13       # ~2.6%, as in the paper's DFT

BAND_W = 8                   # TT bandwidth at CI scale (paper used 32 at 17k)
# NOTE on scale: the TT stages used to dominate these tables through
# dispatch-heavy structure, in two installments. TT2 was a dense-storage
# one-rotation-per-dispatch chase; it now runs as the packed-band wavefront
# chase (core/sbr.py + kernels/rot_apply). Then TT1 — which is NOT cheap:
# once the chase was fixed it was the dominant stage of a TT solve — paid a
# host round trip per panel; it is now one fused program per sweep
# (kernels/house_panel + the fori_loop ladder in core/sbr.py, shard_map'd
# whole in dist/sharded_la.py). benchmarks/bench_sbr.py measures both
# shootouts, so n here is sized only by the O(n^3) stage flops.


@lru_cache(maxsize=None)
def md_problem(n: int = MD_N):
    import jax.numpy as jnp  # noqa: F401  (x64 enabled by run.py)
    from repro.data.problems import md_like
    return md_like(n)


@lru_cache(maxsize=None)
def dft_problem(n: int = DFT_N):
    from repro.data.problems import dft_like
    return dft_like(n)


def time_call(fn, *args, warmup: int = 1, iters: int = 3, **kwargs):
    """(median seconds, last result) for a host-level callable."""
    for _ in range(warmup):
        out = fn(*args, **kwargs)
        jax.block_until_ready(jax.tree.leaves(out)[0]) if jax.tree.leaves(
            out) else None
    ts = []
    out = None
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        leaves = jax.tree.leaves(out)
        if leaves:
            jax.block_until_ready(leaves[0])
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2], out


def csv_row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"


# ---- cross-table solve cache (table2 + table3 share one run per variant) --
_SOLVE_CACHE: dict = {}


def solve_cached(tag: str, prob, s: int, variant: str, **kw):
    """Memoized core.solve keyed by (tag, variant, s) — table3 reuses
    table2's runs instead of re-paying TT's minutes-scale Givens stage."""
    from repro.core import solve
    key = (tag, variant, s, tuple(sorted(kw.items())))
    if key not in _SOLVE_CACHE:
        _SOLVE_CACHE[key] = solve(prob.A, prob.B, s, variant=variant, **kw)
    return _SOLVE_CACHE[key]
