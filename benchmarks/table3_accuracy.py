"""Paper Table 3: accuracy (B-orthogonality + relative residual) of the four
solvers. Metrics are computed exactly as the paper defines them, on the pair
actually solved (the MD experiment solves the inverse pair (B, A))."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import solve
from repro.core.residuals import b_orthogonality, relative_residual

from .common import BAND_W, DFT_S, MD_S, dft_problem, md_problem


def main(full: bool = False) -> list[str]:
    out = []
    for name, prob, s, invert, m, mr in [
            ("md", md_problem(), MD_S, True, None, 120),
            ("dft", dft_problem(), DFT_S, False, 96, 200)]:
        out.append(f"# table3 {name}: n={prob.A.shape[0]} s={s}")
        for variant in ("TD", "TT", "KE", "KI"):
            inv = invert and variant in ("KE", "KI")
            from .common import solve_cached
            res = solve_cached(name, prob, s, variant=variant, invert=inv,
                               band_width=BAND_W, max_restarts=mr, m=m)
            orth = float(b_orthogonality(res.X, prob.B))
            resid = float(relative_residual(prob.A, prob.B, res.X,
                                            res.evals))
            # ground-truth eigenvalue error (we know the exact spectrum)
            err = float(jnp.max(jnp.abs(
                res.evals - prob.exact_evals[:s])
                / jnp.abs(prob.exact_evals[:s])))
            out.append(f"table3_{name}_{variant},0.0,"
                       f"orth={orth:.3e};resid={resid:.3e};"
                       f"eval_relerr={err:.3e}")
    return out


if __name__ == "__main__":
    jax.config.update("jax_enable_x64", True)
    for line in main():
        print(line)
