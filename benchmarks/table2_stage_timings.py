"""Paper Table 2: per-stage execution time of the four GSYEIG solvers on the
MD-like and DFT-like problems (CI scale; --full switches to paper sizes).

Reproduces the paper's findings at reduced n:
  * MD: KE ~ KI (both fast via the inverse-problem trick), TD slower
    (BLAS-2-bound TD1), TT slowest (the extra 7n^3/3 of TT2/Q-accumulation).
  * DFT: the clustered spectrum drives Krylov iteration counts up; KI pays
    4n^2/iter and loses badly; KE stays competitive with TD.
"""
from __future__ import annotations

import jax

from repro.core import solve
from repro.core.residuals import accuracy_report

from .common import BAND_W, DFT_N, DFT_S, MD_N, MD_S, dft_problem, md_problem

STAGE_KEYS = ["GS1", "GS2", "TD1", "TD2", "TD3", "TT1", "TT2", "TT3", "TT4",
              "KE_iter", "KI_iter", "BT1", "Tot."]


def run_experiment(prob, s: int, which_invert: bool, band_w: int,
                   max_restarts: int = 120, m: int | None = None,
                   tag: str = ""):
    from .common import solve_cached
    rows = {}
    info = {}
    for variant in ("TD", "TT", "KE", "KI"):
        invert = which_invert and variant in ("KE", "KI")
        res = solve(prob.A, prob.B, s, variant=variant, invert=invert,
                    band_width=band_w, max_restarts=max_restarts, m=m)
        if variant != "TT":
            # warm second run for stable timings (first run pays compiles);
            # TT is run once — its Givens stage is minutes-scale on CPU.
            # The cached entry is what table3 reuses.
            res = solve_cached(tag, prob, s, variant=variant, invert=invert,
                               band_width=band_w, max_restarts=max_restarts,
                               m=m)
        else:
            from .common import _SOLVE_CACHE
            _SOLVE_CACHE[(tag, variant, s,
                          tuple(sorted(dict(invert=invert,
                                            band_width=band_w,
                                            max_restarts=max_restarts,
                                            m=m).items())))] = res
        rows[variant] = res.stage_times
        acc = accuracy_report(prob.A, prob.B, res.X, res.evals)
        info[variant] = dict(res.info,
                             orth=float(acc.b_orthogonality),
                             resid=float(acc.relative_residual))
    return rows, info


def main(full: bool = False) -> list[str]:
    out = []
    # m tuned per experiment exactly as the paper did ("a large effort was
    # made to optimize ... the number of Krylov vectors (m)"): the DFT-like
    # clustered spectrum needs a subspace covering the cluster.
    specs = [("md", md_problem(), MD_S, True, None, 120),
             ("dft", dft_problem(), DFT_S, False, 96, 200)]
    if full:
        from repro.data.problems import dft_like, md_like
        specs = [("md", md_like(9_997), 100, True, None, 300),
                 ("dft", dft_like(17_243), 448, False, 896, 300)]
    for name, prob, s, invert, m, mr in specs:
        rows, info = run_experiment(prob, s, invert, BAND_W,
                                    max_restarts=mr, m=m, tag=name)
        n = prob.A.shape[0]
        out.append(f"# table2 {name}: n={n} s={s} "
                   f"(KE/KI inverse-trick={invert})")
        out.append("stage," + ",".join(rows.keys()))
        for key in STAGE_KEYS:
            vals = [f"{rows[v].get(key, float('nan')):.3f}"
                    if key in rows[v] else "-" for v in rows]
            if any(v != "-" for v in vals):
                out.append(f"{key}," + ",".join(vals))
        for v, i in info.items():
            if "n_matvec" in i:
                out.append(f"# {name}/{v}: matvecs={i['n_matvec']} "
                           f"restarts={i['n_restart']} "
                           f"converged={i['converged']}")
        # paper-shaped CSV rows
        for v in rows:
            out.append(f"table2_{name}_{v}_total,"
                       f"{rows[v]['Tot.'] * 1e6:.1f},"
                       f"orth={info[v]['orth']:.2e};"
                       f"resid={info[v]['resid']:.2e}")
    return out


if __name__ == "__main__":
    jax.config.update("jax_enable_x64", True)
    for line in main():
        print(line)
