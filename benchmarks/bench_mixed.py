"""Mixed-precision pipeline benchmark: fp64 vs demoted GEMM stages + refinement.

Two measurement layers, both against the SAME Table-3 accuracy gate the
test harness enforces (a fast wrong answer fails the benchmark):

* **per-stage** (``core.gsyeig.solve``): one pencil per cell, every
  precision of ``core.precision`` side by side, so the table shows WHERE
  the demotion pays (TD1, TT1/TT2/TT4, the Krylov matvec) and what the
  adaptive fp64 refinement epilogue (``RF``) costs on top.
* **end-to-end serving** (``core.batched.solve_batched``): a bucket of
  pencils through the ONE-program pipeline with the fixed-step fp64
  refinement fused in — the production path, where the refinement
  amortizes instead of paying a host loop per solve. This is the layer
  the CI gate judges.

    PYTHONPATH=src python -m benchmarks.bench_mixed [--quick]

``--quick`` runs the n=256 cell set and EXITS NONZERO unless mixed
precision beats fp64 end-to-end (batched layer) on at least one variant.
Emits ``artifacts/BENCH_mixed.json`` plus the usual CSV rows.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

PRECISIONS = ("fp64", "mixed", "fast")
#: the shared Table-3 tolerance — identical to the accuracy harness
TOL = 1e-12


# --------------------------------------------------------------------------
# per-stage layer: core.gsyeig.solve
# --------------------------------------------------------------------------

def _solve_timed(prob, s, variant, precision, band_width, max_restarts):
    from repro.core import solve
    invert = variant in ("KE", "KI")       # md pencil: the paper's MD trick
    res = solve(prob.A, prob.B, s, variant=variant, which="smallest",
                invert=invert, band_width=band_width,
                max_restarts=max_restarts, precision=precision)
    jax.block_until_ready(res.X)
    return res


def bench_stage_cell(kind: str, n: int, s: int, variant: str,
                     band_width: int, max_restarts: int,
                     repeats: int) -> dict:
    from repro.core import accuracy_report
    from repro.data.problems import dft_like, md_like
    prob = (md_like if kind == "md" else dft_like)(n)

    runs: dict = {}
    for precision in PRECISIONS:
        # warm: compile + populate caches; keep the warm result for the
        # accuracy gate and the refinement trajectory
        res = _solve_timed(prob, s, variant, precision, band_width,
                           max_restarts)
        acc = accuracy_report(prob.A, prob.B, res.X, res.evals)
        rel, orth = float(acc.relative_residual), float(acc.b_orthogonality)
        assert max(rel, orth) <= TOL, (
            f"{variant}/n{n}/{precision}: residual {rel:.2e} / "
            f"orthogonality {orth:.2e} above the Table-3 tolerance "
            f"{TOL:.0e} — timing a wrong answer is meaningless")

        totals, stage_runs = [], []
        for _ in range(repeats):
            t0 = time.perf_counter()
            r = _solve_timed(prob, s, variant, precision, band_width,
                             max_restarts)
            totals.append(time.perf_counter() - t0)
            stage_runs.append(r.stage_times)
        med = sorted(range(repeats), key=lambda i: totals[i])[repeats // 2]
        rinfo = res.info.get("refinement")
        runs[precision] = {
            "total_s": totals[med],
            "stage_times_s": {k: float(v)
                              for k, v in stage_runs[med].items()},
            "relative_residual": rel,
            "b_orthogonality": orth,
            "refine_steps": int(rinfo["steps"]) if rinfo else 0,
            "refine_converged": bool(rinfo["converged"]) if rinfo else True,
            "refine_overhead_s": float(stage_runs[med].get("RF", 0.0)),
        }

    base = runs["fp64"]["total_s"]
    stages = sorted({k for r in runs.values() for k in r["stage_times_s"]})
    return {
        "cell": f"{kind}_n{n}_s{s}_{variant}",
        "workload": kind, "n": n, "s": s, "variant": variant,
        "precisions": runs,
        "stage_table": {
            st: {p: runs[p]["stage_times_s"].get(st) for p in PRECISIONS}
            for st in stages},
        "speedup_mixed": base / runs["mixed"]["total_s"],
        "speedup_fast": base / runs["fast"]["total_s"],
    }


# --------------------------------------------------------------------------
# end-to-end serving layer: core.batched.solve_batched (the CI gate)
# --------------------------------------------------------------------------

def bench_batched_cell(kind: str, n: int, s: int, variant: str, batch: int,
                       repeats: int, precisions=PRECISIONS) -> dict:
    import jax.numpy as jnp

    from repro.core import accuracy_report
    from repro.core.batched import solve_batched
    from repro.data.problems import dft_like, md_like
    gen = md_like if kind == "md" else dft_like
    probs = [gen(n, key=jax.random.PRNGKey(100 + i)) for i in range(batch)]
    A = jnp.stack([p.A for p in probs])
    B = jnp.stack([p.B for p in probs])

    runs: dict = {}
    for precision in precisions:
        res = solve_batched(A, B, s, variant=variant,
                            precision=precision)        # warm / compile
        worst = 0.0
        for i, p_ in enumerate(probs):
            acc = accuracy_report(p_.A, p_.B, res.X[i], res.evals[i])
            worst = max(worst, float(acc.relative_residual),
                        float(acc.b_orthogonality))
        assert worst <= TOL, (
            f"batched {variant}/n{n}/{precision}: worst metric "
            f"{worst:.2e} above the Table-3 tolerance {TOL:.0e}")

        totals = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            r = solve_batched(A, B, s, variant=variant, precision=precision)
            jax.block_until_ready(r.evals)
            totals.append(time.perf_counter() - t0)
        t = sorted(totals)[len(totals) // 2]
        runs[precision] = {
            "total_s": t,
            "pencils_per_s": batch / t,
            "worst_table3_metric": worst,
            "refine_steps": int(r.info["refine_steps"]),
        }

    base = runs["fp64"]["total_s"]
    out = {
        "cell": f"{kind}_n{n}_s{s}_{variant}_b{batch}",
        "workload": kind, "n": n, "s": s, "variant": variant,
        "batch": batch, "precisions": runs,
    }
    for p in precisions:
        if p != "fp64":
            out[f"speedup_{p}"] = base / runs[p]["total_s"]
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI gate: n=256 cells only; fail unless mixed "
                         "beats fp64 end-to-end on >= 1 variant")
    ap.add_argument("--ns", type=int, nargs="*", default=[128, 256])
    ap.add_argument("--s", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--band-width", type=int, default=16)
    ap.add_argument("--max-restarts", type=int, default=500)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--outdir", default="artifacts")
    args = ap.parse_args()

    ns = [256] if args.quick else args.ns
    repeats = 2 if args.quick else args.repeats
    variants = ("TD", "TT", "KE")

    stage_cells = [bench_stage_cell("md", n, args.s, v, args.band_width,
                                    args.max_restarts, repeats)
                   for n in ns for v in variants]
    # gate layer: the bucketed pipelines with fused fixed-step refinement.
    # quick mode skips 'fast' (bf16 emulation off-TPU is slow and the gate
    # judges mixed); the full run records all three.
    bat_prec = ("fp64", "mixed") if args.quick else PRECISIONS
    batched_cells = [bench_batched_cell("md", n, args.s, v, args.batch,
                                        repeats, precisions=bat_prec)
                     for n in ns for v in ("TD", "TT")]

    print("name,us_per_call,derived")
    for c in stage_cells:
        print(f"bench_mixed_solve_{c['cell']},"
              f"{c['precisions']['mixed']['total_s'] * 1e6:.1f},"
              f"fp64={c['precisions']['fp64']['total_s'] * 1e3:.1f}ms;"
              f"mixed={c['speedup_mixed']:.2f}x;"
              f"fast={c['speedup_fast']:.2f}x;"
              f"rf={c['precisions']['mixed']['refine_steps']}steps")
    for c in batched_cells:
        print(f"bench_mixed_batched_{c['cell']},"
              f"{c['precisions']['mixed']['total_s'] * 1e6:.1f},"
              f"fp64={c['precisions']['fp64']['total_s'] * 1e3:.1f}ms;"
              f"mixed={c.get('speedup_mixed', 0.0):.2f}x")

    gate_cells = [c for c in batched_cells if c["n"] == 256] or batched_cells
    mixed_wins = any(c.get("speedup_mixed", 0.0) > 1.0 for c in gate_cells)
    payload = {
        "tolerance": TOL,
        "repeats": repeats,
        "stage_cells": stage_cells,
        "batched_cells": batched_cells,
        "mixed_beats_fp64_at_n256": mixed_wins,
    }
    os.makedirs(args.outdir, exist_ok=True)
    out = os.path.join(args.outdir, "BENCH_mixed.json")
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {out}")

    if args.quick and not mixed_wins:
        print("QUICK GATE FAILED: mixed precision beat fp64 end-to-end on "
              "no variant at n=256", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
