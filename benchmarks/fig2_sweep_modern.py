"""Paper Figure 2 analogue: the "conventional+modern" solver variants as a
function of s. Our modern path = blocked BLAS-3 algorithms (the PLASMA/
MAGMA counterpart): TD with the blocked DSYTRD panel algorithm + blocked
Cholesky, vs the baseline unblocked pipeline, vs KE (whose GS2-dominated
profile is what the GPU accelerated most in the paper)."""
from __future__ import annotations

import jax

from repro.core import solve

from .common import md_problem


def main(full: bool = False) -> list[str]:
    out = []
    prob = md_problem()
    n = prob.A.shape[0]
    sweep = (4, 8, 16) if not full else (50, 100, 200)
    out.append(f"# fig2: n={n}, total seconds vs s (modern/blocked paths)")
    out.append("s,TD_unblocked,TD_blocked,KE")
    for s in sweep:
        row = [str(s)]
        for name, kw in (
            ("TD_unblocked", dict(variant="TD", td1="unblocked")),
            ("TD_blocked", dict(variant="TD", td1="blocked", gs1="blocked")),
            ("KE", dict(variant="KE", invert=True)),
        ):
            res = solve(prob.A, prob.B, s, max_restarts=150, **kw)
            res = solve(prob.A, prob.B, s, max_restarts=150, **kw)  # warm
            row.append(f"{res.stage_times['Tot.']:.3f}")
            out.append(f"fig2_s{s}_{name},"
                       f"{res.stage_times['Tot.'] * 1e6:.1f},"
                       f"TD1={res.stage_times.get('TD1', 0):.3f}")
        out.append("# " + ",".join(row))
    return out


if __name__ == "__main__":
    jax.config.update("jax_enable_x64", True)
    for line in main():
        print(line)
