"""§Roofline source: merge the dry-run artifacts with the analytic cost
model into the per-(arch x shape x mesh) three-term table."""
from __future__ import annotations

import glob
import json
import os

from repro.analysis.analytic import cell_cost
from repro.analysis.roofline import (HBM_BW, ICI_LINK_BW, PEAK_FLOPS_BF16,
                                     model_flops_for)
from repro.configs import get_config
from repro.models.config import shape_by_name


def build_rows(dryrun_dir: str = "artifacts/dryrun") -> list[dict]:
    """Pass dryrun_dir=artifacts/dryrun_opt for the optimized-serving rows."""
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            rows.append({"arch": rec.get("arch"), "shape": rec.get("shape"),
                         "mesh": rec.get("mesh"), "status": "FAIL"})
            continue
        arch, shape_name, mesh = rec["arch"], rec["shape"], rec["mesh"]
        chips = 512 if "pods" in mesh else 256
        cfg = get_config(arch)
        if rec.get("kv_dtype") == "int8":
            cfg = cfg.scaled(kv_cache_dtype="int8")
        shape = shape_by_name(shape_name)
        replicated = rec.get("serve_sharding") == "replicated"
        ac = cell_cost(cfg, shape, chips, serving_replicated=replicated)
        t_comp = ac.flops / (chips * PEAK_FLOPS_BF16)
        t_mem = ac.hbm_bytes / (chips * HBM_BW)
        t_coll = ac.coll_bytes / (chips * ICI_LINK_BW)
        terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
        bottleneck = max(terms, key=terms.get)
        mf = model_flops_for(arch, shape_name)
        rows.append({
            "arch": arch, "shape": shape_name, "mesh": mesh, "chips": chips,
            "status": "ok", "kind": rec.get("kind"),
            "t_compute_s": t_comp, "t_memory_s": t_mem,
            "t_collective_s": t_coll, "bottleneck": bottleneck,
            "model_flops": mf, "analytic_flops": ac.flops,
            "useful_ratio": mf / ac.flops if ac.flops else 0.0,
            "roofline_fraction": max(terms.values()) and (
                t_comp / max(terms.values())),
            "hlo_flops_per_chip_bodyonce": rec.get(
                "cost_analysis", {}).get("flops", -1.0),
            "hlo_coll_bytes_per_chip_bodyonce": rec.get(
                "collectives", {}).get("total_bytes", -1.0),
            "memory_analysis": rec.get("memory_analysis", {}),
            "t_compile_s": rec.get("t_compile_s", -1.0),
            "serve_sharding": rec.get("serve_sharding", "fsdp"),
        })
    return rows


def main(full: bool = False) -> list[str]:
    rows = build_rows()
    out = []
    for r in rows:
        if r.get("status") != "ok":
            out.append(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']},0,"
                       f"STATUS=FAIL")
            continue
        out.append(
            f"roofline_{r['arch']}_{r['shape']}_{r['mesh']},"
            f"{max(r['t_compute_s'], r['t_memory_s'],
                   r['t_collective_s']) * 1e6:.1f},"
            f"bottleneck={r['bottleneck']};"
            f"comp={r['t_compute_s']:.3e};mem={r['t_memory_s']:.3e};"
            f"coll={r['t_collective_s']:.3e};"
            f"roofline_frac={r['roofline_fraction']:.3f};"
            f"useful={r['useful_ratio']:.3f}")
    os.makedirs("artifacts", exist_ok=True)
    with open("artifacts/roofline_rows.json", "w") as f:
        json.dump(rows, f, indent=1)
    return out


if __name__ == "__main__":
    for line in main():
        print(line)
