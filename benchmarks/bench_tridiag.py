"""TT3 benchmark: scan baseline vs fused batched path vs sharded TT3.

Three executions of the same tridiagonal eigensolve, raced per
``(n, s)`` cell on random tridiagonals:

  scan     — the legacy two-program baseline (``method='scan'``:
             bisection jit + inverse-iteration jit, unroll=1 Sturm scans)
  batched  — ONE fused program with the Sturm scans unrolled
             (``kernels.tridiag_eig.tridiag_eig_batched``, the default
             every pipeline runs); bitwise-identical values, the per-step
             scan overhead amortized over ``SCAN_UNROLL`` rows
  sharded  — the spectrum-partitioned TT3 over an 8-host-device (4, 2)
             mesh (``dist.eigensolver.dist_tridiag_eig``: per-device
             contiguous index slices, 1 + iters collectives), raced
             against the replicated batched path on the same host

Reading the numbers: ``batched`` vs ``scan`` is a pure dispatch/loop-
overhead race on identical arithmetic — the artifact records the bitwise
check alongside the speedup. The sharded row time-shares 8 virtual
devices over however many cores the container grants (recorded as
``cores``), so its wall clock measures oversubscription, not the
algorithm; the hardware-independent invariants — bitwise eigenvalues and
ulp-level eigenvectors vs the replicated path — are what ``--quick``
gates on, plus the batched-beats-scan margin at the largest cell
(n=2048, s=64).

Standalone (sets its own XLA flags, so run it directly, not via run.py):

    PYTHONPATH=src python -m benchmarks.bench_tridiag
    PYTHONPATH=src python -m benchmarks.bench_tridiag --quick  # CI gate

Emits ``artifacts/BENCH_tridiag.json`` and the usual
``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + " --xla_force_host_platform_device_count=8").strip()

import argparse  # noqa: E402
import json      # noqa: E402
import time      # noqa: E402

import jax       # noqa: E402

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402

#: full-run cells; ``--quick`` keeps only the gated largest cell plus one
#: small one (compile time, not solve time, dominates the small cells)
CELLS = [(512, 8), (512, 64), (2048, 8), (2048, 64)]
#: the acceptance cell: the fused batched path must beat the scan
#: baseline here (it is the cell where the Sturm scan's per-step overhead
#: is the whole stage)
GATE_CELL = (2048, 64)


def _problem(n: int, seed: int = 0):
    kd, ke = jax.random.split(jax.random.PRNGKey(seed))
    d = jax.random.normal(kd, (n,), jnp.float64)
    e = jax.random.normal(ke, (n - 1,), jnp.float64)
    return d, e


def _time_median(fn, repeats: int) -> float:
    fn()  # warmup: compile
    walls = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        walls.append(time.perf_counter() - t0)
    return sorted(walls)[len(walls) // 2]


def bench_cell(n: int, s: int, repeats: int) -> dict:
    from repro.core.tridiag_eig import eigh_tridiag_selected

    d, e = _problem(n)
    ks = jnp.arange(s)
    key = jax.random.PRNGKey(1)
    t_scan = _time_median(
        lambda: eigh_tridiag_selected(d, e, ks, key, method="scan"), repeats)
    t_batched = _time_median(
        lambda: eigh_tridiag_selected(d, e, ks, key, method="batched"),
        repeats)
    lam_s, Z_s = eigh_tridiag_selected(d, e, ks, key, method="scan")
    lam_b, Z_b = eigh_tridiag_selected(d, e, ks, key, method="batched")
    bitwise = bool(np.array_equal(np.asarray(lam_s), np.asarray(lam_b))
                   and np.array_equal(np.asarray(Z_s), np.asarray(Z_b)))
    return {"n": n, "s": s,
            "scan_s_median": t_scan,
            "batched_s_median": t_batched,
            "speedup_batched_over_scan": t_scan / t_batched,
            "bitwise_batched_eq_scan": bitwise}


def bench_sharded(mesh, n: int, s: int, repeats: int) -> dict:
    from repro.core.tridiag_eig import eigh_tridiag_selected
    from repro.dist.eigensolver import dist_tridiag_eig

    d, e = _problem(n)
    ks = jnp.arange(s)
    key = jax.random.PRNGKey(1)
    t_rep = _time_median(
        lambda: eigh_tridiag_selected(d, e, ks, key, method="batched"),
        repeats)
    t_sh = _time_median(
        lambda: dist_tridiag_eig(mesh, d, e, ks, key), repeats)
    lam_r, Z_r = eigh_tridiag_selected(d, e, ks, key, method="batched")
    lam_d, Z_d = dist_tridiag_eig(mesh, d, e, ks, key)
    # lam is bitwise (independent lanes); Z only up to the vector-width
    # reassociation of the column-norm reduction (ulp-level)
    lam_bitwise = bool(np.array_equal(np.asarray(lam_r), np.asarray(lam_d)))
    z_err = float(np.abs(np.asarray(Z_r) - np.asarray(Z_d)).max())
    return {"n": n, "s": s, "n_devices": int(mesh.devices.size),
            "replicated_s_median": t_rep,
            "sharded_s_median": t_sh,
            "lam_bitwise_sharded_eq_replicated": lam_bitwise,
            "z_max_abs_err_vs_replicated": z_err}


def quick_gate(cells: list, sharded: list) -> None:
    """CI acceptance: values first (bitwise both ways), then the one
    hardware-robust perf claim — the fused batched path beats the scan
    baseline at the gate cell, where the race is pure loop overhead on
    identical arithmetic (a single-core container slows both sides
    equally, so the ratio survives time-sharing)."""
    for r in cells:
        assert r["bitwise_batched_eq_scan"], r
    for r in sharded:
        assert r["lam_bitwise_sharded_eq_replicated"], r
        assert r["z_max_abs_err_vs_replicated"] <= 1e-12, r
    g = next(r for r in cells if (r["n"], r["s"]) == GATE_CELL)
    assert g["batched_s_median"] < g["scan_s_median"], (
        f"fused batched TT3 lost to the scan baseline at n={g['n']}, "
        f"s={g['s']}: {g['batched_s_median']:.3f}s vs "
        f"{g['scan_s_median']:.3f}s")
    print(f"quick gate OK (gate cell speedup "
          f"{g['speedup_batched_over_scan']:.2f}x)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--quick", action="store_true",
                    help="gated cells only + assert the CI acceptance gate")
    ap.add_argument("--outdir", default="artifacts")
    args = ap.parse_args()

    cell_list = [(512, 8), GATE_CELL] if args.quick else CELLS
    cells = [bench_cell(n, s, args.repeats) for n, s in cell_list]

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    sharded_list = [(512, 64)] if args.quick else [(512, 64), (2048, 64)]
    sharded = [bench_sharded(mesh, n, s, args.repeats)
               for n, s in sharded_list]

    print("name,us_per_call,derived")
    for r in cells:
        print(f"bench_tridiag_n{r['n']}_s{r['s']},"
              f"{r['batched_s_median'] * 1e6:.1f},"
              f"scan_us={r['scan_s_median'] * 1e6:.1f};"
              f"speedup={r['speedup_batched_over_scan']:.2f};"
              f"bitwise={r['bitwise_batched_eq_scan']}")
    for r in sharded:
        print(f"bench_tridiag_sharded_n{r['n']}_s{r['s']},"
              f"{r['sharded_s_median'] * 1e6:.1f},"
              f"replicated_us={r['replicated_s_median'] * 1e6:.1f};"
              f"lam_bitwise={r['lam_bitwise_sharded_eq_replicated']};"
              f"z_err={r['z_max_abs_err_vs_replicated']:.1e}")

    os.makedirs(args.outdir, exist_ok=True)
    out = os.path.join(args.outdir, "BENCH_tridiag.json")
    payload = {"cells": cells, "sharded": sharded,
               "cores": os.cpu_count() or 1,
               "unroll": _scan_unroll()}
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {out}")

    if args.quick:
        quick_gate(cells, sharded)


def _scan_unroll() -> int:
    from repro.kernels.tridiag_eig.ops import SCAN_UNROLL
    return int(SCAN_UNROLL)


if __name__ == "__main__":
    main()
