"""Paper Table 6: "conventional + modern" (GPU-kernel) pipelines.

Our TPU analogue has two facets:
  1. measured: the KE pipeline with the SYMV routed through the Pallas
     kernel in interpret mode (correctness-true; wall time on CPU reflects
     the Python interpreter, so we report it as a *validation* row, not a
     speed claim) vs the XLA path.
  2. derived: the kernel's roofline win — the one-triangle SYMV moves half
     the HBM bytes of a dense GEMV; per-call modeled times on v5e are
     reported as the derived column (n^2*8 bytes vs n^2*4 at 819 GB/s).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ExplicitC, apply_op
from repro.kernels.symv.ops import symv

from .common import md_problem, time_call

HBM_BW = 819e9


def main(full: bool = False) -> list[str]:
    out = []
    prob = md_problem()
    n = prob.A.shape[0]
    C = prob.A  # any symmetric matrix works for the kernel comparison
    x = jnp.ones((n,), C.dtype)

    jit_xla = jax.jit(lambda A, v: A @ v)
    t_xla, y1 = time_call(jit_xla, C, x)
    out.append(f"table6_symv_xla,{t_xla*1e6:.1f},n={n}")

    t_k, y2 = time_call(lambda: symv(C, x, block=256))
    err = float(jnp.max(jnp.abs(y1 - y2)) / jnp.max(jnp.abs(y1)))
    out.append(f"table6_symv_pallas_interpret,{t_k*1e6:.1f},"
               f"relerr={err:.2e};interpret=1")

    # derived roofline rows (f32 on the TPU target)
    dense_bytes = n * n * 4.0
    tri_bytes = n * n * 4.0 / 2.0
    out.append(f"table6_symv_v5e_model_dense,{dense_bytes/HBM_BW*1e6:.2f},"
               "modeled=bytes/819GBps")
    out.append(f"table6_symv_v5e_model_triangle,{tri_bytes/HBM_BW*1e6:.2f},"
               "modeled=half-bytes (paper's symmetry exploit as HBM win)")
    return out


if __name__ == "__main__":
    jax.config.update("jax_enable_x64", True)
    for line in main():
        print(line)
