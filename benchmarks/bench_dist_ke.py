"""Distributed-KE benchmark: single device vs an 8-host-device mesh.

Runs ``repro.dist.eigensolver.solve_ke_distributed`` on the MD-like
problem twice — on a degenerate (1, 1) mesh and on the (4, 2)
data x model mesh over 8 forced host-platform devices — at the settings
that actually converge (the paper's inverse-pair trick + tol=1e-9 +
block size p=4), and records wall-clock per stage, Lanczos counters,
and the host dispatch count. The Krylov stage is the
communication-avoiding block Lanczos: ONE fused shard_map program per
thick restart, two collectives per p-column block step.

Reading the numbers: on a multi-core host the 8-device run should match
or beat the single device; when the container pins all 8 virtual
devices to fewer physical cores (``cores`` in the artifact), the ratio
measures time-sharing overhead, not the algorithm — the
hardware-independent invariants (convergence, dispatch budget, absolute
wall-clock) are what ``--quick`` gates on unconditionally.

Standalone (sets its own XLA flags, so run it directly, not via run.py):

    PYTHONPATH=src python -m benchmarks.bench_dist_ke [--n 128 --s 4]
    PYTHONPATH=src python -m benchmarks.bench_dist_ke --quick  # CI gate

Emits ``artifacts/BENCH_dist_ke.json`` next to the other benchmark tables
and prints the usual ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + " --xla_force_host_platform_device_count=8").strip()

import argparse  # noqa: E402
import json      # noqa: E402
import time      # noqa: E402

import jax       # noqa: E402

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

#: absolute wall-clock ceiling for the 8-device quick gate (seconds). The
#: pre-rework solver (unconverged at 300 restarts, 3 dispatches/restart)
#: took ~23s here; the fused block driver converges in a few restarts and
#: finishes in well under a second even on a single-core container.
QUICK_WALL_CEILING_S = 5.0


def bench_mesh(mesh_shape, n: int, s: int, m: int, p: int,
               filter_degree: int, tol: float, repeats: int) -> dict:
    from repro.data.problems import md_like
    from repro.dist import eigensolver as de

    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    prob = md_like(n)
    label = "x".join(str(d) for d in mesh_shape)

    def run():
        # the paper's MD trick: solve the inverse pair (B, A) for its
        # largest (well-separated) eigenpairs — the setting at which the
        # log-spaced MD spectrum converges in a handful of restarts
        return de.solve_ke_distributed(
            mesh, prob.A, prob.B, s, m=m, tol=tol, max_restarts=300,
            p=p, filter_degree=filter_degree, invert=True,
            return_info=True)

    evals, X, info = run()   # warmup compiles every stage
    walls, dispatches = [], []
    for _ in range(repeats):
        de.reset_dispatch_count()
        t0 = time.perf_counter()
        evals, X, info = run()
        walls.append(time.perf_counter() - t0)
        dispatches.append(de.dispatch_count())
    err = float(np.max(np.abs(np.asarray(evals)
                              - np.asarray(prob.exact_evals[:s]))))
    return {
        "mesh": label,
        "n_devices": int(np.prod(mesh_shape)),
        "n": n, "s": s, "m": m,
        "krylov_block": int(info["p"]),
        "filter_degree": int(info["filter_degree"]),
        "invert": True,
        "tol": tol,
        "wall_s_median": sorted(walls)[len(walls) // 2],
        "wall_s_all": walls,
        "stage_times_s": {k: round(v, 5)
                          for k, v in info["stage_times"].items()},
        "n_matvec": info["n_matvec"],
        "n_restart": info["n_restart"],
        "n_dispatch": max(dispatches),
        "converged": info["converged"],
        "fused": info["fused"],
        "max_abs_eval_error": err,
    }


def quick_gate(recs: list, cores: int) -> None:
    """The CI acceptance gate: hardware-independent invariants always, the
    strict 8-device >= 1-device throughput only when the host actually has
    a core per device (a single-core container time-shares the mesh, so a
    wall-clock speedup there is physically impossible — the artifact
    records ``cores`` and ``t8_over_t1`` so the regression is auditable
    either way)."""
    for r in recs:
        assert r["converged"], f"KE did not converge on mesh {r['mesh']}: {r}"
        assert r["max_abs_eval_error"] < 1e-8, r
        # fused dispatch discipline: one program per restart (+ prep)
        assert r["n_dispatch"] <= r["n_restart"] + 2, r
    t1 = next(r for r in recs if r["n_devices"] == 1)["wall_s_median"]
    t8 = next(r for r in recs if r["n_devices"] > 1)["wall_s_median"]
    assert t8 < QUICK_WALL_CEILING_S, (
        f"8-device KE took {t8:.2f}s (> {QUICK_WALL_CEILING_S}s ceiling)")
    n_dev = max(r["n_devices"] for r in recs)
    if cores >= n_dev:
        assert t8 <= t1, (
            f"8-device run slower than single device on a "
            f"{cores}-core host: t8={t8:.3f}s t1={t1:.3f}s")
    print(f"quick gate OK (cores={cores}, t8/t1={t8 / t1:.2f})")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=128)
    ap.add_argument("--s", type=int, default=4)
    ap.add_argument("--m", type=int, default=24)
    ap.add_argument("--p", type=int, default=4,
                    help="Lanczos block size (s-step width)")
    ap.add_argument("--filter-degree", type=int, default=0)
    ap.add_argument("--tol", type=float, default=1e-9)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--quick", action="store_true",
                    help="assert the CI acceptance gate after measuring")
    ap.add_argument("--outdir", default="artifacts")
    args = ap.parse_args()

    recs = [bench_mesh((1, 1), args.n, args.s, args.m, args.p,
                       args.filter_degree, args.tol, args.repeats),
            bench_mesh((4, 2), args.n, args.s, args.m, args.p,
                       args.filter_degree, args.tol, args.repeats)]
    cores = os.cpu_count() or 1
    t1 = next(r for r in recs if r["n_devices"] == 1)["wall_s_median"]
    t8 = next(r for r in recs if r["n_devices"] > 1)["wall_s_median"]

    print("name,us_per_call,derived")
    for r in recs:
        print(f"bench_dist_ke_{r['mesh']},{r['wall_s_median'] * 1e6:.1f},"
              f"n_matvec={r['n_matvec']};n_restart={r['n_restart']};"
              f"n_dispatch={r['n_dispatch']};"
              f"converged={r['converged']};"
              f"eval_err={r['max_abs_eval_error']:.3e}")

    os.makedirs(args.outdir, exist_ok=True)
    out = os.path.join(args.outdir, "BENCH_dist_ke.json")
    payload = {"records": recs, "cores": cores,
               "t8_over_t1": t8 / t1}
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {out}")

    if args.quick:
        quick_gate(recs, cores)


if __name__ == "__main__":
    main()
