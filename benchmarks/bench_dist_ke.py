"""Distributed-KE benchmark: single device vs an 8-host-device mesh.

Runs ``repro.dist.eigensolver.solve_ke_distributed`` on the MD-like
problem twice — on a degenerate (1, 1) mesh and on the (4, 2)
data x model mesh over 8 forced host-platform devices — and records
wall-clock per stage plus Lanczos matvec counts. On a CPU host the
8-way run measures partitioning *overhead* (no real parallel FLOPs);
the point of the table is collective/bookkeeping cost and the invariant
that the distributed solver does the same number of matvecs and returns
the same spectrum.

Standalone (sets its own XLA flags, so run it directly, not via run.py):

    PYTHONPATH=src python -m benchmarks.bench_dist_ke [--n 128 --s 4]

Emits ``artifacts/BENCH_dist_ke.json`` next to the other benchmark tables
and prints the usual ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + " --xla_force_host_platform_device_count=8").strip()

import argparse  # noqa: E402
import json      # noqa: E402
import time      # noqa: E402

import jax       # noqa: E402

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402


def bench_mesh(mesh_shape, n: int, s: int, m: int, repeats: int) -> dict:
    from repro.data.problems import md_like
    from repro.dist.eigensolver import solve_ke_distributed

    mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    prob = md_like(n)
    label = "x".join(str(d) for d in mesh_shape)

    # warmup compiles every stage; timed repeats measure steady state
    evals, X, info = solve_ke_distributed(mesh, prob.A, prob.B, s, m=m,
                                          max_restarts=300,
                                          return_info=True)
    walls = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        evals, X, info = solve_ke_distributed(mesh, prob.A, prob.B, s, m=m,
                                              max_restarts=300,
                                              return_info=True)
        walls.append(time.perf_counter() - t0)
    err = float(np.max(np.abs(np.asarray(evals)
                              - np.asarray(prob.exact_evals[:s]))))
    return {
        "mesh": label,
        "n_devices": int(np.prod(mesh_shape)),
        "n": n, "s": s, "m": m,
        "wall_s_median": sorted(walls)[len(walls) // 2],
        "wall_s_all": walls,
        "stage_times_s": {k: round(v, 5)
                          for k, v in info["stage_times"].items()},
        "n_matvec": info["n_matvec"],
        "n_restart": info["n_restart"],
        "converged": info["converged"],
        "max_abs_eval_error": err,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=128)
    ap.add_argument("--s", type=int, default=4)
    ap.add_argument("--m", type=int, default=24)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--outdir", default="artifacts")
    args = ap.parse_args()

    recs = [bench_mesh((1, 1), args.n, args.s, args.m, args.repeats),
            bench_mesh((4, 2), args.n, args.s, args.m, args.repeats)]

    print("name,us_per_call,derived")
    for r in recs:
        print(f"bench_dist_ke_{r['mesh']},{r['wall_s_median'] * 1e6:.1f},"
              f"n_matvec={r['n_matvec']};n_restart={r['n_restart']};"
              f"eval_err={r['max_abs_eval_error']:.3e}")

    os.makedirs(args.outdir, exist_ok=True)
    out = os.path.join(args.outdir, "BENCH_dist_ke.json")
    with open(out, "w") as f:
        json.dump(recs, f, indent=1)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
