"""Paper Table 4: "task-parallel libraries" (PLASMA / lf+SM) vs LAPACK for
GS1/GS2. Our analogue: XLA's fused monolithic factorization vs the blocked
right-looking algorithms (the tile decomposition PLASMA schedules; XLA fuses
within blocks). Reports both, plus the DSYGST-style n^3 symmetric GS2 vs
the paper's preferred 2n^3 two-TRSM path."""
from __future__ import annotations

import jax

from repro.core import (cholesky_blocked, cholesky_upper, to_standard_sygst,
                        to_standard_two_trsm)

from .common import dft_problem, md_problem, time_call

_jit_chol = jax.jit(cholesky_upper)
_jit_chol_b = jax.jit(cholesky_blocked, static_argnames=("block",))
_jit_gs2_t = jax.jit(to_standard_two_trsm)
_jit_gs2_s = jax.jit(to_standard_sygst, static_argnames=("block",))


def main(full: bool = False) -> list[str]:
    out = []
    for name, prob in [("md", md_problem()), ("dft", dft_problem())]:
        n = prob.A.shape[0]
        out.append(f"# table4 {name}: n={n}")
        t, U = time_call(_jit_chol, prob.B)
        out.append(f"table4_{name}_GS1_fused,{t*1e6:.1f},n={n}")
        t, _ = time_call(_jit_chol_b, prob.B, block=128)
        out.append(f"table4_{name}_GS1_blocked128,{t*1e6:.1f},n={n}")
        t, _ = time_call(_jit_gs2_t, prob.A, U)
        out.append(f"table4_{name}_GS2_two_trsm,{t*1e6:.1f},flops=2n^3")
        t, _ = time_call(_jit_gs2_s, prob.A, U, block=128)
        out.append(f"table4_{name}_GS2_sygst,{t*1e6:.1f},flops=n^3")
    return out


if __name__ == "__main__":
    jax.config.update("jax_enable_x64", True)
    for line in main():
        print(line)
