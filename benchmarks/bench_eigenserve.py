"""Eigenserve benchmark: batched serving engine vs a sequential `solve` loop.

For each shape bucket, submits a fixed request stream twice:

  * sequential — one ``core.gsyeig.solve`` call per pencil (the repo's only
    serving mode before the engine existed), and
  * engine     — the same pencils through ``serve.eigen_engine.EigenEngine``
    (one vmapped ``solve_batched`` dispatch per full bucket).

Both paths are warmed first so the comparison is steady-state serving
throughput, not compile time. MD buckets exercise the paper's MD trick for
the Krylov variant (``invert=True`` — the direct smallest end converges too
slowly to serve, exactly as the accuracy harness documents).

    PYTHONPATH=src python -m benchmarks.bench_eigenserve [--batch 8]

Emits ``artifacts/BENCH_eigenserve.json``: per-bucket throughput for both
modes and the speedup, plus the usual CSV rows.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402


def _problems(kind: str, n: int, batch: int):
    from repro.data.problems import dft_like, md_like
    gen = md_like if kind == "md" else dft_like
    return [gen(n, key=jax.random.PRNGKey(1000 + i)) for i in range(batch)]


def bench_bucket(kind: str, n: int, s: int, variant: str, batch: int,
                 band_width: int, max_restarts: int, repeats: int) -> dict:
    from repro.core import solve
    from repro.serve.eigen_engine import EigenEngine

    probs = _problems(kind, n, batch)
    invert = kind == "md" and variant in ("KE", "KI")
    kw = dict(variant=variant, which="smallest", invert=invert,
              band_width=band_width, max_restarts=max_restarts)

    def run_sequential():
        out = [solve(p.A, p.B, s, **kw) for p in probs]
        jax.block_until_ready(out[-1].evals)
        return out

    def run_engine():
        eng = EigenEngine(slots=batch, bucket_shapes=[n], variant=variant,
                          band_width=band_width, max_restarts=max_restarts)
        for p in probs:
            eng.submit(p.A, p.B, s, invert=invert)
            eng.tick()
        return eng.run_until_drained(flush=True)

    # warm both paths (compile + populate the shape-bucket pipeline cache)
    seq_out = run_sequential()
    eng_out = run_engine()

    # correctness gate: both modes must hit the generator's exact spectrum
    seq_err = float(max(
        np.max(np.abs(np.asarray(r.evals) - np.asarray(pr.exact_evals[:s])))
        for r, pr in zip(seq_out, probs)))
    eng_err = float(max(
        np.max(np.abs(r.evals - np.asarray(pr.exact_evals[:s])))
        for r, pr in zip(sorted(eng_out, key=lambda r: r.uid), probs)))
    assert max(seq_err, eng_err) < 1e-6, \
        f"{kind}/n{n}/{variant}: wrong spectrum (seq {seq_err:.2e}, " \
        f"engine {eng_err:.2e}) — throughput numbers would be meaningless"

    t_seq, t_eng = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        run_sequential()
        t_seq.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        run_engine()
        t_eng.append(time.perf_counter() - t0)
    seq_s = sorted(t_seq)[len(t_seq) // 2]
    eng_s = sorted(t_eng)[len(t_eng) // 2]

    return {
        "bucket": f"{kind}_n{n}_s{s}_{variant}",
        "workload": kind, "n": n, "s": s, "variant": variant,
        "batch": batch, "invert": invert,
        "sequential_s": seq_s,
        "sequential_problems_per_s": batch / seq_s,
        "engine_s": eng_s,
        "engine_problems_per_s": batch / eng_s,
        "speedup": seq_s / eng_s,
        "max_abs_eval_error_sequential": seq_err,
        "max_abs_eval_error_engine": eng_err,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8,
                    help="bucket seats = pencils per batched dispatch")
    ap.add_argument("--s", type=int, default=4)
    ap.add_argument("--band-width", type=int, default=4)
    ap.add_argument("--max-restarts", type=int, default=200)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--outdir", default="artifacts")
    args = ap.parse_args()

    buckets = [
        ("md", 48, "TD"),
        ("md", 48, "KE"),
        ("dft", 64, "TD"),
    ]
    recs = [bench_bucket(kind, n, args.s, variant, args.batch,
                         args.band_width, args.max_restarts, args.repeats)
            for kind, n, variant in buckets]

    print("name,us_per_call,derived")
    for r in recs:
        print(f"bench_eigenserve_{r['bucket']},{r['engine_s'] * 1e6:.1f},"
              f"seq={r['sequential_problems_per_s']:.1f}/s;"
              f"engine={r['engine_problems_per_s']:.1f}/s;"
              f"speedup={r['speedup']:.2f}x")

    payload = {
        "batch": args.batch,
        "buckets": recs,
        "any_bucket_faster": any(r["speedup"] > 1.0 for r in recs),
    }
    os.makedirs(args.outdir, exist_ok=True)
    out = os.path.join(args.outdir, "BENCH_eigenserve.json")
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {out}")
    assert payload["any_bucket_faster"], \
        "batched engine did not beat the sequential loop on any bucket"


if __name__ == "__main__":
    main()
