"""Eigenserve benchmark: batched serving engine vs a sequential `solve` loop.

For each shape bucket, submits a fixed request stream twice:

  * sequential — one ``core.gsyeig.solve`` call per pencil (the repo's only
    serving mode before the engine existed), and
  * engine     — the same pencils through ``serve.eigen_engine.EigenEngine``
    (one vmapped ``solve_batched`` dispatch per full bucket).

Both paths are warmed first so the comparison is steady-state serving
throughput, not compile time. MD buckets exercise the paper's MD trick for
the Krylov variant (``invert=True`` — the direct smallest end converges too
slowly to serve, exactly as the accuracy harness documents).

    PYTHONPATH=src python -m benchmarks.bench_eigenserve [--batch 8]

Emits ``artifacts/BENCH_eigenserve.json``: per-bucket throughput for both
modes and the speedup, plus the usual CSV rows.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402


def _problems(kind: str, n: int, batch: int):
    from repro.data.problems import dft_like, md_like
    gen = md_like if kind == "md" else dft_like
    return [gen(n, key=jax.random.PRNGKey(1000 + i)) for i in range(batch)]


def bench_bucket(kind: str, n: int, s: int, variant: str, batch: int,
                 band_width: int, max_restarts: int, repeats: int) -> dict:
    from repro.core import solve
    from repro.serve.eigen_engine import EigenEngine

    probs = _problems(kind, n, batch)
    invert = kind == "md" and variant in ("KE", "KI")
    kw = dict(variant=variant, which="smallest", invert=invert,
              band_width=band_width, max_restarts=max_restarts)

    def run_sequential():
        out = [solve(p.A, p.B, s, **kw) for p in probs]
        jax.block_until_ready(out[-1].evals)
        return out

    def run_engine():
        eng = EigenEngine(slots=batch, bucket_shapes=[n], variant=variant,
                          band_width=band_width, max_restarts=max_restarts)
        for p in probs:
            eng.submit(p.A, p.B, s, invert=invert)
            eng.tick()
        return eng.run_until_drained(flush=True)

    # warm both paths (compile + populate the shape-bucket pipeline cache)
    seq_out = run_sequential()
    eng_out = run_engine()

    # correctness gate: both modes must hit the generator's exact spectrum
    seq_err = float(max(
        np.max(np.abs(np.asarray(r.evals) - np.asarray(pr.exact_evals[:s])))
        for r, pr in zip(seq_out, probs)))
    eng_err = float(max(
        np.max(np.abs(r.evals - np.asarray(pr.exact_evals[:s])))
        for r, pr in zip(sorted(eng_out, key=lambda r: r.uid), probs)))
    assert max(seq_err, eng_err) < 1e-6, \
        f"{kind}/n{n}/{variant}: wrong spectrum (seq {seq_err:.2e}, " \
        f"engine {eng_err:.2e}) — throughput numbers would be meaningless"

    t_seq, t_eng = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        run_sequential()
        t_seq.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        run_engine()
        t_eng.append(time.perf_counter() - t0)
    seq_s = sorted(t_seq)[len(t_seq) // 2]
    eng_s = sorted(t_eng)[len(t_eng) // 2]

    return {
        "bucket": f"{kind}_n{n}_s{s}_{variant}",
        "workload": kind, "n": n, "s": s, "variant": variant,
        "batch": batch, "invert": invert,
        "sequential_s": seq_s,
        "sequential_problems_per_s": batch / seq_s,
        "engine_s": eng_s,
        "engine_problems_per_s": batch / eng_s,
        "speedup": seq_s / eng_s,
        "max_abs_eval_error_sequential": seq_err,
        "max_abs_eval_error_engine": eng_err,
    }


def bench_chaos(s: int, batch: int, band_width: int, max_restarts: int,
                repeats: int) -> dict:
    """Fault-injected bursty trace vs the same trace without faults.

    The chaos trace replaces a slice of a healthy MD request stream with
    non-SPD pencils (same total length, same bucket packing); the engine
    (``on_failure='recover'``) must quarantine and dead-letter the
    poisoned lanes WITHOUT sinking the healthy traffic: the gate is
    healthy-request throughput within 20% of the clean run, with every
    submission accounted for (done + dead letters, no silent drops)."""
    from repro.resilience.faults import nonspd_pencil
    from repro.serve.eigen_engine import EigenEngine

    n = 64
    n_healthy, n_poisoned = 8 * batch, max(2, batch // 2)
    total = n_healthy + n_poisoned
    healthy = _problems("md", n, total)
    poisoned = [tuple(map(jax.numpy.asarray, nonspd_pencil(n, seed=i)))
                for i in range(n_poisoned)]
    # poisoned requests land spread across the stream (bursty-but-not-
    # adjacent), displacing — not inserting next to — healthy ones, so
    # the clean and chaos traces pack into identical bucket sequences
    stride = total // n_poisoned
    poison_at = {1 + i * stride: i for i in range(n_poisoned)}

    def trace(with_faults: bool):
        reqs = []
        for j, p in enumerate(healthy):
            if with_faults and j in poison_at:
                A, B = poisoned[poison_at[j]]
                reqs.append((A, B, False))
            else:
                reqs.append((p.A, p.B, True))
        return reqs

    def run(reqs):
        eng = EigenEngine(slots=batch, bucket_shapes=[n], variant="TD",
                          band_width=band_width,
                          max_restarts=max_restarts,
                          on_failure="recover", max_retries=1)
        uids = [eng.submit(A, B, s) for A, B, _ in reqs]
        for _ in uids:
            eng.tick()
        done = eng.run_until_drained(flush=True)
        return eng, uids, done

    run(trace(False))                     # warm the bucket pipeline
    run(trace(True))                      # warm the quarantine solve path

    t_clean, t_chaos = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        run(trace(False))
        t_clean.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        eng, uids, done = run(trace(True))
        t_chaos.append(time.perf_counter() - t0)

    # accounting on the last chaos run: nothing silently dropped, every
    # poisoned lane carries its diagnosis
    retired = {r.uid for r in done} | {r.uid for r in eng.dead_letters}
    assert retired == set(uids), "silent drop: submitted != retired"
    assert len(eng.dead_letters) == n_poisoned, \
        f"{len(eng.dead_letters)} dead letters != {n_poisoned} injected"
    assert all(r.info["dead_letter"]["reason"] == "cholesky_breakdown"
               for r in eng.dead_letters)
    assert len(done) == n_healthy
    uid_to_prob = {uid: healthy[j] for j, uid in enumerate(uids)
                   if j not in poison_at}
    healthy_err = float(max(
        np.max(np.abs(r.evals
                      - np.asarray(uid_to_prob[r.uid].exact_evals[:s])))
        for r in done))
    assert healthy_err < 1e-6, f"chaos run corrupted healthy lanes: " \
                               f"{healthy_err:.2e}"

    # both runs submit `total` requests; the gate compares throughput of
    # the requests that retire healthy (clean: all of them; chaos: all
    # but the dead-lettered poison)
    clean_s = sorted(t_clean)[len(t_clean) // 2]
    chaos_s = sorted(t_chaos)[len(t_chaos) // 2]
    clean_tput = total / clean_s
    chaos_tput = n_healthy / chaos_s
    ratio = chaos_tput / clean_tput
    assert ratio >= 0.8, \
        f"chaos sank healthy throughput to {ratio:.2f}x of clean " \
        f"({chaos_tput:.1f}/s vs {clean_tput:.1f}/s)"

    return {
        "bucket": f"chaos_md_n{n}_s{s}_TD",
        "n": n, "s": s, "batch": batch,
        "n_requests": total,
        "n_healthy": n_healthy, "n_poisoned": n_poisoned,
        "clean_s": clean_s, "chaos_s": chaos_s,
        "clean_healthy_per_s": clean_tput,
        "chaos_healthy_per_s": chaos_tput,
        "healthy_throughput_ratio": ratio,
        "dead_letters": n_poisoned,
        "max_abs_eval_error_healthy": healthy_err,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8,
                    help="bucket seats = pencils per batched dispatch")
    ap.add_argument("--s", type=int, default=4)
    ap.add_argument("--band-width", type=int, default=4)
    ap.add_argument("--max-restarts", type=int, default=200)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--chaos", action="store_true",
                    help="additionally run the fault-injected bursty "
                         "trace (healthy-throughput gate)")
    ap.add_argument("--chaos-only", action="store_true",
                    help="only the chaos trace (the nightly chaos lane); "
                         "does not rewrite the artifact's clean buckets")
    ap.add_argument("--outdir", default="artifacts")
    args = ap.parse_args()

    recs = []
    if not args.chaos_only:
        buckets = [
            ("md", 48, "TD"),
            ("md", 48, "KE"),
            ("dft", 64, "TD"),
        ]
        recs = [bench_bucket(kind, n, args.s, variant, args.batch,
                             args.band_width, args.max_restarts,
                             args.repeats)
                for kind, n, variant in buckets]

    chaos_rec = None
    if args.chaos or args.chaos_only:
        chaos_rec = bench_chaos(args.s, args.batch, args.band_width,
                                args.max_restarts, args.repeats)

    print("name,us_per_call,derived")
    for r in recs:
        print(f"bench_eigenserve_{r['bucket']},{r['engine_s'] * 1e6:.1f},"
              f"seq={r['sequential_problems_per_s']:.1f}/s;"
              f"engine={r['engine_problems_per_s']:.1f}/s;"
              f"speedup={r['speedup']:.2f}x")
    if chaos_rec:
        print(f"bench_eigenserve_{chaos_rec['bucket']},"
              f"{chaos_rec['chaos_s'] * 1e6:.1f},"
              f"clean={chaos_rec['clean_healthy_per_s']:.1f}/s;"
              f"chaos={chaos_rec['chaos_healthy_per_s']:.1f}/s;"
              f"ratio={chaos_rec['healthy_throughput_ratio']:.2f}")

    os.makedirs(args.outdir, exist_ok=True)
    out = os.path.join(args.outdir, "BENCH_eigenserve.json")
    if args.chaos_only:
        # nightly chaos lane: fold the chaos record into the existing
        # artifact without re-benching the clean buckets
        payload = {}
        if os.path.exists(out):
            with open(out) as f:
                payload = json.load(f)
        payload["chaos"] = chaos_rec
    else:
        payload = {
            "batch": args.batch,
            "buckets": recs,
            "any_bucket_faster": any(r["speedup"] > 1.0 for r in recs),
        }
        if chaos_rec:
            payload["chaos"] = chaos_rec
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {out}")
    if not args.chaos_only:
        assert payload["any_bucket_faster"], \
            "batched engine did not beat the sequential loop on any bucket"


if __name__ == "__main__":
    main()
