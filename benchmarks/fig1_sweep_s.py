"""Paper Figures 1/2: solver total time as a function of s (the number of
wanted eigenpairs). Reproduces the crossover the paper reports: Krylov
variants win for small s but their cost grows quickly with s (iterations +
re-orthogonalization + restart costs), while TD's growth is the mild n^2 s
back-transform term."""
from __future__ import annotations

import jax

from repro.core import solve

from .common import BAND_W, md_problem


def main(full: bool = False) -> list[str]:
    out = []
    prob = md_problem()
    n = prob.A.shape[0]
    sweep = (4, 8, 16, 32) if not full else (50, 100, 200, 400)
    out.append(f"# fig1: n={n}, total seconds vs s")
    out.append("s,TD,KE,KI")
    for s in sweep:
        row = [str(s)]
        for variant in ("TD", "KE", "KI"):
            invert = variant in ("KE", "KI")
            res = solve(prob.A, prob.B, s, variant=variant, invert=invert,
                        band_width=BAND_W, max_restarts=150)
            res = solve(prob.A, prob.B, s, variant=variant, invert=invert,
                        band_width=BAND_W, max_restarts=150)  # warm
            row.append(f"{res.stage_times['Tot.']:.3f}")
            out.append(f"fig1_s{s}_{variant},"
                       f"{res.stage_times['Tot.'] * 1e6:.1f},"
                       f"matvecs={res.info.get('n_matvec', 0)}")
        out.append("# " + ",".join(row))
    return out


if __name__ == "__main__":
    jax.config.update("jax_enable_x64", True)
    for line in main():
        print(line)
