"""Variant race: distributed TT vs distributed KE, per stage, per problem.

Runs both distributed pipelines (``repro.dist.eigensolver``) on the two
generators from ``data/problems.py`` — ``md_like`` (separated spectrum,
Krylov-friendly) and ``dft_like`` (clustered valence band, reduction-
friendly) — over an 8-host-device (4, 2) data x model mesh, and records
per-stage wall-clock next to the cost model's predictions and the
router's pick. On a CPU host the absolute times measure partitioning
overhead, not parallel speedup; the payload to read is (a) the per-stage
*shape* of TT vs KE and (b) whether ``choose_variant`` agrees with the
measured winner.

Standalone (sets its own XLA flags, so run it directly, not via run.py):

    PYTHONPATH=src python -m benchmarks.bench_variant_race [--n 96 --s 4]

Emits ``artifacts/BENCH_variant_race.json`` and prints the usual
``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + " --xla_force_host_platform_device_count=8").strip()

import argparse  # noqa: E402
import json      # noqa: E402
import time      # noqa: E402

import jax       # noqa: E402

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402


def bench_variant(variant: str, prob, s: int, band_width: int, m: int,
                  mesh, repeats: int, ke_kwargs: dict) -> dict:
    from repro.dist.eigensolver import solve_ke_distributed, solve_tt_distributed

    def run():
        if variant == "TT":
            return solve_tt_distributed(mesh, prob.A, prob.B, s,
                                        band_width=band_width,
                                        return_info=True)
        # the settings at which the block driver actually converges:
        # tol=1e-9 (the machine-eps default criterion is unreachable on
        # these spectra), the inverse-pair trick on the MD generator, a
        # Chebyshev start filter on the clustered DFT one
        return solve_ke_distributed(mesh, prob.A, prob.B, s, m=m,
                                    max_restarts=300, return_info=True,
                                    **ke_kwargs)

    evals, X, info = run()           # warmup: compiles every stage
    walls, stage_runs = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        evals, X, info = run()
        walls.append(time.perf_counter() - t0)
        stage_runs.append(info["stage_times"])
    # median wall; per-stage medians across repeats
    stages = {k: sorted(r[k] for r in stage_runs)[len(stage_runs) // 2]
              for k in stage_runs[0]}
    err = float(np.max(np.abs(np.asarray(evals)
                              - np.asarray(prob.exact_evals[:s]))))
    rec = {
        "variant": variant,
        "problem": prob.name,
        "wall_s_median": sorted(walls)[len(walls) // 2],
        "stage_times_s": {k: round(v, 5) for k, v in stages.items()},
        "max_abs_eval_error": err,
    }
    if variant == "KE":
        rec["krylov_block"] = int(info["p"])
        rec["filter_degree"] = int(info["filter_degree"])
        rec["invert"] = bool(ke_kwargs.get("invert", False))
    for k in ("n_matvec", "n_restart", "converged", "band_width"):
        if k in info:
            rec[k] = info[k]
    return rec


def main() -> None:
    from repro.analysis.variant_model import choose_variant, predict_stage_times
    from repro.data.problems import dft_like, md_like

    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=128)
    ap.add_argument("--s", type=int, default=4)
    ap.add_argument("--m", type=int, default=48)
    ap.add_argument("--band-width", type=int, default=8)
    ap.add_argument("--p", type=int, default=4,
                    help="Lanczos block size (s-step width)")
    ap.add_argument("--filter-degree", type=int, default=16,
                    help="Chebyshev start-filter degree (clustered problem)")
    ap.add_argument("--tol", type=float, default=1e-9)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--outdir", default="artifacts")
    args = ap.parse_args()

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    out = {"n": args.n, "s": args.s, "mesh": "4x2",
           "n_devices": jax.device_count(), "races": []}
    p_blk = args.p
    for gen, clustered in ((md_like, False), (dft_like, True)):
        prob = gen(args.n)
        # per-problem KE settings that converge (see bench_variant)
        ke_kwargs = ({"tol": args.tol, "p": p_blk, "invert": True}
                     if not clustered else
                     {"tol": args.tol, "p": p_blk,
                      "filter_degree": args.filter_degree})
        choice = choose_variant(args.n, args.s, band_width=args.band_width,
                                m=args.m, clustered=clustered,
                                mesh_shape=(4, 2), krylov_block=p_blk,
                                filter_degree=ke_kwargs.get(
                                    "filter_degree", 0))
        race = {"problem": prob.name, "router": choice.as_json_dict(),
                "ke_settings": {k: v for k, v in ke_kwargs.items()},
                "predicted_stage_times_s": {
                    v: predict_stage_times(v, args.n, args.s,
                                           band_width=args.band_width,
                                           m=args.m, clustered=clustered,
                                           mesh_shape=(4, 2))
                    for v in ("TT", "KE")},
                "measured": []}
        for variant in ("TT", "KE"):
            race["measured"].append(
                bench_variant(variant, prob, args.s, args.band_width,
                              args.m, mesh, args.repeats, ke_kwargs))
        # an unconverged run (KE retiring at max_restarts) is NOT a winner:
        # it returned approximations, so it only competes if every variant
        # failed to converge. The artifact keeps both the eligibility list
        # and the naive all-comers timing winner for transparency.
        unconverged = [r["variant"] for r in race["measured"]
                       if not r.get("converged", True)]
        eligible = [r for r in race["measured"]
                    if r.get("converged", True)] or race["measured"]
        measured_winner = min(eligible,
                              key=lambda r: r["wall_s_median"])["variant"]
        race["unconverged"] = unconverged
        race["fastest_any"] = min(race["measured"],
                                  key=lambda r: r["wall_s_median"])["variant"]
        race["measured_winner"] = measured_winner
        race["router_agrees"] = measured_winner == choice.variant
        out["races"].append(race)

    print("name,us_per_call,derived")
    for race in out["races"]:
        for r in race["measured"]:
            conv = r.get("converged", True)
            print(f"bench_variant_race_{race['problem']}_{r['variant']},"
                  f"{r['wall_s_median'] * 1e6:.1f},"
                  f"eval_err={r['max_abs_eval_error']:.3e}"
                  + ("" if conv else ";UNCONVERGED"))
        print(f"bench_variant_race_{race['problem']}_router,0.0,"
              f"pick={race['router']['variant']};"
              f"measured={race['measured_winner']};"
              f"agrees={race['router_agrees']}"
              + (f";unconverged={'+'.join(race['unconverged'])}"
                 if race["unconverged"] else ""))

    os.makedirs(args.outdir, exist_ok=True)
    path = os.path.join(args.outdir, "BENCH_variant_race.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
