"""TT1/TT2 shootout: fused one-program sweeps vs their dispatch-heavy pasts.

Measures, per (n, w):

  * TT1 stepwise — ``reduce_to_band_stepwise`` (the old per-panel HOST
    loop: one slice + panel-QR + trailing-update + Q1 dispatch per panel)
  * TT1 full / TT1 window — the fused one-program sweep with full-(n, n)
    masked updates (``n_chunks=1``) vs the shrinking trailing-window ladder
  * TT1 auto — the production default (``default_n_chunks`` picks the
    ladder by size; cells where it picks ``n_chunks=1`` reuse the ``full``
    measurement, so ``speedup_tt1`` is exactly 1.0 there by construction)
  * TT2 dense   — ``band_to_tridiag_dense`` (the old one-rotation-per-
    dispatch implementation on full (n, n) storage, full explicit Q)
  * TT2 band    — ``band_chase`` + ``accumulate_q2`` (packed (w+1, n)
    storage, wavefront-batched rotations, blocked Q2 replay) — the
    apples-to-apples explicit-Q comparison
  * TT2 chase / TT4 replay — the production split: chase only, then the
    rotation stream replayed over an (n, s) Ritz slab (``apply_q2``)
  * old/new full TT — (TT1 stepwise + TT2 dense) vs (TT1 auto +
    chase+replay)

How to read the TT1 columns in ``BENCH_sbr.json``: ``tt1_stepwise_s`` vs
``tt1_auto_s`` is the dispatch story (``speedup_tt1_fused``, the
one-program win); ``tt1_full_s`` vs ``tt1_auto_s`` is the window-ladder
story (``speedup_tt1``, must be >= 1.0 in every cell since the ladder is
auto-sized); ``tt1_n_chunks`` records what the auto-sizer picked.

Standalone:

    PYTHONPATH=src python -m benchmarks.bench_sbr [--quick]

``--quick`` runs the single CI gate cell (n=256, w=8) and EXITS NONZERO if
(a) the band-storage TT2 is not faster than the dense-storage chase, or
(b) the fused one-program TT1 sweep is not faster than the stepwise
per-panel host loop — the nightly guards against silent fallback /
dispatch regressions. The full sweep (n in {128, 256, 512} x w in {8, 32})
emits ``artifacts/BENCH_sbr.json`` and the usual
``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402


def _median_time(fn, *args, repeats: int = 3):
    out = fn(*args)
    jax.block_until_ready(out)       # warmup/compile
    walls = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        walls.append(time.perf_counter() - t0)
    return sorted(walls)[len(walls) // 2], out


def bench_cell(n: int, w: int, s: int, repeats: int, dense_repeats: int):
    from repro.core.band_storage import unpack_band
    from repro.core.sbr import (_n_panels, accumulate_q2, apply_q2,
                                band_chase, band_to_tridiag_dense,
                                default_n_chunks, reduce_to_band,
                                reduce_to_band_stepwise)

    key = jax.random.PRNGKey(1111 * n + w)
    M = jax.random.normal(key, (n, n), jnp.float64)
    C = 0.5 * (M + M.T)
    Z = jax.random.normal(jax.random.fold_in(key, 1), (n, s), jnp.float64)

    n_chunks = default_n_chunks(n, w)
    ladder = max(min(4, _n_panels(n, w)), 1)  # the ladder, threshold-free
    t_tt1_full, band = _median_time(
        lambda c: reduce_to_band(c, w=w, n_chunks=1), C, repeats=repeats)
    t_tt1_win, _ = _median_time(
        lambda c: reduce_to_band(c, w=w, n_chunks=ladder), C,
        repeats=repeats)
    # the production default: the auto-sizer picks either n_chunks=1 (the
    # 'full' program) or min(4, n_panels) (the 'window' program), so reuse
    # the matching measurement — re-timing an identical program would only
    # record noise
    t_tt1_auto = t_tt1_full if n_chunks == 1 else t_tt1_win
    t_tt1_step, _ = _median_time(
        lambda c: reduce_to_band_stepwise(c, w=w), C,
        repeats=min(repeats, 2))

    Wd = unpack_band(band.Wb)
    t_dense, ref = _median_time(
        lambda wd, q: band_to_tridiag_dense(wd, q, w), Wd, band.Q1,
        repeats=dense_repeats)

    t_chase, chase = _median_time(
        lambda wb: band_chase(wb, w), band.Wb, repeats=repeats)
    t_accum, Qfull = _median_time(
        lambda ch, q: accumulate_q2(ch, q, w), chase, band.Q1,
        repeats=repeats)
    t_apply, _ = _median_time(
        lambda ch, z: apply_q2(ch, z, w), chase, Z, repeats=repeats)

    # sanity: the packed chase must reproduce the dense reference
    err_d = float(jnp.max(jnp.abs(ref.d - chase.d)))
    err_q = float(jnp.max(jnp.abs(ref.Q - Qfull)))
    scale = float(jnp.max(jnp.abs(chase.d))) + 1.0
    assert err_d <= 1e-9 * scale and err_q <= 1e-9, (n, w, err_d, err_q)

    t_band_fullq = t_chase + t_accum
    t_band_replay = t_chase + t_apply
    return {
        "n": n, "w": w, "s": s,
        "tt1_stepwise_s": t_tt1_step,
        "tt1_full_s": t_tt1_full, "tt1_window_s": t_tt1_win,
        "tt1_auto_s": t_tt1_auto, "tt1_n_chunks": n_chunks,
        "tt2_dense_s": t_dense,
        "tt2_band_fullq_s": t_band_fullq,
        "tt2_chase_s": t_chase, "tt4_replay_s": t_apply,
        "old_tt_s": t_tt1_step + t_dense,
        "new_tt_s": t_tt1_auto + t_band_replay,
        "speedup_tt2_fullq": t_dense / t_band_fullq,
        "speedup_tt2_replay": t_dense / t_band_replay,
        "speedup_tt1": t_tt1_full / t_tt1_auto,
        "speedup_tt1_fused": t_tt1_step / t_tt1_auto,
        "speedup_full_tt": (t_tt1_step + t_dense) / (t_tt1_auto
                                                     + t_band_replay),
        "max_abs_d_err_vs_dense": err_d,
        "max_abs_q_err_vs_dense": err_q,
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI gate: n=256/w=8 only; fail if band TT2 is not "
                         "faster than the dense chase OR the fused TT1 "
                         "sweep is not faster than the stepwise host loop")
    ap.add_argument("--ns", type=int, nargs="*", default=[128, 256, 512])
    ap.add_argument("--ws", type=int, nargs="*", default=[8, 32])
    ap.add_argument("--s", type=int, default=8)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--outdir", default="artifacts")
    args = ap.parse_args()

    if args.quick:
        cells = [(256, 8)]
        repeats = 1
    else:
        cells = [(n, w) for n in args.ns for w in args.ws]
        repeats = args.repeats

    out = {"s": args.s, "cells": []}
    print("name,us_per_call,derived")
    for n, w in cells:
        # the dense chase is the slow baseline; one repeat is plenty at 512
        dense_repeats = 1 if n >= 512 else repeats
        cell = bench_cell(n, w, args.s, repeats, dense_repeats)
        out["cells"].append(cell)
        print(f"bench_sbr_tt1_stepwise_n{n}_w{w},"
              f"{cell['tt1_stepwise_s']*1e6:.1f},")
        print(f"bench_sbr_tt1_fused_n{n}_w{w},{cell['tt1_auto_s']*1e6:.1f},"
              f"n_chunks={cell['tt1_n_chunks']};"
              f"vs_stepwise={cell['speedup_tt1_fused']:.1f}x;"
              f"vs_full={cell['speedup_tt1']:.2f}x")
        print(f"bench_sbr_tt2_dense_n{n}_w{w},{cell['tt2_dense_s']*1e6:.1f},")
        print(f"bench_sbr_tt2_band_n{n}_w{w},"
              f"{cell['tt2_band_fullq_s']*1e6:.1f},"
              f"speedup={cell['speedup_tt2_fullq']:.1f}x")
        print(f"bench_sbr_tt2_chase_replay_n{n}_w{w},"
              f"{(cell['tt2_chase_s']+cell['tt4_replay_s'])*1e6:.1f},"
              f"speedup={cell['speedup_tt2_replay']:.1f}x")
        print(f"bench_sbr_full_tt_n{n}_w{w},{cell['new_tt_s']*1e6:.1f},"
              f"old={cell['old_tt_s']*1e6:.1f}us;"
              f"speedup={cell['speedup_full_tt']:.1f}x")

    if args.quick:
        cell = out["cells"][0]
        ok_tt2 = (cell["tt2_band_fullq_s"] < cell["tt2_dense_s"]
                  and cell["tt2_chase_s"] + cell["tt4_replay_s"]
                  < cell["tt2_dense_s"])
        ok_tt1 = cell["tt1_auto_s"] < cell["tt1_stepwise_s"]
        print(f"bench_sbr_quick_gate,0.0,band_faster={ok_tt2};"
              f"tt1_fused_faster={ok_tt1}")
        if not ok_tt2:
            print("FAIL: band-storage TT2 is not faster than the "
                  "dense-storage chase at n=256", file=sys.stderr)
        if not ok_tt1:
            print("FAIL: the fused one-program TT1 sweep is not faster "
                  "than the stepwise per-panel host loop at n=256",
                  file=sys.stderr)
        return 0 if (ok_tt2 and ok_tt1) else 1

    os.makedirs(args.outdir, exist_ok=True)
    path = os.path.join(args.outdir, "BENCH_sbr.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
