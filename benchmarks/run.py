"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Run:
    PYTHONPATH=src python -m benchmarks.run [--full] [--only table2]
--full switches the eigensolver benchmarks to the paper's exact problem
sizes (n=9,997 / n=17,243 — hours of CPU time; CI scale is the default and
preserves the papers' qualitative ordering, see DESIGN.md §7).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax

jax.config.update("jax_enable_x64", True)

TABLES = ("table2", "table3", "table4", "table6", "fig1", "fig2",
          "roofline")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None, choices=TABLES)
    args = ap.parse_args()

    from . import (fig1_sweep_s, fig2_sweep_modern, roofline_report,
                   table2_stage_timings, table3_accuracy,
                   table4_blocked_vs_fused, table6_kernel_pipelines)

    mods = {
        "table2": table2_stage_timings,
        "table3": table3_accuracy,
        "table4": table4_blocked_vs_fused,
        "table6": table6_kernel_pipelines,
        "fig1": fig1_sweep_s,
        "fig2": fig2_sweep_modern,
        "roofline": roofline_report,
    }
    names = [args.only] if args.only else list(TABLES)
    print("name,us_per_call,derived")
    for name in names:
        t0 = time.time()
        try:
            for line in mods[name].main(full=args.full):
                print(line, flush=True)
        except Exception as e:  # noqa: BLE001
            print(f"{name}_ERROR,0,{type(e).__name__}: {e}", flush=True)
            import traceback
            traceback.print_exc(file=sys.stderr)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
