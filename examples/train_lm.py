"""End-to-end training driver example: a few hundred steps of an assigned
architecture (reduced same-family config on CPU), with checkpointing,
auto-resume, and the paper-technique spectral probe enabled.

    PYTHONPATH=src python examples/train_lm.py [--arch xlstm-125m]
"""
import argparse
import subprocess
import sys
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", args.arch, "--smoke",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "128",
        "--ckpt-dir", "artifacts/ckpt_example",
        "--ckpt-every", "100",
        "--spectral-every", "100",
    ]
    env = dict(os.environ, PYTHONPATH="src")
    raise SystemExit(subprocess.call(cmd, env=env))


if __name__ == "__main__":
    main()
