"""The paper's technique inside the trainer: KI-style implicit-operator
Lanczos on the loss Hessian (hessian-vector products), tracking sharpness
(lambda_max) and most-negative curvature during a short training run.

    PYTHONPATH=src python examples/spectral_probe.py
"""
import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.data.tokens import DataConfig, TokenPipeline
from repro.models.model import forward
from repro.train.loss import ce_loss
from repro.train.optimizer import OptimizerConfig
from repro.train.spectral import curvature_spectrum
from repro.train.train_step import init_train_state, make_train_step


def main():
    cfg = smoke_config("gemma3-1b")
    opt = OptimizerConfig(lr=1e-3, warmup_steps=5, decay_steps=60)
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                    global_batch=4))
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    step_fn = jax.jit(make_train_step(cfg, opt))

    def probe_loss(params, b):
        logits, _ = forward(params, b["tokens"], cfg, remat=False)
        return ce_loss(logits, b["labels"])[0]

    print("step  loss     sharpness      lambda_min")
    for step in range(60):
        batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        state, metrics = step_fn(state, batch)
        if step % 15 == 0:
            spec = curvature_spectrum(probe_loss, state.params, batch, m=12,
                                      key=jax.random.PRNGKey(step))
            print(f"{step:4d}  {float(metrics['loss']):7.4f}  "
                  f"{spec['sharpness']:12.4e}  {spec['lambda_min']:12.4e}")
    print("spectral probe OK (Lanczos on an implicit operator = variant KI)")


if __name__ == "__main__":
    main()
