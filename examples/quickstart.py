"""Quickstart: solve a dense symmetric-definite generalized eigenproblem
with all four of the paper's pipelines and compare them.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402

from repro.core import solve                     # noqa: E402
from repro.core.residuals import accuracy_report  # noqa: E402
from repro.data.problems import md_like           # noqa: E402


def main():
    n, s = 256, 6
    prob = md_like(n)   # A, B SPD pair with known spectrum
    print(f"GSYEIG: n={n}, wanted s={s} smallest eigenpairs "
          f"(exact: {np.asarray(prob.exact_evals[:3]).round(5)}...)\n")
    print(f"{'variant':8s} {'total(s)':>9s} {'matvecs':>8s} "
          f"{'|I-X^TBX|/|B|':>14s} {'resid':>10s} {'max eval err':>13s}")
    for variant in ("TD", "TT", "KE", "KI"):
        invert = variant in ("KE", "KI")  # the paper's MD trick (A is SPD)
        res = solve(prob.A, prob.B, s, variant=variant, invert=invert,
                    band_width=8)
        acc = accuracy_report(prob.A, prob.B, res.X, res.evals)
        err = float(jnp.max(jnp.abs(res.evals - prob.exact_evals[:s])))
        print(f"{variant:8s} {res.stage_times['Tot.']:9.3f} "
              f"{res.info.get('n_matvec', 0):8d} "
              f"{float(acc.b_orthogonality):14.2e} "
              f"{float(acc.relative_residual):10.2e} {err:13.2e}")
    print("\nStage keys recorded per variant (paper Table 2 shape):")
    print(" ", sorted(res.stage_times))


if __name__ == "__main__":
    main()
