"""Batched serving example: prefill + greedy decode with the sharded-KV
decode path (reduced config on CPU).

    PYTHONPATH=src python examples/serve_lm.py [--arch gemma3-1b]
"""
import argparse
import os
import subprocess
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    args = ap.parse_args()
    cmd = [
        sys.executable, "-m", "repro.launch.serve",
        "--arch", args.arch, "--smoke",
        "--batch", "4", "--prompt-len", "16", "--gen", "16",
    ]
    env = dict(os.environ, PYTHONPATH="src")
    raise SystemExit(subprocess.call(cmd, env=env))


if __name__ == "__main__":
    main()
