"""gemma3-27b — 62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144,
5:1 local:global attention, 128k context. [hf:google/gemma-3 family]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21_504,
    vocab_size=262_144,
    local_global_ratio=5,
    sliding_window=1024,
    rope_theta=1_000_000.0,
)
