"""jamba-1.5-large-398b — 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2, Mamba:attention 7:1 interleave.
[arXiv:2403.19887]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24_576,
    vocab_size=65_536,
    n_experts=16,
    experts_per_token=2,
    moe_d_ff=24_576,
    moe_every=2,
    # period-8 unit: attention at position 3 (1 attn : 7 mamba, as in Jamba)
    block_pattern=("mamba", "mamba", "mamba", "attn",
                   "mamba", "mamba", "mamba", "mamba"),
    ssm_state_dim=16,
    ssm_expand=2,
)
