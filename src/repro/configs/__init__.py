"""Architecture registry: one module per assigned architecture.

``get_config(arch_id)`` returns the full-size ModelConfig;
``smoke_config(arch_id)`` returns the reduced same-family config used by the
CPU smoke tests (small widths/layers/experts/vocab, identical structure).
"""
from __future__ import annotations

import dataclasses
from importlib import import_module

from repro.models.config import LM_SHAPES, ModelConfig, ShapeConfig

_MODULES = {
    "qwen2-moe-a2.7b": "qwen2_moe_a2p7b",
    "arctic-480b": "arctic_480b",
    "mistral-large-123b": "mistral_large_123b",
    "gemma3-27b": "gemma3_27b",
    "qwen1.5-32b": "qwen1p5_32b",
    "gemma3-1b": "gemma3_1b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "jamba-1.5-large-398b": "jamba_1p5_large_398b",
    "chameleon-34b": "chameleon_34b",
    "xlstm-125m": "xlstm_125m",
}

ARCH_IDS = tuple(_MODULES)

# archs whose every layer is full attention: long_500k is skipped (see
# DESIGN.md §Arch-applicability) — a 500k dense KV cache in every layer is
# the paper's "matrix exceeds device memory" regime.
FULL_ATTENTION_ARCHS = frozenset({
    "qwen2-moe-a2.7b", "arctic-480b", "mistral-large-123b", "qwen1.5-32b",
    "chameleon-34b", "seamless-m4t-medium",
})


def get_config(arch_id: str) -> ModelConfig:
    mod = import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def smoke_config(arch_id: str) -> ModelConfig:
    cfg = get_config(arch_id)
    from repro.models.model import _period
    P = _period(cfg)
    heads = min(cfg.n_heads, 4)
    kv = max(1, min(cfg.n_kv_heads, heads))
    # keep GQA ratio valid: heads % kv == 0
    while heads % kv:
        kv -= 1
    overrides = dict(
        n_layers=2 * P + (1 if cfg.n_layers % P else 0),
        d_model=64,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=512,
        dtype="float32",
        param_dtype="float32",
    )
    if cfg.is_moe:
        overrides.update(n_experts=8,
                         experts_per_token=min(cfg.experts_per_token, 2),
                         moe_d_ff=64,
                         # capacity == T at prefill: no token drops, so the
                         # decode == prefill equivalence test is exact
                         capacity_factor=4.0)
    if cfg.sliding_window:
        overrides.update(sliding_window=16)
    if cfg.encoder_decoder:
        overrides.update(n_encoder_layers=2)
    return cfg.scaled(**overrides)


def arch_shapes(arch_id: str) -> tuple[ShapeConfig, ...]:
    """The assigned shape cells that apply to this architecture."""
    shapes = []
    for s in LM_SHAPES:
        if s.name == "long_500k" and arch_id in FULL_ATTENTION_ARCHS:
            continue  # documented skip
        shapes.append(s)
    return tuple(shapes)


__all__ = ["ARCH_IDS", "FULL_ATTENTION_ARCHS", "get_config", "smoke_config",
           "arch_shapes", "LM_SHAPES"]
