"""chameleon-34b — 48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536,
early-fusion VLM: VQ image tokens live in the text vocab, so the backbone
consumes plain token ids (frontend stub not needed at the input layer).
[arXiv:2405.09818]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22_016,
    vocab_size=65_536,
    frontend="vision",
)
