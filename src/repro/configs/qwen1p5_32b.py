"""qwen1.5-32b — 64L d_model=5120 40H (MHA kv=40) d_ff=27392 vocab=152064,
QKV bias. [hf:Qwen/Qwen1.5 family]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    head_dim=128,
    d_ff=27_392,
    vocab_size=152_064,
    attn_qkv_bias=True,
)
