"""gemma3-1b — 26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144,
5:1 local:global, 128k. [hf:google/gemma-3-1b-pt]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262_144,
    local_global_ratio=5,
    sliding_window=512,
    rope_theta=1_000_000.0,
)
