"""xlstm-125m — 12L d_model=768 4H d_ff=0 vocab=50304, sLSTM + mLSTM blocks
(d_ff=0: capacity lives in the block up-projection). [arXiv:2405.04517]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50_304,
    xlstm=True,
    xlstm_proj_factor=2.0,
)
