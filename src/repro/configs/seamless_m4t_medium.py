"""seamless-m4t-medium — enc-dec 12L d_model=1024 16H d_ff=4096
vocab=256206, multimodal (audio frontend is a STUB per the assignment:
input_specs() provides precomputed frame embeddings). [arXiv:2308.11596]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256_206,
    encoder_decoder=True,
    n_encoder_layers=12,
    frontend="audio",
)
