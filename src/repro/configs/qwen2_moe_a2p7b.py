"""qwen2-moe-a2.7b — 24L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=151936,
MoE 60e top-4, 4 shared experts. [hf:Qwen/Qwen1.5-MoE-A2.7B]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=151_936,
    n_experts=60,
    experts_per_token=4,
    n_shared_experts=4,
    moe_d_ff=1408,
    attn_qkv_bias=True,
)
