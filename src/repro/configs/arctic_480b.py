"""arctic-480b — 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128e top-2 PLUS a dense residual FFN in parallel.
[hf:Snowflake/snowflake-arctic-base]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32_000,
    n_experts=128,
    experts_per_token=2,
    moe_dense_residual=True,
    moe_d_ff=4864,
)
