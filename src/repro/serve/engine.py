"""Serving engine: request queue + continuous batching over the decode step.

The decode path (models.decode_step) is a fixed-shape (B, 1) program; the
engine keeps B slots, admits requests into free slots (their KV history
interleaves safely because every cache row is per-batch-element), and
retires sequences on EOS/length. This is the standard slot-based continuous
batching scheme (vLLM-style, ring-buffer caches instead of paged blocks —
the paged refinement drops into LayerKVCache without touching the engine).

Per-slot state semantics: ``DecodeState.pos`` is a (B,) vector — each slot
decodes from its own position — and admission resets the admitted slot's
row of every cache / recurrent state (``models.model.reset_decode_slot``).
A request admitted into a freed slot mid-stream therefore reproduces its
solo-run output token-for-token; it can neither write at the long-running
occupant's position nor attend to the previous occupant's cached
keys/values (the regression test in tests/test_serve_engine.py pins this).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Deque, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.model import init_decode_state, reset_decode_slot
from repro.train.train_step import make_serve_step


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (len,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    finished_at: float = 0.0


@dataclasses.dataclass
class _Slot:
    req: Optional[Request] = None
    produced: int = 0
    prompt_cursor: int = 0

    @property
    def free(self) -> bool:
        return self.req is None


class ServeEngine:
    """Synchronous continuous-batching engine (one decode step per tick)."""

    def __init__(self, cfg: ModelConfig, params, batch_slots: int = 4,
                 capacity: int = 256):
        self.cfg = cfg
        self.params = params
        self.B = batch_slots
        self.capacity = capacity
        self._step = jax.jit(make_serve_step(cfg))
        # donate the state: the reset rewrites one slot's rows in place
        # instead of copying every layer's caches per admission
        self._reset_slot = jax.jit(
            lambda state, i: reset_decode_slot(cfg, state, i, capacity),
            donate_argnums=(0,))
        self.state = init_decode_state(cfg, batch_slots, capacity=capacity)
        self.slots = [_Slot() for _ in range(batch_slots)]
        self.queue: Deque[Request] = deque()
        self.done: List[Request] = []
        self._tokens = np.zeros((batch_slots, 1), np.int32)
        self._uid = 0

    # -------------------------------------------------------------- admit --
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               eos_id: Optional[int] = None) -> int:
        self._uid += 1
        req = Request(uid=self._uid, prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens, eos_id=eos_id,
                      submitted_at=time.perf_counter())
        self.queue.append(req)
        return req.uid

    def _admit(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot.free and self.queue:
                slot.req = self.queue.popleft()
                slot.produced = 0
                slot.prompt_cursor = 0
                # fresh request, fresh slot: zero the slot's position and
                # every cache row so nothing of the previous occupant leaks.
                # Unconditional on purpose — even a never-occupied free slot
                # is dirty by admission time, because free slots still tick
                # (their pos advances and token-0 rows land in their caches).
                # The jitted reset donates the state, so this is a row
                # rewrite, not a full-state copy.
                self.state = self._reset_slot(self.state,
                                              jnp.asarray(i, jnp.int32))
                # and the host-side token buffer: a zero-length prompt would
                # otherwise feed the previous occupant's last sampled token
                self._tokens[i, 0] = 0

    # --------------------------------------------------------------- tick --
    def tick(self) -> int:
        """One decode step for all active slots; returns #active slots.

        Prompt tokens are fed through the same step (prefill-by-decode);
        a production deployment would add the bulk-prefill program from
        launch/dryrun's prefill cells for long prompts.
        """
        self._admit()
        active = 0
        for i, slot in enumerate(self.slots):
            if slot.free:
                self._tokens[i, 0] = 0
                continue
            active += 1
            req = slot.req
            if slot.prompt_cursor < len(req.prompt):
                self._tokens[i, 0] = req.prompt[slot.prompt_cursor]
                slot.prompt_cursor += 1
            # else: token already holds last sampled id (greedy)
        if active == 0:
            return 0
        logits, self.state = self._step(self.params,
                                        jnp.asarray(self._tokens),
                                        self.state)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        for i, slot in enumerate(self.slots):
            if slot.free:
                continue
            req = slot.req
            if slot.prompt_cursor < len(req.prompt):
                continue  # still prefilling the prompt
            tok = int(nxt[i])
            req.output.append(tok)
            slot.produced += 1
            self._tokens[i, 0] = tok
            if slot.produced >= req.max_new_tokens or \
                    (req.eos_id is not None and tok == req.eos_id):
                req.finished_at = time.perf_counter()
                self.done.append(req)
                slot.req = None  # retire: slot is admissible next tick
        return active

    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        for _ in range(max_ticks):
            if not self.queue and all(s.free for s in self.slots):
                break
            self.tick()
        return self.done
