"""Eigensolver serving engine: shape-bucketed continuous batching for
sequences of dense generalized eigenproblems.

The same slot-based scheme ``serve.engine.ServeEngine`` uses for token
decoding, transposed to the paper's workload: MD / DFT drivers emit one
``(A, B, s)`` pencil per timestep / SCF iteration, almost always at a small
set of recurring shapes. The engine

  * admits requests into *shape buckets* keyed on
    ``(n, s, which, invert, variant)`` — each bucket has ``slots`` seats,
  * dispatches a full bucket as ONE vmapped program through
    ``core.batched.solve_batched`` (the compiled pipeline is reused from the
    shape-bucket jit cache across dispatches),
  * routes oversized or mesh-worthy requests through the existing
    ``variant='auto'`` cost-model router in ``core.gsyeig.solve`` (with the
    engine's device mesh, if any),
  * retires every request with per-request latency + dispatch metadata in
    ``req.info``.

``run_until_drained(flush=True)`` flushes partially-filled buckets at the
end of a stream, so a bucket never strands requests.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.batched import BATCHED_VARIANTS, solve_batched
from repro.core.gsyeig import solve

BucketKey = Tuple[int, int, str, bool, str]  # (n, s, which, invert, variant)


@dataclasses.dataclass
class EigenRequest:
    uid: int
    A: Optional[jax.Array]   # released (None) at retirement — a continuously
    B: Optional[jax.Array]   # fed engine must not retain every operand
    s: int
    which: str = "smallest"
    invert: bool = False
    variant: str = "TD"
    # filled by the engine:
    evals: Optional[np.ndarray] = None
    X: Optional[np.ndarray] = None
    info: Dict[str, Any] = dataclasses.field(default_factory=dict)
    submitted_at: float = 0.0
    finished_at: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.finished_at - self.submitted_at


class EigenEngine:
    """Synchronous bucketed batching engine for GSYEIG requests.

    Parameters
    ----------
    slots : seats per shape bucket; a bucket dispatches as soon as it fills.
    bucket_shapes : admissible ``n`` values for batched service; requests at
        any other ``n`` fall through to the direct (router) path. ``None``
        admits every shape below ``max_batched_n`` to batching.
    max_batched_n : problems larger than this always go through the
        ``variant='auto'`` router (optionally onto ``mesh``) — batching a
        handful of huge pencils would thrash memory for no dispatch win.
    mesh : optional ``jax.sharding.Mesh`` handed to the router path.
    """

    def __init__(self, slots: int = 4,
                 bucket_shapes: Optional[List[int]] = None,
                 variant: str = "TD",
                 max_batched_n: int = 1024,
                 mesh=None,
                 band_width: int = 8,
                 m: int | None = None,
                 max_restarts: int = 200,
                 key: jax.Array | None = None):
        assert slots >= 1
        assert variant in BATCHED_VARIANTS, variant
        self.slots = slots
        self.bucket_shapes = (None if bucket_shapes is None
                              else sorted(set(int(n) for n in bucket_shapes)))
        self.default_variant = variant
        self.max_batched_n = max_batched_n
        self.mesh = mesh
        self.band_width = band_width
        self.m = m
        self.max_restarts = max_restarts
        self._key = key if key is not None else jax.random.PRNGKey(1729)
        self.buckets: "OrderedDict[BucketKey, List[EigenRequest]]" = \
            OrderedDict()
        self.direct_queue: List[EigenRequest] = []
        self.done: List[EigenRequest] = []
        self._uid = 0
        self.n_dispatches = 0

    # -------------------------------------------------------------- admit --
    def _batchable(self, n: int, variant: Optional[str]) -> bool:
        if variant is not None and variant not in BATCHED_VARIANTS:
            return False  # e.g. an explicit 'auto' request
        if n > self.max_batched_n:
            return False
        if self.bucket_shapes is not None and n not in self.bucket_shapes:
            return False
        return True

    def submit(self, A, B, s: int, which: str = "smallest",
               invert: bool = False, variant: Optional[str] = None) -> int:
        """Queue one pencil; returns its uid. ``variant=None`` uses the
        engine default for batchable requests; ``variant='auto'`` forces the
        cost-model router path."""
        A = jnp.asarray(A)
        B = jnp.asarray(B)
        n = A.shape[0]
        assert A.shape == (n, n) and B.shape == (n, n), (A.shape, B.shape)
        self._uid += 1
        batchable = self._batchable(n, variant)
        v = (variant if variant is not None
             else (self.default_variant if batchable else "auto"))
        req = EigenRequest(uid=self._uid, A=A, B=B, s=int(s), which=which,
                           invert=invert, variant=v,
                           submitted_at=time.perf_counter())
        if batchable:
            bkey: BucketKey = (n, int(s), which, bool(invert), v)
            self.buckets.setdefault(bkey, []).append(req)
        else:
            self.direct_queue.append(req)
        return req.uid

    # ----------------------------------------------------------- dispatch --
    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def _dispatch_bucket(self, bkey: BucketKey,
                         reqs: List[EigenRequest]) -> None:
        n, s, which, invert, variant = bkey
        A = jnp.stack([r.A for r in reqs])
        B = jnp.stack([r.B for r in reqs])
        res = solve_batched(A, B, s, variant=variant, which=which,
                            invert=invert, band_width=self.band_width,
                            m=self.m, max_restarts=self.max_restarts,
                            key=self._next_key())
        self.n_dispatches += 1
        now = time.perf_counter()
        evals = np.asarray(res.evals)
        X = np.asarray(res.X)
        conv = np.asarray(res.converged)
        for i, req in enumerate(reqs):
            req.evals, req.X = evals[i], X[i]
            req.A = req.B = None  # free the operands; results stay
            req.finished_at = now
            req.info = {"path": "batched", "bucket": list(bkey),
                        "batch": len(reqs), "variant": variant,
                        "converged": bool(conv[i]),
                        "cache_hit": res.info["cache_hit"],
                        "compile_s": res.info["compile_s"],
                        "dispatch_wall_s": res.info["wall_s"],
                        "latency_s": req.finished_at - req.submitted_at}
            if not conv[i]:
                req.info["warnings"] = [
                    f"{variant}: pencil retired at the restart budget "
                    f"(max_restarts={self.max_restarts}) without "
                    f"converging; residuals may exceed tolerance"]
            self.done.append(req)

    def _dispatch_direct(self, req: EigenRequest) -> None:
        # core.solve's mesh= dispatch implements KE/TT (and 'auto' restricts
        # itself to those); a direct TD/KI request runs on one device
        mesh = self.mesh if req.variant in ("KE", "TT", "auto") else None
        res = solve(req.A, req.B, req.s, variant=req.variant,
                    which=req.which, invert=req.invert,
                    band_width=self.band_width, m=self.m,
                    max_restarts=self.max_restarts, mesh=mesh,
                    key=self._next_key())
        self.n_dispatches += 1
        req.evals = np.asarray(res.evals)
        req.X = np.asarray(res.X)
        req.A = req.B = None  # free the operands; results stay
        req.finished_at = time.perf_counter()
        req.info = {"path": "direct", "variant": res.info["variant"],
                    "stage_times": res.stage_times,
                    "latency_s": req.finished_at - req.submitted_at}
        if "router" in res.info:
            req.info["router"] = res.info["router"]
        if "warnings" in res.info:
            req.info["warnings"] = res.info["warnings"]
        self.done.append(req)

    # --------------------------------------------------------------- tick --
    def tick(self, flush: bool = False) -> int:
        """Dispatch every full bucket (plus partial buckets when ``flush``)
        and one direct request; returns the number of retired requests."""
        retired0 = len(self.done)
        for bkey in list(self.buckets):
            pending = self.buckets[bkey]
            while len(pending) >= self.slots:
                batch, self.buckets[bkey] = pending[:self.slots], \
                    pending[self.slots:]
                pending = self.buckets[bkey]
                self._dispatch_bucket(bkey, batch)
            if flush and pending:
                self.buckets[bkey] = []
                self._dispatch_bucket(bkey, pending)
            if not self.buckets[bkey]:
                del self.buckets[bkey]
        if self.direct_queue:
            self._dispatch_direct(self.direct_queue.pop(0))
        return len(self.done) - retired0

    def pending(self) -> int:
        return (sum(len(v) for v in self.buckets.values())
                + len(self.direct_queue))

    def run_until_drained(self, flush: bool = True,
                          max_ticks: int = 10_000) -> List[EigenRequest]:
        for _ in range(max_ticks):
            if not self.pending():
                break
            if self.tick(flush=flush) == 0 and not flush:
                # nothing retired and nothing may dispatch without a flush:
                # only partial buckets remain, so stop instead of spinning
                break
        return self.done

    # ------------------------------------------------------------ metrics --
    def summary(self) -> Dict[str, Any]:
        """JSON-clean per-bucket serving metrics for the CLI / benchmark."""
        per_bucket: Dict[str, Dict[str, Any]] = {}
        for req in self.done:
            if req.info.get("path") == "batched":
                n, s, which, invert, variant = req.info["bucket"]
                name = f"n{n}_s{s}_{which}_{variant}" + \
                    ("_inv" if invert else "")
            else:
                name = "direct"
            b = per_bucket.setdefault(name, {"count": 0, "latency_s": []})
            b["count"] += 1
            b["latency_s"].append(req.info["latency_s"])
        for b in per_bucket.values():
            lat = b.pop("latency_s")
            b["mean_latency_s"] = float(np.mean(lat))
            b["p90_latency_s"] = float(np.percentile(lat, 90))
        return {"requests": len(self.done),
                "dispatches": self.n_dispatches,
                "buckets": per_bucket}


__all__ = ["EigenEngine", "EigenRequest"]
