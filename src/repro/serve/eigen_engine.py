"""Eigensolver serving engine: shape-bucketed continuous batching for
sequences of dense generalized eigenproblems.

The same slot-based scheme ``serve.engine.ServeEngine`` uses for token
decoding, transposed to the paper's workload: MD / DFT drivers emit one
``(A, B, s)`` pencil per timestep / SCF iteration, almost always at a small
set of recurring shapes. The engine

  * admits requests into *shape buckets* keyed on
    ``(n, s, which, invert, variant)`` — each bucket has ``slots`` seats,
  * dispatches a full bucket as ONE vmapped program through
    ``core.batched.solve_batched`` (the compiled pipeline is reused from the
    shape-bucket jit cache across dispatches),
  * routes oversized or mesh-worthy requests through the existing
    ``variant='auto'`` cost-model router in ``core.gsyeig.solve`` (with the
    engine's device mesh, if any),
  * retires every request with per-request latency + dispatch metadata in
    ``req.info`` — every retired request carries a uniform ``warnings``
    list and a ``health`` verdict (both always present, JSON-clean),
  * QUARANTINES unhealthy / unconverged lanes of a vmapped bucket: the
    failing pencil is retried individually up the degradation ladder
    (``core.gsyeig.solve`` with the engine's ``on_failure`` policy,
    bounded backoff), so one bad pencil cannot poison its bucket-mates;
    a lane that exhausts ``max_retries`` is DEAD-LETTERED with its
    verdict (``engine.dead_letters``) instead of silently dropped.

``run_until_drained(flush=True)`` flushes partially-filled buckets at the
end of a stream, so a bucket never strands requests.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.batched import BATCHED_VARIANTS, solve_batched
from repro.core.gsyeig import solve
from repro.resilience.recovery import SolverError, validate_on_failure

BucketKey = Tuple[int, int, str, bool, str]  # (n, s, which, invert, variant)


@dataclasses.dataclass
class EigenRequest:
    uid: int
    A: Optional[jax.Array]   # released (None) at retirement — a continuously
    B: Optional[jax.Array]   # fed engine must not retain every operand
    s: int
    which: str = "smallest"
    invert: bool = False
    variant: str = "TD"
    # filled by the engine:
    evals: Optional[np.ndarray] = None
    X: Optional[np.ndarray] = None
    info: Dict[str, Any] = dataclasses.field(default_factory=dict)
    submitted_at: float = 0.0
    finished_at: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.finished_at - self.submitted_at


class EigenEngine:
    """Synchronous bucketed batching engine for GSYEIG requests.

    Parameters
    ----------
    slots : seats per shape bucket; a bucket dispatches as soon as it fills.
    bucket_shapes : admissible ``n`` values for batched service; requests at
        any other ``n`` fall through to the direct (router) path. ``None``
        admits every shape below ``max_batched_n`` to batching.
    max_batched_n : problems larger than this always go through the
        ``variant='auto'`` router (optionally onto ``mesh``) — batching a
        handful of huge pencils would thrash memory for no dispatch win.
    mesh : optional ``jax.sharding.Mesh`` handed to the router path.
    max_retries : individual retries a quarantined lane gets before it is
        dead-lettered.
    on_failure : the ladder policy handed to ``core.gsyeig.solve`` for
        quarantine/direct solves; also selects whether UNCONVERGED bucket
        lanes are quarantined (``'recover'``, the default) or retired
        with a warning (``'warn'``, the pre-quarantine behavior).
        Unhealthy (non-finite) lanes are never retired silently under
        either policy; ``'ignore'`` restores the old behavior entirely.
    retry_backoff_s : sleep before quarantine retry k of ``k * backoff``
        seconds (bounded, linear).
    """

    def __init__(self, slots: int = 4,
                 bucket_shapes: Optional[List[int]] = None,
                 variant: str = "TD",
                 max_batched_n: int = 1024,
                 mesh=None,
                 band_width: int = 8,
                 m: int | None = None,
                 max_restarts: int = 200,
                 key: jax.Array | None = None,
                 max_retries: int = 2,
                 on_failure: str = "recover",
                 retry_backoff_s: float = 0.0):
        assert slots >= 1
        assert variant in BATCHED_VARIANTS, variant
        validate_on_failure(on_failure)
        self.slots = slots
        self.bucket_shapes = (None if bucket_shapes is None
                              else sorted(set(int(n) for n in bucket_shapes)))
        self.default_variant = variant
        self.max_batched_n = max_batched_n
        self.mesh = mesh
        self.band_width = band_width
        self.m = m
        self.max_restarts = max_restarts
        self.max_retries = max_retries
        self.on_failure = on_failure
        self.retry_backoff_s = retry_backoff_s
        self._key = key if key is not None else jax.random.PRNGKey(1729)
        self.buckets: "OrderedDict[BucketKey, List[EigenRequest]]" = \
            OrderedDict()
        self.direct_queue: List[EigenRequest] = []
        self.done: List[EigenRequest] = []
        self.dead_letters: List[EigenRequest] = []
        self._uid = 0
        self.n_dispatches = 0
        self.n_quarantined = 0

    # -------------------------------------------------------------- admit --
    def _batchable(self, n: int, variant: Optional[str]) -> bool:
        if variant is not None and variant not in BATCHED_VARIANTS:
            return False  # e.g. an explicit 'auto' request
        if n > self.max_batched_n:
            return False
        if self.bucket_shapes is not None and n not in self.bucket_shapes:
            return False
        return True

    def submit(self, A, B, s: int, which: str = "smallest",
               invert: bool = False, variant: Optional[str] = None) -> int:
        """Queue one pencil; returns its uid. ``variant=None`` uses the
        engine default for batchable requests; ``variant='auto'`` forces the
        cost-model router path."""
        A = jnp.asarray(A)
        B = jnp.asarray(B)
        n = A.shape[0]
        assert A.shape == (n, n) and B.shape == (n, n), (A.shape, B.shape)
        self._uid += 1
        batchable = self._batchable(n, variant)
        v = (variant if variant is not None
             else (self.default_variant if batchable else "auto"))
        req = EigenRequest(uid=self._uid, A=A, B=B, s=int(s), which=which,
                           invert=invert, variant=v,
                           submitted_at=time.perf_counter())
        if batchable:
            bkey: BucketKey = (n, int(s), which, bool(invert), v)
            self.buckets.setdefault(bkey, []).append(req)
        else:
            self.direct_queue.append(req)
        return req.uid

    # ----------------------------------------------------------- dispatch --
    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def _dispatch_bucket(self, bkey: BucketKey,
                         reqs: List[EigenRequest]) -> None:
        n, s, which, invert, variant = bkey
        A = jnp.stack([r.A for r in reqs])
        B = jnp.stack([r.B for r in reqs])
        res = solve_batched(A, B, s, variant=variant, which=which,
                            invert=invert, band_width=self.band_width,
                            m=self.m, max_restarts=self.max_restarts,
                            key=self._next_key())
        self.n_dispatches += 1
        now = time.perf_counter()
        evals = np.asarray(res.evals)
        X = np.asarray(res.X)
        conv = np.asarray(res.converged)
        healthy = np.asarray(res.healthy)
        for i, req in enumerate(reqs):
            lane_healthy = bool(healthy[i])
            lane_conv = bool(conv[i])
            # per-lane quarantine: an unhealthy lane is NEVER retired as a
            # result (its eigenpairs are NaN); an unconverged lane is
            # quarantined under 'recover' so the ladder can escalate it
            if ((not lane_healthy and self.on_failure != "ignore")
                    or (not lane_conv and self.on_failure == "recover")):
                self._quarantine(
                    req, bkey,
                    "nonfinite lane" if not lane_healthy
                    else "unconverged lane")
                continue
            req.evals, req.X = evals[i], X[i]
            req.A = req.B = None  # free the operands; results stay
            req.finished_at = now
            warnings = []
            if not lane_conv:
                warnings.append(
                    f"{variant}: pencil retired at the restart budget "
                    f"(max_restarts={self.max_restarts}) without "
                    f"converging; residuals may exceed tolerance")
            if not lane_healthy:
                warnings.append(
                    f"{variant}: pencil retired with NON-FINITE eigenpairs "
                    f"(on_failure='ignore')")
            req.info = {"path": "batched", "bucket": list(bkey),
                        "batch": len(reqs), "variant": variant,
                        "converged": lane_conv,
                        "cache_hit": res.info["cache_hit"],
                        "compile_s": res.info["compile_s"],
                        "dispatch_wall_s": res.info["wall_s"],
                        "latency_s": req.finished_at - req.submitted_at,
                        "warnings": warnings,
                        "health": {"healthy": lane_healthy,
                                   "stages": {"PIPELINE": lane_healthy},
                                   "first_unhealthy_stage":
                                       None if lane_healthy else "PIPELINE",
                                   "detail": "fused per-lane sentinel of "
                                             "the vmapped bucket program"},
                        "recovery": []}
            self.done.append(req)

    def _quarantine(self, req: EigenRequest, bkey: BucketKey,
                    why: str) -> None:
        """Retry one failing bucket lane individually up the ladder, with
        bounded linear backoff; dead-letter it when the retries are spent.
        The operands are still attached (they are only freed at
        retirement), so the retry solves exactly the submitted pencil."""
        n, s, which, invert, variant = bkey
        self.n_quarantined += 1
        trail: List[Dict[str, Any]] = [
            {"action": "quarantine", "stage": "bucket", "outcome": why,
             "params": {"bucket": list(bkey)}}]
        last_diag: Dict[str, Any] = {}
        for attempt in range(1, self.max_retries + 1):
            if self.retry_backoff_s > 0:
                time.sleep(self.retry_backoff_s * attempt)
            try:
                res = solve(req.A, req.B, req.s, variant=variant,
                            which=which, invert=invert,
                            band_width=self.band_width, m=self.m,
                            max_restarts=self.max_restarts,
                            key=self._next_key(),
                            on_failure=self.on_failure)
            except SolverError as err:
                last_diag = err.diagnosis
                trail.append({"action": "quarantine_retry",
                              "stage": err.diagnosis["stage"],
                              "outcome": "failed",
                              "params": {"attempt": attempt,
                                         "reason": err.diagnosis["reason"]}})
                continue
            self.n_dispatches += 1
            ok = (res.info["health"]["healthy"]
                  and (res.info.get("converged", True)
                       or self.on_failure != "recover"))
            trail.append({"action": "quarantine_retry", "stage": "solve",
                          "outcome": "recovered" if ok else "unconverged",
                          "params": {"attempt": attempt}})
            if ok:
                req.evals = np.asarray(res.evals)
                req.X = np.asarray(res.X)
                req.A = req.B = None
                req.finished_at = time.perf_counter()
                req.info = {
                    "path": "quarantine", "bucket": list(bkey),
                    "variant": res.info["variant"],
                    "converged": bool(res.info.get("converged", True)),
                    "attempts": attempt,
                    "latency_s": req.finished_at - req.submitted_at,
                    "warnings": list(res.info.get("warnings", [])),
                    "health": res.info["health"],
                    "recovery": trail + list(res.info.get("recovery", []))}
                self.done.append(req)
                return
            last_diag = {"stage": "solve", "reason": "unconverged",
                         "hint": "restart budget exhausted on individual "
                                 "retry", "recovery": []}
        self._dead_letter(req, bkey, trail, last_diag)

    def _dead_letter(self, req: EigenRequest, bkey: Optional[BucketKey],
                     trail: List[Dict[str, Any]],
                     diagnosis: Dict[str, Any]) -> None:
        """Retire a request into ``dead_letters`` with its verdict — the
        no-silent-drop invariant: every submitted uid lands in ``done``
        or here, never nowhere."""
        req.A = req.B = None
        req.finished_at = time.perf_counter()
        req.info = {
            "path": "dead_letter",
            "bucket": None if bkey is None else list(bkey),
            "variant": req.variant,
            "converged": False,
            "latency_s": req.finished_at - req.submitted_at,
            "warnings": [f"request {req.uid} dead-lettered after "
                         f"{self.max_retries} quarantine retries"],
            "health": {"healthy": False,
                       "stages": diagnosis.get("health", {}),
                       "first_unhealthy_stage": diagnosis.get("stage"),
                       "detail": diagnosis.get("reason", "")},
            "recovery": trail,
            "dead_letter": {k: v for k, v in diagnosis.items()
                            if k != "health"}}
        self.dead_letters.append(req)

    def _dispatch_direct(self, req: EigenRequest) -> None:
        # core.solve's mesh= dispatch implements KE/TT (and 'auto' restricts
        # itself to those); a direct TD/KI request runs on one device
        mesh = self.mesh if req.variant in ("KE", "TT", "auto") else None
        try:
            res = solve(req.A, req.B, req.s, variant=req.variant,
                        which=req.which, invert=req.invert,
                        band_width=self.band_width, m=self.m,
                        max_restarts=self.max_restarts, mesh=mesh,
                        key=self._next_key(), on_failure=self.on_failure)
        except SolverError as err:
            self.n_dispatches += 1
            self._dead_letter(
                req, None,
                [{"action": "direct_solve", "stage": err.diagnosis["stage"],
                  "outcome": "failed"}], err.diagnosis)
            return
        self.n_dispatches += 1
        req.evals = np.asarray(res.evals)
        req.X = np.asarray(res.X)
        req.A = req.B = None  # free the operands; results stay
        req.finished_at = time.perf_counter()
        req.info = {"path": "direct", "variant": res.info["variant"],
                    "stage_times": res.stage_times,
                    "latency_s": req.finished_at - req.submitted_at,
                    "warnings": list(res.info.get("warnings", [])),
                    "health": res.info["health"],
                    "recovery": list(res.info.get("recovery", []))}
        if "router" in res.info:
            req.info["router"] = res.info["router"]
        self.done.append(req)

    # --------------------------------------------------------------- tick --
    def tick(self, flush: bool = False) -> int:
        """Dispatch every full bucket (plus partial buckets when ``flush``)
        and one direct request; returns the number of retired requests."""
        retired0 = len(self.done)
        for bkey in list(self.buckets):
            pending = self.buckets[bkey]
            while len(pending) >= self.slots:
                batch, self.buckets[bkey] = pending[:self.slots], \
                    pending[self.slots:]
                pending = self.buckets[bkey]
                self._dispatch_bucket(bkey, batch)
            if flush and pending:
                self.buckets[bkey] = []
                self._dispatch_bucket(bkey, pending)
            if not self.buckets[bkey]:
                del self.buckets[bkey]
        if self.direct_queue:
            self._dispatch_direct(self.direct_queue.pop(0))
        return len(self.done) - retired0

    def pending(self) -> int:
        return (sum(len(v) for v in self.buckets.values())
                + len(self.direct_queue))

    def run_until_drained(self, flush: bool = True,
                          max_ticks: int = 10_000) -> List[EigenRequest]:
        for _ in range(max_ticks):
            if not self.pending():
                break
            if self.tick(flush=flush) == 0 and not flush:
                # nothing retired and nothing may dispatch without a flush:
                # only partial buckets remain, so stop instead of spinning
                break
        return self.done

    # ------------------------------------------------------------ metrics --
    def summary(self) -> Dict[str, Any]:
        """JSON-clean per-bucket serving metrics for the CLI / benchmark."""
        per_bucket: Dict[str, Dict[str, Any]] = {}
        for req in self.done:
            if req.info.get("path") == "batched":
                n, s, which, invert, variant = req.info["bucket"]
                name = f"n{n}_s{s}_{which}_{variant}" + \
                    ("_inv" if invert else "")
            else:
                name = req.info.get("path", "direct")
            b = per_bucket.setdefault(name, {"count": 0, "latency_s": []})
            b["count"] += 1
            b["latency_s"].append(req.info["latency_s"])
        for b in per_bucket.values():
            lat = b.pop("latency_s")
            b["mean_latency_s"] = float(np.mean(lat))
            b["p90_latency_s"] = float(np.percentile(lat, 90))
        return {"requests": len(self.done) + len(self.dead_letters),
                "dispatches": self.n_dispatches,
                "quarantined": self.n_quarantined,
                "dead_letters": len(self.dead_letters),
                "dead_letter_uids": [r.uid for r in self.dead_letters],
                "buckets": per_bucket}


__all__ = ["EigenEngine", "EigenRequest"]
