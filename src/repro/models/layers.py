"""Core layer primitives — pure-functional JAX (params are nested dicts).

Initialization is explicit (PRNG keys threaded); forward passes are pure.
Dtype policy: params stored in ``param_dtype`` (f32 master), compute in
``dtype`` (bf16 on the TPU target), losses in f32.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .config import ModelConfig

Params = Dict[str, Any]


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ----------------------------------------------------------------- linear --

def init_linear(key, d_in: int, d_out: int, cfg: ModelConfig,
                bias: bool = False) -> Params:
    scale = (2.0 / (d_in + d_out)) ** 0.5
    p = {"w": jax.random.normal(key, (d_in, d_out), pdtype(cfg)) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), pdtype(cfg))
    return p


def linear(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    y = x @ p["w"].astype(cdtype(cfg))
    if "b" in p:
        y = y + p["b"].astype(cdtype(cfg))
    return y


# ---------------------------------------------------------------- rmsnorm --

def init_rmsnorm(d: int, cfg: ModelConfig) -> Params:
    return {"g": jnp.ones((d,), pdtype(cfg))}


def rmsnorm(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + cfg.norm_eps)
    return (y * p["g"].astype(jnp.float32)).astype(x.dtype)


# -------------------------------------------------------------- embedding --

def init_embedding(key, cfg: ModelConfig) -> Params:
    e = jax.random.normal(key, (cfg.vocab_size, cfg.d_model), pdtype(cfg))
    return {"table": e * (cfg.d_model ** -0.5)}


def embed(p: Params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    return p["table"].astype(cdtype(cfg))[tokens]


def unembed(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Logits in f32 (loss numerics)."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      p["table"].astype(jnp.float32))


# ------------------------------------------------------------------- rope --

def rope_angles(positions: jax.Array, head_dim: int,
                theta: float) -> tuple[jax.Array, jax.Array]:
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, hd); cos/sin: (..., S, hd/2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * c - xf2 * s, xf2 * c + xf1 * s],
                           axis=-1).astype(x.dtype)


# ----------------------------------------------------- chunked sequence scan

def chunked_scan(step, init, xs, chunk: int, remat: bool = True):
    """scan(step, init, xs) restructured as scan-of-scans.

    Storage for the backward pass drops from O(S) carries to O(S/chunk)
    outer carries (+ O(chunk) recomputed inside each checkpointed inner
    scan) — the standard two-level checkpointing that makes long-sequence
    recurrent layers (mamba/xlstm) trainable at 4k+ tokens.
    """
    S = jax.tree.leaves(xs)[0].shape[0]
    chunk = min(chunk, S)
    if S % chunk:
        # fall back to the flat scan for ragged sizes (tests/small shapes)
        return jax.lax.scan(step, init, xs)
    nc = S // chunk
    xs_c = jax.tree.map(lambda x: x.reshape(nc, chunk, *x.shape[1:]), xs)

    def inner(carry, xc):
        return jax.lax.scan(step, carry, xc)

    outer_body = jax.checkpoint(inner) if remat else inner

    def outer(carry, xc):
        return outer_body(carry, xc)

    carry, ys_c = jax.lax.scan(outer, init, xs_c)
    ys = jax.tree.map(lambda y: y.reshape(nc * chunk, *y.shape[2:]), ys_c)
    return carry, ys


# ----------------------------------------------------------------- swiglu --

def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None) -> Params:
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_linear(k1, cfg.d_model, d_ff, cfg),
        "up": init_linear(k2, cfg.d_model, d_ff, cfg),
        "down": init_linear(k3, d_ff, cfg.d_model, cfg),
    }


def mlp(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    g = jax.nn.silu(linear(p["gate"], x, cfg))
    u = linear(p["up"], x, cfg)
    return linear(p["down"], g * u, cfg)
