"""Architecture configuration — one dataclass covers all 10 assigned archs.

Field semantics follow the assignment sheet; per-arch instances live in
``repro.configs.<id>``. Everything is static/hashable so configs can be jit
static args.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None            # default d_model // n_heads

    # ---- MoE ----
    n_experts: int = 0                         # 0 => dense FFN
    experts_per_token: int = 0
    n_shared_experts: int = 0                  # qwen2-moe shared experts
    moe_dense_residual: bool = False           # arctic: dense FFN in parallel
    moe_d_ff: Optional[int] = None             # expert hidden if != d_ff
    moe_every: int = 1                         # jamba: MoE every 2nd layer
    capacity_factor: float = 1.25

    # ---- attention pattern ----
    sliding_window: Optional[int] = None       # window for 'local' layers
    local_global_ratio: int = 0                # gemma3: N local per 1 global
    attn_qkv_bias: bool = False                # qwen1.5: QKV bias
    attn_logit_softcap: Optional[float] = None
    rope_theta: float = 10_000.0

    # ---- hybrid / SSM ----
    block_pattern: Tuple[str, ...] = ()        # repeating unit, e.g. 7x mamba + attn
    ssm_state_dim: int = 16
    ssm_conv_dim: int = 4
    ssm_expand: int = 2

    # ---- xLSTM ----
    xlstm: bool = False                        # sLSTM/mLSTM alternating blocks
    xlstm_proj_factor: float = 2.0             # block up-projection (d_ff=0)

    # ---- encoder-decoder ----
    encoder_decoder: bool = False
    n_encoder_layers: int = 0

    # ---- modality frontend (STUB per assignment) ----
    frontend: Optional[str] = None             # 'audio' | 'vision' | None

    # ---- numerics ----
    kv_cache_dtype: str = "compute"            # 'compute' | 'int8' (decode)
    dtype: str = "bfloat16"                    # activations/params compute dtype
    param_dtype: str = "float32"               # master params
    tie_embeddings: bool = True
    norm_eps: float = 1e-6

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ------------------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def expert_ff(self) -> int:
        return self.moe_d_ff if self.moe_d_ff is not None else self.d_ff

    def layer_kinds(self) -> Tuple[str, ...]:
        """Resolved per-layer kind list of length n_layers (decoder side).

        Kinds: 'attn', 'local', 'global', 'mamba', 'slstm', 'mlstm'.
        """
        if self.xlstm:
            # xLSTM-7:1-style mix per arXiv:2405.04517 (sLSTM at positions of
            # every 4th block for the 125M config family)
            kinds = tuple("slstm" if i % 4 == 1 else "mlstm"
                          for i in range(self.n_layers))
            return kinds
        if self.block_pattern:
            period = len(self.block_pattern)
            return tuple(self.block_pattern[i % period]
                         for i in range(self.n_layers))
        if self.local_global_ratio > 0:
            period = self.local_global_ratio + 1
            # gemma3: L local then 1 global, repeating
            return tuple("global" if (i % period) == self.local_global_ratio
                         else "local" for i in range(self.n_layers))
        return ("attn",) * self.n_layers

    def ffn_kinds(self) -> Tuple[str, ...]:
        """Per-layer FFN type: 'moe' | 'dense' | 'none'."""
        kinds = self.layer_kinds()
        out = []
        for i, k in enumerate(kinds):
            if k in ("slstm", "mlstm"):
                out.append("none")      # xlstm: capacity inside the block
            elif self.is_moe and (i % self.moe_every == self.moe_every - 1
                                  if self.moe_every > 1 else True):
                out.append("moe")
            elif self.d_ff > 0:
                out.append("dense")
            else:
                out.append("none")
        return tuple(out)

    def scaled(self, **overrides) -> "ModelConfig":
        """A reduced copy (used by smoke tests)."""
        return dataclasses.replace(self, **overrides)

    # ---- parameter count (for roofline MODEL_FLOPS = 6 N D) -------------
    def param_count(self, active_only: bool = False) -> int:
        d, h = self.d_model, self.head_dim
        q = d * self.n_heads * h
        kv = 2 * d * self.n_kv_heads * h
        o = self.n_heads * h * d
        attn = q + kv + o

        def ffn_params(width: int) -> int:
            return 3 * d * width  # SwiGLU: gate, up, down

        kinds = self.layer_kinds()
        fkinds = self.ffn_kinds()
        total = 0
        active = 0
        for kind, fkind in zip(kinds, fkinds):
            if kind in ("attn", "local", "global"):
                total += attn
                active += attn
            elif kind == "mamba":
                d_in = self.ssm_expand * d
                m = (2 * d * d_in                            # in_proj (x, z)
                     + d_in * self.ssm_conv_dim
                     + d_in * 2 * self.ssm_state_dim         # B_t, C_t proj
                     + d_in * d_in + d_in                    # dt proj + bias
                     + d_in * self.ssm_state_dim + d_in      # A_log, D
                     + d_in * d)                             # out proj
                total += m
                active += m
            elif kind in ("slstm", "mlstm"):
                d_in = int(self.xlstm_proj_factor * d)
                m = 2 * d * d_in + d_in * d + 4 * d * d_in // 2
                total += m
                active += m
            if fkind == "moe":
                e_p = ffn_params(self.expert_ff)
                total += self.n_experts * e_p
                active += self.experts_per_token * e_p
                shared = self.n_shared_experts * e_p
                total += shared
                active += shared
                if self.moe_dense_residual:
                    total += ffn_params(self.d_ff)
                    active += ffn_params(self.d_ff)
                total += d * self.n_experts  # router
                active += d * self.n_experts
            elif fkind == "dense":
                total += ffn_params(self.d_ff)
                active += ffn_params(self.d_ff)
        emb = self.vocab_size * d
        total += emb if self.tie_embeddings else 2 * emb
        active += emb if self.tie_embeddings else 2 * emb
        if self.encoder_decoder:
            # encoder layers: self-attn + FFN; decoder adds cross-attn
            enc = self.n_encoder_layers * (attn + ffn_params(self.d_ff))
            dec_cross = self.n_layers * attn
            total += enc + dec_cross
            active += enc + dec_cross
        return active if active_only else total


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell of the assignment."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


LM_SHAPES = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeConfig:
    for s in LM_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
