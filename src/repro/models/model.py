"""Model assembly: embeddings -> (scan over layer super-blocks) -> logits.

Heterogeneous layer patterns (jamba 7:1 mamba:attn, gemma3 5:1 local:global,
xlstm mlstm/slstm mix) are handled by scanning over the *repeating period*:
layer params are stored as P stacked pytrees (P = period length), the scan
runs over the R = n_layers // P repetitions, and any remainder layers are
executed unrolled ("tail"). This keeps the HLO O(period) instead of
O(n_layers) — essential for 62..88-layer configs compiled for 512 devices.

Both paths are provided:
  * ``forward``      — full-sequence (train / prefill)
  * ``decode_step``  — one token with per-layer caches/states (ring-buffer KV
    for attention layers, O(1) states for mamba/xlstm)
Encoder-decoder (seamless) adds ``encode`` and cross-attention in the
decoder layers.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (LayerKVCache, attention, attention_decode,
                        init_attention, init_layer_cache)
from .config import ModelConfig
from .layers import (Params, cdtype, embed, init_embedding, init_mlp,
                     init_rmsnorm, mlp, rmsnorm, unembed)
from .moe import init_moe, moe_ffn
from .ssm import (MambaState, init_mamba, init_mamba_state, mamba,
                  mamba_decode)
from .xlstm import (MLSTMState, SLSTMState, init_mlstm, init_mlstm_state,
                    init_slstm, init_slstm_state, mlstm, mlstm_decode, slstm,
                    slstm_decode)

ATTN_KINDS = ("attn", "local", "global")


def _period(cfg: ModelConfig) -> int:
    kinds = cfg.layer_kinds()
    if cfg.block_pattern:
        p = len(cfg.block_pattern)
    elif cfg.local_global_ratio > 0:
        p = cfg.local_global_ratio + 1
    elif cfg.xlstm:
        p = 4
    else:
        p = 1
    return min(p, len(kinds))


def layer_plan(cfg: ModelConfig) -> tuple[tuple[str, ...], int, int, int]:
    """(kinds, period P, repeats R, tail length)."""
    kinds = cfg.layer_kinds()
    P = _period(cfg)
    if cfg.is_moe and cfg.moe_every > 1:
        # scan positions must have a fixed FFN type across repetitions
        assert P % cfg.moe_every == 0, (P, cfg.moe_every)
    R = len(kinds) // P
    tail = len(kinds) - P * R
    return kinds, P, R, tail


# --------------------------------------------------------------- init -----

def _init_layer(key, cfg: ModelConfig, kind: str, fkind: str) -> Params:
    ks = jax.random.split(key, 8)
    p: Params = {"ln1": init_rmsnorm(cfg.d_model, cfg)}
    if kind in ATTN_KINDS:
        p["attn"] = init_attention(ks[0], cfg)
        if cfg.encoder_decoder:
            p["lnx"] = init_rmsnorm(cfg.d_model, cfg)
            p["xattn"] = init_attention(ks[1], cfg, cross=True)
    elif kind == "mamba":
        p["mamba"] = init_mamba(ks[0], cfg)
    elif kind == "slstm":
        p["cell"] = init_slstm(ks[0], cfg)
    elif kind == "mlstm":
        p["cell"] = init_mlstm(ks[0], cfg)
    else:
        raise ValueError(kind)
    if fkind != "none":
        p["ln2"] = init_rmsnorm(cfg.d_model, cfg)
        p["ffn"] = init_moe(ks[2], cfg) if fkind == "moe" \
            else init_mlp(ks[2], cfg)
    return p


def _init_encoder_layer(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "ln1": init_rmsnorm(cfg.d_model, cfg),
        "attn": init_attention(ks[0], cfg),
        "ln2": init_rmsnorm(cfg.d_model, cfg),
        "ffn": init_mlp(ks[1], cfg),
    }


def init_params(key, cfg: ModelConfig) -> Params:
    kinds, P, R, tail = layer_plan(cfg)
    fkinds = cfg.ffn_kinds()
    ke, kl, kt, kf, kenc = jax.random.split(key, 5)
    params: Params = {"embed": init_embedding(ke, cfg),
                      "ln_f": init_rmsnorm(cfg.d_model, cfg)}
    # stacked period blocks: params["blocks"][i] has leaves (R, ...)
    blocks = []
    for i in range(P):
        per_rep = [
            _init_layer(jax.random.fold_in(kl, r * P + i), cfg, kinds[i],
                        fkinds[i])
            for r in range(R)
        ]
        blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_rep))
    params["blocks"] = tuple(blocks)
    params["tail"] = tuple(
        _init_layer(jax.random.fold_in(kt, t), cfg, kinds[P * R + t],
                    fkinds[P * R + t])
        for t in range(tail)
    )
    if cfg.encoder_decoder:
        params["encoder"] = tuple(
            _init_encoder_layer(jax.random.fold_in(kenc, i), cfg)
            for i in range(cfg.n_encoder_layers)
        )
        params["ln_enc"] = init_rmsnorm(cfg.d_model, cfg)
    return params


# ------------------------------------------------------------- forward ----

def _layer_fwd(p: Params, x: jax.Array, cfg: ModelConfig, kind: str,
               fkind: str, aux: jax.Array,
               memory: Optional[jax.Array]) -> tuple:
    h = rmsnorm(p["ln1"], x, cfg)
    if kind in ATTN_KINDS:
        window = cfg.sliding_window if kind == "local" else None
        x = x + attention(p["attn"], h, cfg, window=window)
        if cfg.encoder_decoder and memory is not None:
            hx = rmsnorm(p["lnx"], x, cfg)
            x = x + attention(p["xattn"], hx, cfg, kv_src=memory,
                              causal=False)
    elif kind == "mamba":
        x = x + mamba(p["mamba"], h, cfg)
    elif kind == "slstm":
        x = x + slstm(p["cell"], h, cfg)
    elif kind == "mlstm":
        x = x + mlstm(p["cell"], h, cfg)
    if fkind != "none":
        h2 = rmsnorm(p["ln2"], x, cfg)
        if fkind == "moe":
            f, a = moe_ffn(p["ffn"], h2, cfg)
            aux = aux + a
        else:
            f = mlp(p["ffn"], h2, cfg)
        x = x + f
    return x, aux


def encode(params: Params, embeds: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Encoder stack (enc-dec models); embeds (B, S_enc, D) from the
    frontend stub."""
    x = embeds.astype(cdtype(cfg))
    for p in params["encoder"]:
        h = rmsnorm(p["ln1"], x, cfg)
        x = x + attention(p["attn"], h, cfg, causal=False)
        x = x + mlp(p["ffn"], rmsnorm(p["ln2"], x, cfg), cfg)
    return rmsnorm(params["ln_enc"], x, cfg)


def forward(params: Params, tokens: jax.Array, cfg: ModelConfig,
            memory: Optional[jax.Array] = None,
            embeds: Optional[jax.Array] = None,
            remat: bool = True,
            unroll: bool = False) -> tuple[jax.Array, jax.Array]:
    """Full-sequence pass -> (logits (B,S,V) f32, moe aux loss scalar)."""
    kinds, P, R, tail = layer_plan(cfg)
    fkinds = cfg.ffn_kinds()
    if embeds is not None:
        x = embeds.astype(cdtype(cfg))
    else:
        x = embed(params["embed"], tokens, cfg)
    aux0 = jnp.zeros((), jnp.float32)

    def superblock(carry, block_slice):
        x, aux = carry
        for i in range(P):
            x, aux = _layer_fwd(block_slice[i], x, cfg, kinds[i], fkinds[i],
                                aux, memory)
        return (x, aux), None

    sb = jax.checkpoint(superblock) if remat else superblock
    if R > 0 and not unroll:
        (x, aux), _ = jax.lax.scan(sb, (x, aux0), params["blocks"])
    elif R > 0:
        # analysis mode: python loop (exact XLA cost_analysis; see
        # analysis/loop_correct.py — scan bodies are otherwise counted once)
        aux = aux0
        for r in range(R):
            blk = jax.tree.map(lambda v: v[r], params["blocks"])
            (x, aux), _ = sb((x, aux), blk)
    else:
        aux = aux0
    for t in range(tail):
        x, aux = _layer_fwd(params["tail"][t], x, cfg, kinds[P * R + t],
                            fkinds[P * R + t], aux, memory)
    x = rmsnorm(params["ln_f"], x, cfg)
    logits = unembed(params["embed"], x, cfg)
    return logits, aux


# ---------------------------------------------------------------- decode --

class DecodeState(NamedTuple):
    block_caches: Tuple[Any, ...]   # per period position, leaves stacked (R,)
    tail_caches: Tuple[Any, ...]
    pos: jax.Array                  # (B,) int32: next position PER batch slot
    memory: Optional[jax.Array] = None  # enc-dec cross-attention memory


def _kind_cache(cfg: ModelConfig, kind: str, batch: int, capacity: int):
    if kind in ATTN_KINDS:
        cap = capacity if kind != "local" else min(
            capacity, cfg.sliding_window or capacity)
        return init_layer_cache(cfg, batch, cap)
    if kind == "mamba":
        return init_mamba_state(cfg, batch)
    if kind == "slstm":
        return init_slstm_state(cfg, batch)
    if kind == "mlstm":
        return init_mlstm_state(cfg, batch)
    raise ValueError(kind)


def init_decode_state(cfg: ModelConfig, batch: int, capacity: int,
                      memory: Optional[jax.Array] = None) -> DecodeState:
    kinds, P, R, tail = layer_plan(cfg)
    blocks = []
    for i in range(P):
        per_rep = [_kind_cache(cfg, kinds[i], batch, capacity)
                   for _ in range(R)]
        blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_rep))
    tails = tuple(_kind_cache(cfg, kinds[P * R + t], batch, capacity)
                  for t in range(tail))
    return DecodeState(block_caches=tuple(blocks), tail_caches=tails,
                       pos=jnp.zeros((batch,), jnp.int32), memory=memory)


def reset_decode_slot(cfg: ModelConfig, state: DecodeState, slot,
                      capacity: int) -> DecodeState:
    """Re-initialize batch slot ``slot`` of a ``DecodeState`` for a fresh
    request: position back to 0 and every per-slot row of every cache /
    recurrent state restored to its init value (zero KV rows, unit
    quantization scales, zero mamba/xlstm states).

    This is the admission-time reset a continuous-batching engine needs:
    without it a request admitted into a freed slot inherits the previous
    occupant's position and cached keys/values. ``slot`` may be a traced
    int32 scalar, so the whole reset jits to one program (jit it with
    ``donate_argnums=(0,)`` so the state is rewritten in place rather than
    copied per admission — see ``serve.engine.ServeEngine``).
    """
    fresh = init_decode_state(cfg, 1, capacity=capacity)

    def _write_row(batch_axis):
        def write(full, one):
            start = [jnp.zeros((), jnp.int32)] * full.ndim
            start[batch_axis] = jnp.asarray(slot, jnp.int32)
            return jax.lax.dynamic_update_slice(
                full, one.astype(full.dtype), tuple(start))
        return write

    # scanned block caches lead with (R,), so batch is axis 1; tail caches
    # lead with batch
    blocks = jax.tree.map(_write_row(1), state.block_caches,
                          fresh.block_caches)
    tails = jax.tree.map(_write_row(0), state.tail_caches,
                         fresh.tail_caches)
    pos = state.pos.at[slot].set(0)
    memory = state.memory
    if memory is not None:
        # zero the slot's cross-attention memory too — stale encoder output
        # is the same leak class as stale KV. An enc-dec engine must install
        # the NEW request's encoder memory into this row after the reset.
        memory = _write_row(0)(memory, jnp.zeros_like(memory[:1]))
    return DecodeState(block_caches=blocks, tail_caches=tails, pos=pos,
                       memory=memory)


def _layer_dec(p: Params, x: jax.Array, cache, pos, cfg: ModelConfig,
               kind: str, fkind: str, memory) -> tuple:
    h = rmsnorm(p["ln1"], x, cfg)
    if kind in ATTN_KINDS:
        window = cfg.sliding_window if kind == "local" else None
        y, cache = attention_decode(p["attn"], h, cache, pos, cfg,
                                    window=window)
        x = x + y
        if cfg.encoder_decoder and memory is not None:
            hx = rmsnorm(p["lnx"], x, cfg)
            x = x + attention(p["xattn"], hx, cfg, kv_src=memory,
                              causal=False)
    elif kind == "mamba":
        y, cache = mamba_decode(p["mamba"], h, cache, cfg)
        x = x + y
    elif kind == "slstm":
        y, cache = slstm_decode(p["cell"], h, cache, cfg)
        x = x + y
    elif kind == "mlstm":
        y, cache = mlstm_decode(p["cell"], h, cache, cfg)
        x = x + y
    if fkind != "none":
        h2 = rmsnorm(p["ln2"], x, cfg)
        if fkind == "moe":
            f, _ = moe_ffn(p["ffn"], h2, cfg, no_drop=True)
        else:
            f = mlp(p["ffn"], h2, cfg)
        x = x + f
    return x, cache


def decode_step(params: Params, tokens: jax.Array, state: DecodeState,
                cfg: ModelConfig,
                unroll: bool = False) -> tuple[jax.Array, DecodeState]:
    """tokens (B, 1) -> (logits (B, 1, V), new state)."""
    kinds, P, R, tail = layer_plan(cfg)
    fkinds = cfg.ffn_kinds()
    x = embed(params["embed"], tokens, cfg)
    pos = state.pos

    def superblock(carry, scanned):
        x = carry
        block_slice, cache_slice = scanned
        new_caches = []
        for i in range(P):
            x, c = _layer_dec(block_slice[i], x, cache_slice[i], pos, cfg,
                              kinds[i], fkinds[i], state.memory)
            new_caches.append(c)
        return x, tuple(new_caches)

    if R > 0 and not unroll:
        x, new_block_caches = jax.lax.scan(
            superblock, x, (params["blocks"], state.block_caches))
    elif R > 0:
        caches_out = []
        for r in range(R):
            blk = jax.tree.map(lambda v: v[r], params["blocks"])
            cch = jax.tree.map(lambda v: v[r], state.block_caches)
            x, c = superblock(x, (blk, cch))
            caches_out.append(c)
        new_block_caches = jax.tree.map(
            lambda *xs: jnp.stack(xs), *caches_out)
    else:
        new_block_caches = state.block_caches
    new_tails = []
    for t in range(tail):
        x, c = _layer_dec(params["tail"][t], x, state.tail_caches[t], pos,
                          cfg, kinds[P * R + t], fkinds[P * R + t],
                          state.memory)
        new_tails.append(c)
    x = rmsnorm(params["ln_f"], x, cfg)
    logits = unembed(params["embed"], x, cfg)
    return logits, DecodeState(block_caches=new_block_caches,
                               tail_caches=tuple(new_tails), pos=pos + 1,
                               memory=state.memory)
