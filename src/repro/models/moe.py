"""Mixture-of-Experts FFN with top-k routing (qwen2-moe, arctic, jamba).

Dispatch is *grouped sort-based* scatter/gather (§Perf iteration 4):
tokens are split into groups of ``moe_group_size``; within each group the
(token, k) pairs are sorted by expert id, the rank inside each expert
segment is the capacity slot (rank = position - searchsorted(segment
start)), and tokens scatter-add into per-expert buffers / gather back out.

Why not the classic one-hot dispatch einsum (t5x-style (T, E, C) tensors):
at prefill_32k scale (T ~ 1e6 tokens, E = 60, C ~ 87k) that tensor is
O(10^14) elements — the baseline dry-run measured 66 TB/device of XLA
temps. The sort-based path materializes only O(T*K*D) values and
O(T*K) int32 indices, and the per-group cumulative ranks keep every
reduction local to a shard (groups shard over the DP axes; the expert
axis E shards over 'model' = EP).

Supports the assignment's variants:
  * shared experts always-on (qwen2-moe: 4 shared + 60 routed top-4)
  * dense residual FFN in parallel (arctic: dense path + 128e top-2)
  * no_drop mode (decode: capacity = group size, nothing dropped)

Returns the Switch-style load-balancing aux loss.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import Params, cdtype, init_linear, init_mlp, mlp, pdtype


def init_moe(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4)
    d_ff = cfg.expert_ff
    scale = (2.0 / (cfg.d_model + d_ff)) ** 0.5
    e, d = cfg.n_experts, cfg.d_model
    p: Params = {
        "router": init_linear(ks[0], d, e, cfg),
        # stacked expert weights: (E, d, ff) / (E, ff, d) — EP shards dim 0
        "w_gate": jax.random.normal(ks[1], (e, d, d_ff), pdtype(cfg)) * scale,
        "w_up": jax.random.normal(ks[2], (e, d, d_ff), pdtype(cfg)) * scale,
        "w_down": jax.random.normal(ks[3], (e, d_ff, d), pdtype(cfg)) * scale,
    }
    if cfg.n_shared_experts > 0:
        p["shared"] = init_mlp(jax.random.fold_in(key, 7), cfg,
                               d_ff=d_ff * cfg.n_shared_experts)
    if cfg.moe_dense_residual:
        p["dense"] = init_mlp(jax.random.fold_in(key, 11), cfg, d_ff=cfg.d_ff)
    return p


MOE_GROUP_SIZE = 2048


def _dispatch_group(xg, gate_idx, gate_vals, wg, wu, wd, E, cap, dtype):
    """One token group: xg (Tg, D), gate_idx/vals (Tg, K) -> (Tg, D).

    Sort-based slotting; everything O(Tg*K*D) — no (T, E, C) one-hots.
    """
    Tg, D = xg.shape
    K = gate_idx.shape[-1]
    TK = Tg * K
    flat_e = gate_idx.reshape(TK)
    flat_gate = gate_vals.reshape(TK)
    tok_of = jnp.repeat(jnp.arange(Tg), K)

    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # rank inside the expert segment = index - start of segment
    seg_start = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank_sorted = jnp.arange(TK) - seg_start
    # unsort the slot assignment back to (token, k) order
    slot = jnp.zeros((TK,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))
    valid = slot < cap
    buf_idx = jnp.where(valid, flat_e * cap + slot, E * cap)  # E*cap = trash

    # scatter tokens into per-expert buffers (+1 trash row for drops)
    vals = xg[tok_of] * valid[:, None].astype(xg.dtype)
    expert_in = jnp.zeros((E * cap + 1, D), dtype).at[buf_idx].add(
        vals.astype(dtype))
    expert_in = expert_in[:E * cap].reshape(E, cap, D)

    # expert FFN (batched over E — the EP axis)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, wg)) \
        * jnp.einsum("ecd,edf->ecf", expert_in, wu)
    expert_out = jnp.einsum("ecf,efd->ecd", h, wd).reshape(E * cap, D)
    expert_out = jnp.concatenate(
        [expert_out, jnp.zeros((1, D), dtype)], axis=0)

    # gather back + gate-weighted combine over K
    out_tk = expert_out[buf_idx] * (flat_gate * valid)[:, None].astype(dtype)
    out = jnp.zeros((Tg, D), dtype).at[tok_of].add(out_tk)

    # per-expert token counts for the aux loss (from segment boundaries)
    starts = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    counts = jnp.diff(jnp.append(starts, TK)).astype(jnp.float32)
    return out, counts


def moe_ffn(p: Params, x: jax.Array, cfg: ModelConfig,
            no_drop: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss).

    no_drop=True sets capacity = group size (nothing can overflow) — the
    decode-path mode, where dropping a token would corrupt generation.
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    T = B * S
    Tg = min(MOE_GROUP_SIZE, T)
    G = T // Tg
    if G * Tg != T:           # ragged small inputs: one group
        Tg, G = T, 1
    cap = Tg if no_drop else max(int(cfg.capacity_factor * Tg * K / E), 1)
    cap = min(cap, Tg)
    xt = x.reshape(G, Tg, D)

    router_logits = (xt.astype(jnp.float32)
                     @ p["router"]["w"].astype(jnp.float32))     # (G, Tg, E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)                # (G, Tg, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    wg = p["w_gate"].astype(cdtype(cfg))
    wu = p["w_up"].astype(cdtype(cfg))
    wd = p["w_down"].astype(cdtype(cfg))

    out, counts = jax.vmap(
        lambda xg, gi, gv: _dispatch_group(xg, gi, gv, wg, wu, wd, E, cap,
                                           cdtype(cfg))
    )(xt.astype(cdtype(cfg)), gate_idx, gate_vals.astype(jnp.float32))

    out = out.reshape(B, S, D).astype(x.dtype)

    # Switch aux loss: E * sum_e(fraction_routed_e * mean_prob_e)
    frac = jnp.sum(counts, axis=0) / (T * K)                     # (E,)
    mean_p = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac * mean_p)

    if "shared" in p:
        out = out + mlp(p["shared"], x, cfg)
    if "dense" in p:
        out = out + mlp(p["dense"], x, cfg)
    return out, aux.astype(jnp.float32)
