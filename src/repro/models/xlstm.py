"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) + sLSTM (scalar).

The xlstm-125m config has d_ff = 0 — FFN capacity lives inside the blocks via
the pre-up-projection (factor ``xlstm_proj_factor``). Both blocks expose a
full-sequence scan path and an O(1) decode step; like the Mamba layers this
is what makes the long_500k cell run where full attention cannot.

mLSTM: per-head matrix memory C_t = f_t C_{t-1} + i_t v_t k_t^T, query read
h_t = C_t q_t / max(|n_t^T q_t|, 1) with exponential gating stabilized by the
max-state m_t (as in the paper, App. A).
sLSTM: scalar-memory cells with exponential input gates and the same
stabilizer, block-diagonal recurrent weights (per-head).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (Params, cdtype, chunked_scan, init_linear,
                     linear, pdtype)


def _heads(cfg: ModelConfig) -> tuple[int, int]:
    n_h = cfg.n_heads
    d_in = int(cfg.xlstm_proj_factor * cfg.d_model)
    # round head dim down to keep shapes consistent
    hd = d_in // n_h
    return n_h, hd


# ------------------------------------------------------------------ mLSTM --

def init_mlstm(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    n_h, hd = _heads(cfg)
    d_in = n_h * hd
    ks = jax.random.split(key, 7)
    return {
        "up": init_linear(ks[0], d, 2 * d_in, cfg),           # x and gate z
        "q": init_linear(ks[1], d_in, d_in, cfg),
        "k": init_linear(ks[2], d_in, d_in, cfg),
        "v": init_linear(ks[3], d_in, d_in, cfg),
        "ifg": init_linear(ks[4], d_in, 3 * n_h, cfg, bias=True),  # i, f, o
        "down": init_linear(ks[5], d_in, d, cfg),
    }


class MLSTMState(NamedTuple):
    C: jax.Array   # (B, H, hd, hd) matrix memory
    n: jax.Array   # (B, H, hd)    normalizer
    m: jax.Array   # (B, H)        stabilizer (log domain)


def init_mlstm_state(cfg: ModelConfig, batch: int) -> MLSTMState:
    n_h, hd = _heads(cfg)
    return MLSTMState(
        C=jnp.zeros((batch, n_h, hd, hd), jnp.float32),
        n=jnp.zeros((batch, n_h, hd), jnp.float32),
        m=jnp.full((batch, n_h), -1e30, jnp.float32),
    )


def _mlstm_gates(p: Params, xu: jax.Array, cfg: ModelConfig, n_h: int):
    g = linear(p["ifg"], xu, cfg).astype(jnp.float32)
    i_, f_, o_ = jnp.split(g, 3, axis=-1)     # (..., H)
    return i_, f_, o_


def _mlstm_step(carry: MLSTMState, qkvifo, hd: int):
    q, k, v, i_, f_, o_ = qkvifo    # q/k/v (B,H,hd); i/f/o (B,H)
    C, n, m = carry
    logf = -jax.nn.softplus(-f_)                 # log sigmoid(f)
    m_new = jnp.maximum(logf + m, i_)
    fg = jnp.exp(logf + m - m_new)               # stabilized forget
    ig = jnp.exp(i_ - m_new)                     # stabilized input
    ks = k / (hd ** 0.5)
    C = fg[..., None, None] * C + ig[..., None, None] * (
        v[..., :, None] * ks[..., None, :])
    n = fg[..., None] * n + ig[..., None] * ks
    num = jnp.einsum("bhij,bhj->bhi", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, q)), 1.0)
    h = jax.nn.sigmoid(o_)[..., None] * num / den[..., None]
    return MLSTMState(C, n, m_new), h


def mlstm(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    B, S, D = x.shape
    n_h, hd = _heads(cfg)
    d_in = n_h * hd
    xu = linear(p["up"], x, cfg)
    xin, z = xu[..., :d_in], xu[..., d_in:]
    q = linear(p["q"], xin, cfg).reshape(B, S, n_h, hd).astype(jnp.float32)
    k = linear(p["k"], xin, cfg).reshape(B, S, n_h, hd).astype(jnp.float32)
    v = linear(p["v"], xin, cfg).reshape(B, S, n_h, hd).astype(jnp.float32)
    i_, f_, o_ = _mlstm_gates(p, xin, cfg, n_h)

    def step(carry, t):
        return _mlstm_step(carry, t, hd)

    st0 = init_mlstm_state(cfg, B)
    xs = (jnp.moveaxis(q, 1, 0), jnp.moveaxis(k, 1, 0), jnp.moveaxis(v, 1, 0),
          jnp.moveaxis(i_, 1, 0), jnp.moveaxis(f_, 1, 0),
          jnp.moveaxis(o_, 1, 0))
    _, hs = chunked_scan(step, st0, xs, chunk=128)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, d_in).astype(cdtype(cfg))
    return linear(p["down"], h * jax.nn.silu(z), cfg)


def mlstm_decode(p: Params, x: jax.Array, state: MLSTMState,
                 cfg: ModelConfig) -> Tuple[jax.Array, MLSTMState]:
    B, _, D = x.shape
    n_h, hd = _heads(cfg)
    d_in = n_h * hd
    xu = linear(p["up"], x, cfg)
    xin, z = xu[..., :d_in], xu[..., d_in:]
    q = linear(p["q"], xin, cfg).reshape(B, n_h, hd).astype(jnp.float32)
    k = linear(p["k"], xin, cfg).reshape(B, n_h, hd).astype(jnp.float32)
    v = linear(p["v"], xin, cfg).reshape(B, n_h, hd).astype(jnp.float32)
    i_, f_, o_ = _mlstm_gates(p, xin[:, 0], cfg, n_h)
    st, h = _mlstm_step(state, (q, k, v, i_, f_, o_), hd)
    h = h.reshape(B, 1, d_in).astype(cdtype(cfg))
    return linear(p["down"], h * jax.nn.silu(z), cfg), st


# ------------------------------------------------------------------ sLSTM --

def init_slstm(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    n_h, hd = _heads(cfg)
    d_in = n_h * hd
    ks = jax.random.split(key, 4)
    scale = (1.0 / d_in) ** 0.5
    return {
        "up": init_linear(ks[0], d, 2 * d_in, cfg),
        "wx": init_linear(ks[1], d_in, 4 * d_in, cfg, bias=True),  # i,f,z,o
        # block-diagonal recurrent weights (per head): (H, hd, 4*hd)
        "wr": jax.random.normal(ks[2], (n_h, hd, 4 * hd),
                                pdtype(cfg)) * scale,
        "down": init_linear(ks[3], d_in, d, cfg),
    }


class SLSTMState(NamedTuple):
    c: jax.Array  # (B, H, hd)
    n: jax.Array  # (B, H, hd)
    h: jax.Array  # (B, H, hd)
    m: jax.Array  # (B, H, hd)


def init_slstm_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    n_h, hd = _heads(cfg)
    z = jnp.zeros((batch, n_h, hd), jnp.float32)
    return SLSTMState(c=z, n=z, h=z, m=jnp.full_like(z, -1e30))


def _slstm_step(p: Params, carry: SLSTMState, gx, cfg: ModelConfig):
    c, n, h, m = carry
    wr = p["wr"].astype(jnp.float32)
    gr = jnp.einsum("bhj,hjk->bhk", h, wr)           # (B,H,4hd)
    g = gx + gr
    hd = c.shape[-1]
    gi, gf, gz, go = [g[..., k * hd:(k + 1) * hd] for k in range(4)]
    logf = -jax.nn.softplus(-gf)
    m_new = jnp.maximum(logf + m, gi)
    fg = jnp.exp(logf + m - m_new)
    ig = jnp.exp(gi - m_new)
    c = fg * c + ig * jnp.tanh(gz)
    n = fg * n + ig
    h_new = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1.0)
    return SLSTMState(c, n, h_new, m_new), h_new


def slstm(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    B, S, D = x.shape
    n_h, hd = _heads(cfg)
    d_in = n_h * hd
    xu = linear(p["up"], x, cfg)
    xin, z = xu[..., :d_in], xu[..., d_in:]
    gx = linear(p["wx"], xin, cfg).reshape(B, S, n_h, 4 * hd) \
        .astype(jnp.float32)

    def step(carry, g):
        return _slstm_step(p, carry, g, cfg)

    _, hs = chunked_scan(step, init_slstm_state(cfg, B),
                         jnp.moveaxis(gx, 1, 0), chunk=128)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, d_in).astype(cdtype(cfg))
    return linear(p["down"], h * jax.nn.silu(z), cfg)


def slstm_decode(p: Params, x: jax.Array, state: SLSTMState,
                 cfg: ModelConfig) -> Tuple[jax.Array, SLSTMState]:
    B, _, D = x.shape
    n_h, hd = _heads(cfg)
    d_in = n_h * hd
    xu = linear(p["up"], x, cfg)
    xin, z = xu[..., :d_in], xu[..., d_in:]
    gx = linear(p["wx"], xin, cfg).reshape(B, n_h, 4 * hd).astype(jnp.float32)
    st, h = _slstm_step(p, state, gx, cfg)
    h = h.reshape(B, 1, d_in).astype(cdtype(cfg))
    return linear(p["down"], h * jax.nn.silu(z), cfg), st
