"""GQA attention with RoPE, sliding-window masks, and KV-cache decode.

Three entry points per layer:
  * ``attention``        — full-sequence (train / prefill), causal (+window)
  * ``attention_decode`` — one new token against a cached K/V history
Cross-attention (enc-dec) reuses ``attention`` with precomputed KV and no
causal mask.

Sharding: heads are the TP axis (q/k/v/o projections sharded over 'model'),
sequence is shardable for the masked full-sequence path (SP), batch over
'data' (+'pod').
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (Params, apply_rope, cdtype, init_linear, linear,
                     rope_angles)


def init_attention(key, cfg: ModelConfig, cross: bool = False) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    hd = cfg.head_dim
    return {
        "q": init_linear(kq, cfg.d_model, cfg.n_heads * hd, cfg,
                         bias=cfg.attn_qkv_bias),
        "k": init_linear(kk, cfg.d_model, cfg.n_kv_heads * hd, cfg,
                         bias=cfg.attn_qkv_bias),
        "v": init_linear(kv, cfg.d_model, cfg.n_kv_heads * hd, cfg,
                         bias=cfg.attn_qkv_bias),
        "o": init_linear(ko, cfg.n_heads * hd, cfg.d_model, cfg),
    }


def _split_heads(x: jax.Array, n: int, hd: int) -> jax.Array:
    return x.reshape(*x.shape[:-1], n, hd)


def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    """(B, S, Hkv, hd) -> (B, S, Hkv*groups, hd) for GQA."""
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def _causal_window_mask(q_len: int, kv_len: int, window: Optional[int],
                        q_offset: int = 0) -> jax.Array:
    """True = attend. q positions are offset (prefill continuation)."""
    qi = jnp.arange(q_len)[:, None] + q_offset
    kj = jnp.arange(kv_len)[None, :]
    mask = kj <= qi
    if window is not None:
        mask = mask & (kj > qi - window)
    return mask


def _sdpa(q, k, v, mask, cfg: ModelConfig):
    """q: (B,S,H,hd) k/v: (B,T,H,hd); mask (S,T) or (B,S,T) or None."""
    hd = q.shape[-1]
    logits = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32)
    logits = logits * (hd ** -0.5)
    if cfg.attn_logit_softcap:
        c = cfg.attn_logit_softcap
        logits = c * jnp.tanh(logits / c)
    if mask is not None:
        if mask.ndim == 2:
            mask = mask[None, None, :, :]
        elif mask.ndim == 3:
            mask = mask[:, None, :, :]
        logits = jnp.where(mask, logits, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, v)


# query-chunk size above which the S^2 logits are never materialized at once
_CHUNK_Q = 512


def _sdpa_chunked(q, k, v, cfg: ModelConfig, causal: bool,
                  window: Optional[int], chunk: int = _CHUNK_Q):
    """Flash-style query-chunked attention: O(chunk * T) live logits.

    The full (S, T) score matrix of a 32k prefill is 100+ GB/device in f32 —
    this scans over query chunks (each chunk checkpointed, so the backward
    pass recomputes chunk logits instead of storing them). Same math as
    ``_sdpa``; the equivalence is asserted by tests/test_models_unit.py.
    """
    B, S, H, hd = q.shape
    T = k.shape[1]
    if S % chunk != 0 or S <= chunk:
        mask = _causal_window_mask(S, T, window) if causal else None
        return _sdpa(q, k, v, mask, cfg)
    nc = S // chunk
    qc = q.reshape(B, nc, chunk, H, hd)

    def one_chunk(i, qi):
        off = i * chunk
        mask = _causal_window_mask(chunk, T, window, q_offset=off) \
            if causal else None
        return _sdpa(qi, k, v, mask, cfg)

    @jax.checkpoint
    def body(i, qi):
        return one_chunk(i, qi)

    out = jax.lax.map(lambda args: body(*args),
                      (jnp.arange(nc), jnp.moveaxis(qc, 1, 0)))
    return jnp.moveaxis(out, 0, 1).reshape(B, S, H, hd)


def attention(p: Params, x: jax.Array, cfg: ModelConfig,
              window: Optional[int] = None,
              kv_src: Optional[jax.Array] = None,
              causal: bool = True,
              positions: Optional[jax.Array] = None) -> jax.Array:
    """Full-sequence attention. kv_src enables cross-attention (no RoPE/mask)."""
    B, S, _ = x.shape
    hd = cfg.head_dim
    groups = cfg.n_heads // cfg.n_kv_heads
    src = x if kv_src is None else kv_src
    q = _split_heads(linear(p["q"], x, cfg), cfg.n_heads, hd)
    k = _split_heads(linear(p["k"], src, cfg), cfg.n_kv_heads, hd)
    v = _split_heads(linear(p["v"], src, cfg), cfg.n_kv_heads, hd)
    if kv_src is None:  # self-attention: RoPE
        if positions is None:
            positions = jnp.arange(S)[None, :]
        cos, sin = rope_angles(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    out = _sdpa_chunked(q, k, v, cfg, causal=causal, window=window)
    return linear(p["o"], out.reshape(B, S, cfg.n_heads * hd), cfg)


# ------------------------------------------------------------ KV caching --

class LayerKVCache(NamedTuple):
    """Ring-buffer cache for one attention layer (window == capacity).

    int8 mode (§Perf iteration: long-context decode is KV-read bound):
    k/v stored int8 with per-(B, slot, head) f32 absmax scales — halves the
    HBM bytes per decoded token vs bf16 at <1e-2 logit error (tests).
    """
    k: jax.Array          # (B, W, Hkv, hd) compute dtype or int8
    v: jax.Array          # (B, W, Hkv, hd)
    k_scale: jax.Array    # (B, W, Hkv) f32; ones when not quantized
    v_scale: jax.Array


def init_layer_cache(cfg: ModelConfig, batch: int, capacity: int,
                     dtype=None) -> LayerKVCache:
    hd = cfg.head_dim
    quant = getattr(cfg, "kv_cache_dtype", "compute") == "int8"
    dt = jnp.int8 if quant else (dtype or cdtype(cfg))
    shape = (batch, capacity, cfg.n_kv_heads, hd)
    sshape = (batch, capacity, cfg.n_kv_heads)
    return LayerKVCache(k=jnp.zeros(shape, dt), v=jnp.zeros(shape, dt),
                        k_scale=jnp.ones(sshape, jnp.float32),
                        v_scale=jnp.ones(sshape, jnp.float32))


def _quantize_kv(x: jax.Array):
    """x (B, 1, Hkv, hd) -> (int8 values, (B, 1, Hkv) scales)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-10)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_kv(q: jax.Array, scale: jax.Array, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def attention_decode(p: Params, x: jax.Array, cache: LayerKVCache,
                     pos: jax.Array, cfg: ModelConfig,
                     window: Optional[int] = None
                     ) -> tuple[jax.Array, LayerKVCache]:
    """One-token decode: x (B, 1, D), pos (B,) int32 per-batch-slot current
    index (a scalar broadcasts — every slot at the same position).

    The cache is a ring buffer of length W (= full seq for global layers,
    sliding window for local layers): slot_b = pos_b % W. Positions are
    per batch element so a continuous-batching engine can run each slot's
    request from its own position 0 — the validity mask below then hides
    whatever a previous occupant left in the ring.
    """
    B = x.shape[0]
    hd = cfg.head_dim
    groups = cfg.n_heads // cfg.n_kv_heads
    W = cache.k.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.full((B,), pos, jnp.int32)
    q = _split_heads(linear(p["q"], x, cfg), cfg.n_heads, hd)    # (B,1,H,hd)
    k = _split_heads(linear(p["k"], x, cfg), cfg.n_kv_heads, hd)
    v = _split_heads(linear(p["v"], x, cfg), cfg.n_kv_heads, hd)
    cos, sin = rope_angles(pos[:, None], hd, cfg.rope_theta)  # (B,1,hd/2)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    slot = jnp.mod(pos, W).astype(jnp.int32)                  # (B,)
    rows = jnp.arange(B)
    quant = cache.k.dtype == jnp.int8
    if quant:
        kq, ks_new = _quantize_kv(k)
        vq, vs_new = _quantize_kv(v)
        ck = cache.k.at[rows, slot].set(kq[:, 0])
        cv = cache.v.at[rows, slot].set(vq[:, 0])
        kscale = cache.k_scale.at[rows, slot].set(ks_new[:, 0])
        vscale = cache.v_scale.at[rows, slot].set(vs_new[:, 0])
    else:
        ck = cache.k.at[rows, slot].set(k[:, 0].astype(cache.k.dtype))
        cv = cache.v.at[rows, slot].set(v[:, 0].astype(cache.v.dtype))
        kscale, vscale = cache.k_scale, cache.v_scale
    # valid slots: ring indices holding positions in (pos-W, pos], per batch
    idx = jnp.arange(W)
    # absolute position stored in ring slot i (given current write at `slot`)
    age = jnp.mod(slot[:, None] - idx[None, :], W)            # (B,W) 0=newest
    valid = age <= jnp.minimum(pos, W - 1)[:, None]
    if window is not None:
        valid = valid & (age < window)
    if quant:
        kk = _repeat_kv(_dequantize_kv(ck, kscale, x.dtype), groups)
        vv = _repeat_kv(_dequantize_kv(cv, vscale, x.dtype), groups)
    else:
        kk = _repeat_kv(ck, groups)
        vv = _repeat_kv(cv, groups)
    logits = jnp.einsum("bshd,bthd->bhst", q, kk).astype(jnp.float32)
    logits = logits * (hd ** -0.5)
    if cfg.attn_logit_softcap:
        c = cfg.attn_logit_softcap
        logits = c * jnp.tanh(logits / c)
    logits = jnp.where(valid[:, None, None, :], logits,
                       jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhst,bthd->bshd", probs, vv)
    y = linear(p["o"], out.reshape(B, 1, cfg.n_heads * hd), cfg)
    return y, LayerKVCache(k=ck, v=cv, k_scale=kscale, v_scale=vscale)
