"""Mamba-style selective SSM block (jamba's 'mamba' layers).

Selective state-space recurrence (Gu & Dao, arXiv:2312.00752) with input-
dependent (dt, B, C). Implemented as an associative-scan-friendly diagonal
recurrence: h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t ; y_t = C_t h_t.
We use ``jax.lax.scan`` over the sequence (training/prefill) and an O(1)
single-step update for decode — the property that makes jamba's long_500k
cell feasible where full attention is not.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (Params, cdtype, chunked_scan, init_linear,
                     linear, pdtype)


def init_mamba(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    n = cfg.ssm_state_dim
    ks = jax.random.split(key, 6)
    p = {
        "in_proj": init_linear(ks[0], d, 2 * d_in, cfg),     # x and gate z
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv_dim, d_in),
                                    pdtype(cfg)) * 0.2,
        "conv_b": jnp.zeros((d_in,), pdtype(cfg)),
        "bc_proj": init_linear(ks[2], d_in, 2 * n, cfg),     # B_t, C_t
        "dt_proj": init_linear(ks[3], d_in, d_in, cfg, bias=True),
        "A_log": jnp.log(jnp.arange(1, n + 1, dtype=pdtype(cfg))
                         )[None, :].repeat(d_in, 0),         # (d_in, n)
        "D": jnp.ones((d_in,), pdtype(cfg)),
        "out_proj": init_linear(ks[4], d_in, d, cfg),
    }
    return p


class MambaState(NamedTuple):
    h: jax.Array        # (B, d_in, n) SSM state
    conv: jax.Array     # (B, conv_dim-1, d_in) trailing inputs for the conv


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=None) -> MambaState:
    d_in = cfg.ssm_expand * cfg.d_model
    dt = dtype or cdtype(cfg)
    return MambaState(
        h=jnp.zeros((batch, d_in, cfg.ssm_state_dim), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv_dim - 1, d_in), dt),
    )


def _ssm_params(p: Params, xz: jax.Array, cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    x, z = xz[..., :d_in], xz[..., d_in:]
    return x, z


def _causal_conv(p: Params, x: jax.Array, cfg: ModelConfig,
                 state: jax.Array | None = None):
    """Depthwise causal conv over sequence; x (B, S, d_in)."""
    k = cfg.ssm_conv_dim
    w = p["conv_w"].astype(x.dtype)     # (k, d_in)
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)     # (B, S+k-1, d_in)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    out = out + p["conv_b"].astype(x.dtype)[None, None, :]
    new_state = xp[:, -(k - 1):, :] if k > 1 else pad
    return jax.nn.silu(out), new_state


def mamba(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Full-sequence pass; x (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    d_in = cfg.ssm_expand * D
    n = cfg.ssm_state_dim
    xz = linear(p["in_proj"], x, cfg)
    xs, z = _ssm_params(p, xz, cfg)
    xs, _ = _causal_conv(p, xs, cfg)

    bc = linear(p["bc_proj"], xs, cfg).astype(jnp.float32)   # (B,S,2n)
    Bt, Ct = bc[..., :n], bc[..., n:]
    dt = jax.nn.softplus(linear(p["dt_proj"], xs, cfg)
                         .astype(jnp.float32))                # (B,S,d_in)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # (d_in, n)
    xf = xs.astype(jnp.float32)

    def step(h, inputs):
        xt, dtt, bt, ct = inputs   # (B,d_in) (B,d_in) (B,n) (B,n)
        decay = jnp.exp(dtt[..., None] * A[None])             # (B,d_in,n)
        h = decay * h + (dtt * xt)[..., None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    h0 = jnp.zeros((B, d_in, n), jnp.float32)
    _, ys = chunked_scan(step, h0,
                         (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dt, 1, 0),
                          jnp.moveaxis(Bt, 1, 0), jnp.moveaxis(Ct, 1, 0)),
                         chunk=128)
    y = jnp.moveaxis(ys, 0, 1) + xf * p["D"].astype(jnp.float32)[None, None]
    y = (y.astype(cdtype(cfg)) * jax.nn.silu(z))
    return linear(p["out_proj"], y, cfg)


def mamba_decode(p: Params, x: jax.Array, state: MambaState,
                 cfg: ModelConfig) -> Tuple[jax.Array, MambaState]:
    """Single-token decode; x (B, 1, D). O(1) state update."""
    B, _, D = x.shape
    d_in = cfg.ssm_expand * D
    n = cfg.ssm_state_dim
    xz = linear(p["in_proj"], x, cfg)
    xs, z = _ssm_params(p, xz, cfg)
    xs, conv_state = _causal_conv(p, xs, cfg, state=state.conv)

    bc = linear(p["bc_proj"], xs, cfg).astype(jnp.float32)
    Bt, Ct = bc[..., :n], bc[..., n:]
    dt = jax.nn.softplus(linear(p["dt_proj"], xs, cfg).astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xf = xs.astype(jnp.float32)

    decay = jnp.exp(dt[:, 0, :, None] * A[None])
    h = decay * state.h + (dt[:, 0] * xf[:, 0])[..., None] * Bt[:, 0][:, None]
    y = jnp.einsum("bdn,bn->bd", h, Ct[:, 0])[:, None, :]
    y = y + xf * p["D"].astype(jnp.float32)[None, None]
    y = (y.astype(cdtype(cfg)) * jax.nn.silu(z))
    return linear(p["out_proj"], y, cfg), MambaState(h=h, conv=conv_state)
