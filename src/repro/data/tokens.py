"""Synthetic deterministic token pipeline.

Host-side generator producing shardable batches: each (host, step) pair maps
to a disjoint PRNG stream, so data-parallel workers never need coordination
and restart-from-checkpoint reproduces the exact stream (the cursor is part
of the checkpoint). A lightweight Zipf-ish unigram over the vocab plus a
Markov bigram mixer gives losses that actually *decrease* during the example
runs (pure uniform tokens would not).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    zipf_a: float = 1.2


class TokenPipeline:
    """Deterministic, seekable synthetic stream (the data substrate)."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, n_hosts: int = 1):
        assert cfg.global_batch % n_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.local_batch = cfg.global_batch // n_hosts
        self._step = 0
        rng = np.random.default_rng(cfg.seed)
        # fixed unigram (Zipf) + a sparse "bigram" shift pattern
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._unigram = p / p.sum()
        self._shift = rng.integers(1, cfg.vocab_size,
                                   size=min(cfg.vocab_size, 4096))

    @property
    def step(self) -> int:
        return self._step

    def seek(self, step: int) -> None:
        """Restart support: position the stream at `step` (O(1))."""
        self._step = step

    def next_batch(self) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, self.host_id, self._step))
        toks = rng.choice(cfg.vocab_size, p=self._unigram,
                          size=(self.local_batch, cfg.seq_len + 1))
        # inject predictable structure: half the positions continue a pattern
        mixer = self._shift[toks[:, :-1] % len(self._shift)]
        structured = (toks[:, :-1] + mixer) % cfg.vocab_size
        mask = rng.random((self.local_batch, cfg.seq_len)) < 0.5
        nxt = np.where(mask, structured, toks[:, 1:])
        batch = {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": nxt.astype(np.int32),
        }
        self._step += 1
        return batch

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()
