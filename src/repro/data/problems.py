"""Synthetic GSYEIG problem generators shaped like the paper's two workloads.

Both are constructed as A = U^T C U, B = U^T U with a *known* spectrum for C,
so tests have exact ground truth: the generalized eigenvalues of (A, B) are
exactly the chosen spectrum and the eigenvectors are U^{-1} Q.

  * ``md_like``  — molecular-dynamics NMA (iMod): A and B both SPD, smooth
    low-frequency end, moderate Lanczos iteration counts (paper Exp. 1).
  * ``dft_like`` — FLEUR/DFT: A symmetric indefinite-ish spectrum with a
    *clustered* lower end, B ≈ overlap matrix close to I; drives Lanczos to
    many iterations (paper Exp. 2's 4k iterations).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class GSyEigProblem(NamedTuple):
    A: jax.Array
    B: jax.Array
    exact_evals: jax.Array  # full spectrum, ascending
    name: str


def _random_orthogonal(n: int, key: jax.Array, dtype) -> jax.Array:
    M = jax.random.normal(key, (n, n), dtype)
    Q, R = jnp.linalg.qr(M)
    # fix signs for determinism
    return Q * jnp.sign(jnp.diagonal(R))[None, :]


def _assemble(n: int, spectrum: jax.Array, key: jax.Array, dtype,
              b_offdiag: float, name: str) -> GSyEigProblem:
    kq, ku = jax.random.split(key)
    Q = _random_orthogonal(n, kq, dtype)
    C = (Q * spectrum[None, :]) @ Q.T
    C = 0.5 * (C + C.T)
    # U = I + small strictly-upper noise: B = U^T U is SPD, well conditioned
    noise = jax.random.normal(ku, (n, n), dtype) * (b_offdiag / jnp.sqrt(n))
    U = jnp.eye(n, dtype=dtype) + jnp.triu(noise, k=1)
    A = U.T @ C @ U
    A = 0.5 * (A + A.T)
    B = U.T @ U
    B = 0.5 * (B + B.T)
    return GSyEigProblem(A=A, B=B, exact_evals=jnp.sort(spectrum), name=name)


def md_like(n: int, key: jax.Array | None = None,
            dtype=jnp.float64) -> GSyEigProblem:
    """Both A, B SPD; spectrum spans ~4 decades, smooth low end (NMA modes)."""
    if key is None:
        key = jax.random.PRNGKey(9997)
    kq, ks = jax.random.split(key)
    # positive spectrum, log-spaced + jitter: lowest modes well separated
    base = jnp.logspace(-2.0, 2.0, n, dtype=dtype)
    jitter = 1.0 + 0.01 * jax.random.uniform(ks, (n,), dtype)
    spectrum = base * jitter
    return _assemble(n, spectrum, kq, dtype, b_offdiag=0.3, name="md")


def dft_like(n: int, key: jax.Array | None = None,
             dtype=jnp.float64) -> GSyEigProblem:
    """Symmetric A (negative + positive), tight cluster at the low end; B≈I.

    The clustered valence band means slow Lanczos convergence — this is what
    produced the paper's 4k-iteration counts in Experiment 2.
    """
    if key is None:
        key = jax.random.PRNGKey(17243)
    kq, ks = jax.random.split(key)
    n_low = max(n // 10, 4)
    # low cluster: tightly spaced "valence" states
    low = -1.0 + 0.02 * jnp.arange(n_low, dtype=dtype) / n_low
    # the rest: spread "conduction" states
    high = jnp.linspace(0.0, 50.0, n - n_low, dtype=dtype)
    spectrum = jnp.concatenate([low, high])
    jitter = 1.0 + 1e-3 * jax.random.uniform(ks, (n,), dtype)
    spectrum = spectrum * jitter
    return _assemble(n, spectrum, kq, dtype, b_offdiag=0.1, name="dft")


def paper_shapes() -> dict:
    """The paper's two experiment sizes (for --full benchmark runs)."""
    return {
        "md": dict(n=9_997, s=100),
        "dft": dict(n=17_243, s=448),
    }
