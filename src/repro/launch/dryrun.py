import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede every other import (jax locks the device count on first
# init). 512 host devices let jax.make_mesh build the production meshes.

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, arch_shapes, get_config  # noqa: E402
from repro.launch.mesh import make_production_mesh           # noqa: E402
from repro.launch.specs import (prefill_specs, serve_specs,   # noqa: E402
                                train_specs)
from repro.models.config import shape_by_name                 # noqa: E402
from repro.train.optimizer import OptimizerConfig             # noqa: E402
from repro.train.train_step import (make_serve_step,          # noqa: E402
                                    make_train_step)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces (and persists under artifacts/dryrun/):
  * compiled.memory_analysis()  — per-device bytes (the "fits?" proof),
  * compiled.cost_analysis()    — HLO flops/bytes for the roofline terms,
  * the collective-bytes table parsed from the optimized HLO
    (all-gather / all-reduce / reduce-scatter / all-to-all /
     collective-permute operand sizes — cost_analysis does not report them).

Shape semantics per the assignment: train_4k lowers train_step;
prefill_32k lowers the full-sequence prefill; decode_32k / long_500k lower
serve_step (ONE new token against a seq_len KV cache).
"""

try:                                  # jax >= 0.5 ambient-mesh API
    _set_mesh = jax.set_mesh
except AttributeError:                # 0.4.x: specs carry NamedShardings,
    import contextlib                 # no ambient mesh needed for .lower()

    def _set_mesh(_mesh):
        return contextlib.nullcontext()

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "c64": 8}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in the optimized HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    # lines look like:  %x = bf16[4,128]{1,0} all-gather(...), replica_groups=
    pat = re.compile(
        r"=\s*(?:\(?)([a-z0-9]+)\[([0-9,]*)\][^=]*?\b(" +
        "|".join(_COLLECTIVES) + r")\b")
    for m in pat.finditer(hlo_text):
        dt, dims, kind = m.group(1), m.group(2), m.group(3)
        nbytes = _DTYPE_BYTES.get(dt, 4)
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        out[kind] += n * nbytes
        counts[kind] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": int(sum(out.values()))}


def lower_cell(mesh, arch: str, shape_name: str,
               serve_sharding: str = "fsdp", kv_dtype: str = "compute",
               serve_dtype: str | None = None):
    """Returns (lowered, kind). Lowering is cheap; compile happens later.

    serve_sharding: 'fsdp' (baseline — weights DP-sharded, gathered per
    token) or 'replicated' (§Perf iteration 1 — weights replicated over DP,
    TP-only; no per-token parameter collectives).
    """
    cfg = get_config(arch)
    if kv_dtype != "compute":
        cfg = cfg.scaled(kv_cache_dtype=kv_dtype)
    if serve_dtype is not None:
        cfg = cfg.scaled(param_dtype=serve_dtype)
    shape = shape_by_name(shape_name)
    if shape.kind == "train":
        state_specs, batch_specs = train_specs(mesh, cfg, shape)
        step = make_train_step(cfg, OptimizerConfig())
        with _set_mesh(mesh):
            lowered = jax.jit(step).lower(state_specs, batch_specs)
        return lowered, "train_step"
    if shape.kind == "prefill":
        param_specs, batch_specs = prefill_specs(mesh, cfg, shape)
        from repro.train.train_step import make_prefill
        pf = make_prefill(cfg)
        with _set_mesh(mesh):
            if cfg.encoder_decoder:
                lowered = jax.jit(pf).lower(param_specs,
                                            batch_specs["tokens"],
                                            batch_specs["embeds"])
            else:
                lowered = jax.jit(pf).lower(param_specs,
                                            batch_specs["tokens"])
        return lowered, "prefill"
    # decode
    param_specs, token_specs, state_specs = serve_specs(
        mesh, cfg, shape, fsdp_params=(serve_sharding == "fsdp"))
    serve = make_serve_step(cfg)
    with _set_mesh(mesh):
        lowered = jax.jit(serve).lower(param_specs, token_specs, state_specs)
    return lowered, "serve_step"


def run_cell(mesh, mesh_name: str, arch: str, shape_name: str,
             outdir: str, compile_: bool = True,
             serve_sharding: str = "fsdp", kv_dtype: str = "compute",
             serve_dtype: str | None = None) -> dict:
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "status": "ok", "serve_sharding": serve_sharding,
           "kv_dtype": kv_dtype, "serve_dtype": serve_dtype}
    try:
        lowered, kind = lower_cell(mesh, arch, shape_name,
                                   serve_sharding=serve_sharding,
                                   kv_dtype=kv_dtype,
                                   serve_dtype=serve_dtype)
        rec["kind"] = kind
        rec["t_lower_s"] = round(time.time() - t0, 2)
        if compile_:
            t1 = time.time()
            compiled = lowered.compile()
            rec["t_compile_s"] = round(time.time() - t1, 2)
            # collectives exist only AFTER SPMD partitioning -> compiled HLO
            rec["collectives"] = parse_collective_bytes(compiled.as_text())
            from repro.analysis.roofline import cost_analysis_dict
            ca = cost_analysis_dict(compiled)
            rec["cost_analysis"] = {
                "flops": float(ca.get("flops", -1.0)),
                "bytes_accessed": float(ca.get("bytes accessed", -1.0)),
                "transcendentals": float(ca.get("transcendentals", -1.0)),
            }
            ma = compiled.memory_analysis()
            if ma is not None:
                rec["memory_analysis"] = {
                    k: int(getattr(ma, k))
                    for k in ("argument_size_in_bytes",
                              "output_size_in_bytes",
                              "temp_size_in_bytes",
                              "generated_code_size_in_bytes")
                    if hasattr(ma, k)
                }
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug report
        rec["status"] = "FAIL"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["t_total_s"] = round(time.time() - t0, 2)
    os.makedirs(outdir, exist_ok=True)
    safe = f"{arch}_{shape_name}_{mesh_name}".replace("/", "_")
    with open(os.path.join(outdir, safe + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all'")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--outdir", default="artifacts/dryrun")
    ap.add_argument("--no-compile", action="store_true",
                    help="lower + parse HLO only (fast pass)")
    ap.add_argument("--serve-sharding", default="fsdp",
                    choices=["fsdp", "replicated"])
    ap.add_argument("--kv-dtype", default="compute",
                    choices=["compute", "int8"])
    ap.add_argument("--serve-dtype", default=None,
                    choices=[None, "bfloat16"])
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("pod16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("pods2x16x16", make_production_mesh(multi_pod=True)))

    n_fail = 0
    for mesh_name, mesh in meshes:
        for arch in archs:
            shapes = [s.name for s in arch_shapes(arch)]
            if args.shape != "all":
                if args.shape not in shapes:
                    continue
                shapes = [args.shape]
            for shape_name in shapes:
                rec = run_cell(mesh, mesh_name, arch, shape_name,
                               args.outdir, compile_=not args.no_compile,
                               serve_sharding=args.serve_sharding,
                               kv_dtype=args.kv_dtype,
                               serve_dtype=args.serve_dtype)
                flops = rec.get("cost_analysis", {}).get("flops", -1)
                coll = rec.get("collectives", {}).get("total_bytes", -1)
                print(f"[{rec['status']:4s}] {mesh_name:12s} {arch:22s} "
                      f"{shape_name:12s} kind={rec.get('kind', '?'):10s} "
                      f"lower={rec.get('t_lower_s', 0):7.1f}s "
                      f"compile={rec.get('t_compile_s', 0):7.1f}s "
                      f"flops={flops:.3e} coll_bytes={coll:.3e}"
                      if rec["status"] == "ok" else
                      f"[FAIL] {mesh_name} {arch} {shape_name}: "
                      f"{rec.get('error', '')[:200]}", flush=True)
                if rec["status"] != "ok":
                    n_fail += 1
    print(f"dry-run complete; failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
