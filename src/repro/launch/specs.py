"""input_specs(): ShapeDtypeStruct stand-ins for every (arch x shape) cell.

No device allocation anywhere — shapes come from jax.eval_shape over the
real init functions, shardings are attached directly to the structs (the
pattern AOT .lower() consumes).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.dist.partitioning import (batch_shardings, decode_state_shardings,
                                     opt_state_shardings, param_shardings,
                                     replicated)
from repro.models.config import ModelConfig, ShapeConfig, shape_by_name
from repro.models.model import init_decode_state, init_params
from repro.train.train_step import init_train_state


def _with_shardings(shapes: Any, shardings: Any) -> Any:
    return jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
        shapes, shardings)


def train_specs(mesh, cfg: ModelConfig, shape: ShapeConfig):
    """(state_specs, batch_specs) for train_step lowering."""
    key = jax.random.PRNGKey(0)
    state_shape = jax.eval_shape(
        functools.partial(init_train_state, cfg=cfg), key)
    from repro.train.train_step import TrainState
    p_sh = param_shardings(mesh, state_shape.params)
    o_sh = opt_state_shardings(mesh, state_shape.opt)
    state_sharding = TrainState(params=p_sh, opt=o_sh,
                                step=replicated(mesh, state_shape.step))
    state_specs = _with_shardings(state_shape, state_sharding)

    B, S = shape.global_batch, shape.seq_len
    batch_shape = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if cfg.encoder_decoder:
        batch_shape["embeds"] = jax.ShapeDtypeStruct(
            (B, S, cfg.d_model), jnp.float32)
    b_sh = batch_shardings(mesh, batch_shape)
    batch_specs = _with_shardings(batch_shape, b_sh)
    return state_specs, batch_specs


def serve_specs(mesh, cfg: ModelConfig, shape: ShapeConfig,
                fsdp_params: bool = True):
    """(param_specs, token_specs, state_specs) for serve_step lowering.

    The decode cell means: one new token against a KV history of
    ``shape.seq_len`` (capacity = seq_len ring buffers).

    fsdp_params=False is the serving-optimized sharding (§Perf iteration 1):
    weights replicated over the DP axes + TP-sharded over 'model', so no
    per-token parameter all-gathers — decode reads weights from local HBM.
    """
    key = jax.random.PRNGKey(0)
    B, S = shape.global_batch, shape.seq_len
    params_shape = jax.eval_shape(
        functools.partial(init_params, cfg=cfg), key)
    p_sh = param_shardings(mesh, params_shape, fsdp=fsdp_params)
    param_specs = _with_shardings(params_shape, p_sh)

    memory_shape = None
    if cfg.encoder_decoder:
        memory_shape = jax.ShapeDtypeStruct(
            (B, min(S, 4096), cfg.d_model), jnp.dtype(cfg.dtype))
    state_shape = jax.eval_shape(
        lambda: init_decode_state(cfg, B, capacity=S, memory=memory_shape
                                  if memory_shape is None else
                                  jnp.zeros(memory_shape.shape,
                                            memory_shape.dtype)))
    s_sh = decode_state_shardings(mesh, state_shape)
    state_specs = _with_shardings(state_shape, s_sh)

    tok_shape = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
    t_sh = batch_shardings(mesh, tok_shape)
    token_specs = _with_shardings(tok_shape, t_sh)["tokens"]
    return param_specs, token_specs, state_specs


def prefill_specs(mesh, cfg: ModelConfig, shape: ShapeConfig):
    """(param_specs, batch_specs) for the prefill lowering."""
    key = jax.random.PRNGKey(0)
    B, S = shape.global_batch, shape.seq_len
    params_shape = jax.eval_shape(
        functools.partial(init_params, cfg=cfg), key)
    p_sh = param_shardings(mesh, params_shape)
    param_specs = _with_shardings(params_shape, p_sh)
    batch_shape = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.encoder_decoder:
        batch_shape["embeds"] = jax.ShapeDtypeStruct(
            (B, S, cfg.d_model), jnp.float32)
    b_sh = batch_shardings(mesh, batch_shape)
    batch_specs = _with_shardings(batch_shape, b_sh)
    return param_specs, batch_specs
