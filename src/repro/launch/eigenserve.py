"""CLI for the eigensolver serving engine: synthetic md/dft request
streams through shape-bucketed continuous batching.

    PYTHONPATH=src python -m repro.launch.eigenserve \
        --slots 4 --bucket-shapes 48,64 --requests 12 --stream mixed

Each request is one ``(A, B, s)`` pencil drawn from the paper's two
workload generators (``data.problems.md_like`` / ``dft_like``) at one of
the bucket shapes — the MD-timestep / DFT-SCF-iteration serving pattern.
``--oversize-every K`` injects an oversized pencil every K requests to
exercise the ``variant='auto'`` router fallback path (optionally onto a
device mesh via ``--mesh``/``--devices``).
"""
from __future__ import annotations

import os
import sys


def _early_device_count() -> int | None:
    """--devices must take effect before jax is imported (XLA_FLAGS)."""
    argv = sys.argv
    for i, a in enumerate(argv):
        if a == "--devices" and i + 1 < len(argv):
            return int(argv[i + 1])
        if a.startswith("--devices="):
            return int(a.split("=", 1)[1])
    return None


_n_dev = _early_device_count()
if _n_dev:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_n_dev}").strip()

import argparse  # noqa: E402
import json      # noqa: E402
import time      # noqa: E402

import jax       # noqa: E402

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro.data.problems import dft_like, md_like        # noqa: E402
from repro.serve.eigen_engine import EigenEngine          # noqa: E402


def _parse_mesh(spec: str | None):
    if not spec:
        return None
    dims = tuple(int(x) for x in spec.lower().split("x"))
    if len(dims) != 2:
        raise SystemExit(f"--mesh wants DATAxMODEL, e.g. 4x2; got {spec!r}")
    return jax.make_mesh(dims, ("data", "model"))


def request_stream(kinds, shapes, n_requests: int, seed: int,
                   oversize_every: int, oversize_n: int):
    """Yield (problem, workload, invert) tuples round-robin over
    (workload, shape); every ``oversize_every``-th request is an oversized
    pencil destined for the router path."""
    gens = {"md": md_like, "dft": dft_like}
    for i in range(n_requests):
        kind = kinds[i % len(kinds)]
        oversized = oversize_every and (i + 1) % oversize_every == 0
        n = oversize_n if oversized else shapes[(i // len(kinds)) % len(shapes)]
        prob = gens[kind](n, key=jax.random.PRNGKey(seed * 100_003 + i))
        # the paper's MD trick: Krylov service of the MD smallest end works
        # on the inverse pair (md_like's A is SPD)
        yield prob, kind, kind == "md"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=4,
                    help="seats per shape bucket (batched dispatch size)")
    ap.add_argument("--bucket-shapes", default="48,64",
                    help="comma-separated admissible n values")
    ap.add_argument("--stream", choices=["md", "dft", "mixed"],
                    default="mixed")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--s", type=int, default=4)
    ap.add_argument("--variant", choices=["TD", "TT", "KE", "KI"],
                    default="TD")
    ap.add_argument("--band-width", type=int, default=8)
    ap.add_argument("--max-restarts", type=int, default=200)
    ap.add_argument("--max-batched-n", type=int, default=256)
    ap.add_argument("--oversize-every", type=int, default=0,
                    help="inject an oversized (router-path) request every "
                         "K submissions (0 = never)")
    ap.add_argument("--oversize-n", type=int, default=320)
    ap.add_argument("--mesh", default=None,
                    help="DATAxMODEL mesh for the router fallback path")
    ap.add_argument("--devices", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--on-failure", choices=["recover", "warn", "ignore"],
                    default="recover",
                    help="per-lane failure policy: 'recover' quarantines "
                         "unhealthy/unconverged lanes and retries them up "
                         "the degradation ladder, dead-lettering what "
                         "cannot be saved")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="individual retries per quarantined lane before "
                         "it is dead-lettered")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    shapes = [int(x) for x in args.bucket_shapes.split(",") if x]
    kinds = ["md", "dft"] if args.stream == "mixed" else [args.stream]
    engine = EigenEngine(slots=args.slots, bucket_shapes=shapes,
                         variant=args.variant,
                         max_batched_n=args.max_batched_n,
                         mesh=_parse_mesh(args.mesh),
                         band_width=args.band_width,
                         max_restarts=args.max_restarts,
                         on_failure=args.on_failure,
                         max_retries=args.max_retries)

    stream = list(request_stream(kinds, shapes, args.requests, args.seed,
                                 args.oversize_every, args.oversize_n))
    t0 = time.perf_counter()
    uids = {}
    for prob, kind, invert in stream:
        # Krylov variants use the inverse-pair trick on MD; direct variants
        # solve the pencil as-is
        inv = invert and args.variant in ("KE", "KI")
        uid = engine.submit(prob.A, prob.B, args.s, invert=inv)
        uids[uid] = prob
        engine.tick()          # continuous service: dispatch full buckets
    done = engine.run_until_drained(flush=True)
    wall = time.perf_counter() - t0
    # the no-silent-drop invariant: every submission retires somewhere
    assert len(done) + len(engine.dead_letters) == args.requests

    # verify every retirement against the generator's known spectrum
    max_err = 0.0
    for req in done:
        exact = np.asarray(uids[req.uid].exact_evals[:args.s])
        max_err = max(max_err, float(np.max(np.abs(req.evals - exact))))

    payload = {
        "requests": args.requests,
        "slots": args.slots,
        "bucket_shapes": shapes,
        "stream": args.stream,
        "variant": args.variant,
        "wall_s": round(wall, 4),
        "requests_per_s": round(args.requests / max(wall, 1e-12), 2),
        "max_abs_eval_error": max_err,
        "summary": engine.summary(),
    }
    if args.json:
        print(json.dumps(payload, indent=1))
    else:
        for k, v in payload.items():
            print(f"{k}: {v}")
    assert max_err < 1e-6, f"serving accuracy regression: {max_err}"
    print("eigenserve OK")


if __name__ == "__main__":
    main()
