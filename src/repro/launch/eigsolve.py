"""CLI for the paper's eigensolvers:

    PYTHONPATH=src python -m repro.launch.eigsolve \
        --problem md --n 512 --s 8 --variant KE --invert
"""
from __future__ import annotations

import argparse
import json

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro.core import solve                      # noqa: E402
from repro.core.residuals import accuracy_report  # noqa: E402
from repro.data.problems import dft_like, md_like  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--problem", choices=["md", "dft"], default="md")
    ap.add_argument("--n", type=int, default=384)
    ap.add_argument("--s", type=int, default=8)
    ap.add_argument("--variant", choices=["TD", "TT", "KE", "KI"],
                    default="KE")
    ap.add_argument("--which", choices=["smallest", "largest"],
                    default="smallest")
    ap.add_argument("--invert", action="store_true",
                    help="the paper's MD trick (requires A SPD)")
    ap.add_argument("--gs2", choices=["trsm", "sygst"], default="trsm")
    ap.add_argument("--td1", choices=["unblocked", "blocked"],
                    default="unblocked")
    ap.add_argument("--band-width", type=int, default=8)
    ap.add_argument("--m", type=int, default=None)
    ap.add_argument("--max-restarts", type=int, default=300)
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    prob = (md_like if args.problem == "md" else dft_like)(args.n)
    res = solve(prob.A, prob.B, args.s, variant=args.variant,
                which=args.which, invert=args.invert, gs2=args.gs2,
                td1=args.td1, band_width=args.band_width, m=args.m,
                max_restarts=args.max_restarts)
    acc = accuracy_report(prob.A, prob.B, res.X, res.evals)
    err = float(np.max(np.abs(np.asarray(res.evals)
                              - np.asarray(prob.exact_evals[:args.s]))))
    payload = {
        "variant": args.variant,
        "n": args.n, "s": args.s,
        "evals": [float(x) for x in res.evals],
        "stage_times_s": {k: round(v, 4) for k, v in res.stage_times.items()},
        "b_orthogonality": float(acc.b_orthogonality),
        "relative_residual": float(acc.relative_residual),
        "max_abs_eval_error": err,
        "n_matvec": int(res.info.get("n_matvec", 0)),
    }
    if args.json:
        print(json.dumps(payload, indent=1))
    else:
        for k, v in payload.items():
            print(f"{k}: {v}")


if __name__ == "__main__":
    main()
