"""CLI for the paper's eigensolvers:

    PYTHONPATH=src python -m repro.launch.eigsolve \
        --problem md --n 512 --s 8 --variant KE --invert

Distributed execution (KE and TT): ``--mesh DxM`` lays a (data=D, model=M)
mesh over the visible devices and routes the solve through
``repro.dist`` (core.solve's ``mesh=`` dispatch); ``--devices N`` forces N
host-platform devices for CPU testing, e.g.

    PYTHONPATH=src python -m repro.launch.eigsolve \
        --problem md --n 64 --s 4 --variant TT --devices 8 --mesh 4x2

``--variant auto`` defers the choice to the flop/bandwidth cost model in
``repro.analysis.variant_model`` (the decision and its predicted-time
table are printed in the payload under ``router``).
"""
from __future__ import annotations

import os
import sys


def _early_device_count() -> int | None:
    """--devices must take effect before jax is imported (XLA_FLAGS)."""
    argv = sys.argv
    for i, a in enumerate(argv):
        if a == "--devices" and i + 1 < len(argv):
            return int(argv[i + 1])
        if a.startswith("--devices="):
            return int(a.split("=", 1)[1])
    return None


_n_dev = _early_device_count()
if _n_dev:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_n_dev}").strip()

import argparse  # noqa: E402
import json      # noqa: E402

import jax       # noqa: E402

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402

from repro.core import solve                      # noqa: E402
from repro.core.residuals import accuracy_report  # noqa: E402
from repro.data.problems import dft_like, md_like  # noqa: E402


def _parse_mesh(spec: str | None):
    """'4x2' -> Mesh((4, 2), ('data', 'model')); None -> single device."""
    if not spec:
        return None
    dims = tuple(int(x) for x in spec.lower().split("x"))
    if len(dims) != 2:
        raise SystemExit(f"--mesh wants DATAxMODEL, e.g. 4x2; got {spec!r}")
    return jax.make_mesh(dims, ("data", "model"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--problem", choices=["md", "dft"], default="md")
    ap.add_argument("--n", type=int, default=384)
    ap.add_argument("--s", type=int, default=8)
    ap.add_argument("--variant", choices=["TD", "TT", "KE", "KI", "auto"],
                    default="KE")
    ap.add_argument("--which", choices=["smallest", "largest"],
                    default="smallest")
    ap.add_argument("--invert", action="store_true",
                    help="the paper's MD trick (requires A SPD)")
    ap.add_argument("--gs2", choices=["trsm", "sygst"], default="trsm")
    ap.add_argument("--td1", choices=["unblocked", "blocked"],
                    default="unblocked")
    ap.add_argument("--band-width", type=int, default=8)
    ap.add_argument("--m", type=int, default=None)
    ap.add_argument("--max-restarts", type=int, default=300)
    ap.add_argument("--p", type=int, default=None, dest="krylov_block",
                    help="Lanczos block size (s-step width); default: 4 "
                         "on a mesh, 1 locally")
    ap.add_argument("--filter-degree", type=int, default=None,
                    help="Chebyshev start-filter degree (KE/KI); default: "
                         "16 on clustered spectra, else off; 0 forces off")
    ap.add_argument("--tol", type=float, default=0.0,
                    help="Lanczos residual tolerance (0 = machine-eps "
                         "criterion; 1e-9 is the converging setting on "
                         "the paper's spectra)")
    ap.add_argument("--precision", choices=["fp64", "mixed", "fast"],
                    default="fp64",
                    help="compute dtype of the GEMM-heavy stages (mixed = "
                         "fp32, fast = bf16/fp32-acc); non-fp64 runs the "
                         "fp64 refinement epilogue and reports its "
                         "trajectory")
    ap.add_argument("--mesh", default=None,
                    help="DATAxMODEL mesh (e.g. 4x2): run the KE or TT "
                         "variant (or --variant auto, restricted to those "
                         "two) through the repro.dist distributed pipeline")
    ap.add_argument("--devices", type=int, default=None,
                    help="force N host-platform devices (set before the "
                         "jax import; pairs with --mesh on CPU)")
    ap.add_argument("--on-failure", choices=["recover", "warn", "ignore"],
                    default="warn",
                    help="degradation-ladder policy (resilience.recovery): "
                         "'warn' diagnoses failures, 'recover' additionally "
                         "retries/escalates/falls back, 'ignore' restores "
                         "the pre-resilience behavior")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="transient-failure retries under "
                         "--on-failure recover")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    mesh = _parse_mesh(args.mesh)
    if mesh is not None and args.variant not in ("KE", "TT", "auto"):
        raise SystemExit("--mesh is only implemented for --variant "
                         "KE, TT, or auto")

    prob = (md_like if args.problem == "md" else dft_like)(args.n)
    res = solve(prob.A, prob.B, args.s, variant=args.variant,
                which=args.which, invert=args.invert, gs2=args.gs2,
                td1=args.td1, band_width=args.band_width, m=args.m,
                max_restarts=args.max_restarts, mesh=mesh, tol=args.tol,
                krylov_block=args.krylov_block, filter=args.filter_degree,
                precision=args.precision,
                on_failure=args.on_failure, max_retries=args.max_retries,
                # the router's clustered-spectrum hint: the DFT generator's
                # low end is the paper's slow-Lanczos regime
                clustered=(args.problem == "dft"
                           and args.which == "smallest"))
    acc = accuracy_report(prob.A, prob.B, res.X, res.evals)
    exact = np.asarray(prob.exact_evals)
    want = exact[:args.s] if args.which == "smallest" else exact[-args.s:]
    err = float(np.max(np.abs(np.asarray(res.evals) - want)))
    payload = {
        "variant": res.info["variant"],
        "requested_variant": args.variant,
        "n": args.n, "s": args.s,
        "mesh": args.mesh or "single",
        "n_devices": jax.device_count(),
        "evals": [float(x) for x in res.evals],
        "stage_times_s": {k: round(v, 4) for k, v in res.stage_times.items()},
        "b_orthogonality": float(acc.b_orthogonality),
        "relative_residual": float(acc.relative_residual),
        "max_abs_eval_error": err,
        "n_matvec": int(res.info.get("n_matvec", 0)),
        "health": res.info["health"],
        "recovery": res.info["recovery"],
    }
    if "warnings" in res.info:
        payload["warnings"] = res.info["warnings"]
    if "router" in res.info:
        payload["router"] = res.info["router"]
    if "refinement" in res.info:
        rinfo = res.info["refinement"]
        payload["precision"] = args.precision
        payload["refinement"] = {
            "steps": int(rinfo["steps"]),
            "converged": bool(rinfo["converged"]),
            "relative_residual": [float(x)
                                  for x in rinfo["relative_residual"]],
            "b_orthogonality": [float(x)
                                for x in rinfo["b_orthogonality"]],
        }
    if args.json:
        print(json.dumps(payload, indent=1))
    else:
        for k, v in payload.items():
            print(f"{k}: {v}")


if __name__ == "__main__":
    main()
