"""Production mesh construction.

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the 512-device XLA flag is set only by dryrun.py, before
any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod adds the 2-pod leading axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, *,
                    multi_pod: bool = False):
    """Small mesh for in-CI multi-device tests (subprocesses set their own
    --xla_force_host_platform_device_count)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))
