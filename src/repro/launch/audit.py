"""Static program audit CLI: prove every solver path has the shape we claim.

    PYTHONPATH=src python -m repro.launch.audit            # full audit
    PYTHONPATH=src python -m repro.launch.audit --quick    # CI fast lane
    PYTHONPATH=src python -m repro.launch.audit --entry dist/tt3_program

Lowers (never runs) every registered solver program — the fused TT1 panel
sweep, the bulge chase, the batched TT3, the distributed KE restart /
Chebyshev prep / spectrum-partitioned TT3 programs, the shape-bucketed
``solve_batched`` pipelines and every Pallas kernel wrapper — walks the
jaxpr/StableHLO into ProgramProfiles, and enforces the budget contracts
of ``analysis.static_audit.contracts``: dispatch counts, collectives per
block step, pinned static collective totals, loop-step structure, dtype
policy (no fp64->fp32/bf16 leaks), plus the Pallas BlockSpec/VMEM lint
and the StageCost cross-check against ``analysis.variant_model``.

Writes ``artifacts/AUDIT.json`` and exits nonzero on any budget, dtype
or cross-check violation (warnings don't fail). Defaults to 2 forced
host devices so the distributed contracts are audited with real
collectives; ``--devices 1`` skips the mesh entries.
"""
from __future__ import annotations

import os
import sys


def _early_device_count() -> int:
    """--devices must take effect before jax is imported (XLA_FLAGS)."""
    argv = sys.argv
    for i, a in enumerate(argv):
        if a == "--devices" and i + 1 < len(argv):
            return int(argv[i + 1])
        if a.startswith("--devices="):
            return int(a.split("=", 1)[1])
    return 2     # audit the distributed contracts by default


_n_dev = _early_device_count()
if _n_dev > 1:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_n_dev}").strip()

import argparse  # noqa: E402
import json      # noqa: E402

import jax       # noqa: E402

jax.config.update("jax_enable_x64", True)

from repro.analysis.static_audit import (        # noqa: E402
    AuditSpec, all_ok, check_all, check_entry, crosscheck_stagecosts,
    entries, errors, get_entry, lint_pallas_profiles, lint_reports,
    lint_signature_parity, register_all)


def run_audit(quick: bool = False, entry: str | None = None,
              spec: AuditSpec | None = None) -> dict:
    """Audit everything (or one entry); returns the AUDIT.json payload."""
    spec = register_all(spec)
    have_mesh = jax.device_count() >= 2
    if entry:
        reports = [check_entry(get_entry(entry))]
    else:
        tags = ["quick"] if quick else None
        reports = check_all(tags=tags, have_mesh=have_mesh)
    rd = {r.name: r for r in reports}

    checks = crosscheck_stagecosts(rd, spec) if entry is None else []
    pallas = lint_pallas_profiles(rd)
    sigs = lint_signature_parity() if entry is None else []
    dtypes = lint_reports(rd)

    n_viol = sum(len(r.violations) for r in reports)
    n_lint_err = len(errors(pallas)) + len(errors(sigs))
    n_xfail = sum(1 for c in checks if not c.ok)
    ok = (n_viol == 0 and n_lint_err == 0 and n_xfail == 0
          and dtypes["ok"])
    # the resilience proof in one place: every sentinel-bearing contract
    # holds with ZERO extra dispatches (the allowance is pinned to 0), and
    # the required fused is_finite sites are present in the lowered traces
    sentinel_entries = [r for r in reports
                        if not r.skipped and r.contract.min_isfinite_sites]
    sentinels = {
        "entries": len(sentinel_entries),
        "isfinite_sites": sum(r.isfinite_sites for r in sentinel_entries),
        "extra_dispatches_allowed": max(
            (r.contract.sentinel_extra_dispatches
             for r in sentinel_entries), default=0),
        "ok": all(r.ok for r in sentinel_entries),
    }
    return {
        "schema": "repro/static-audit/v1",
        "jax_version": jax.__version__,
        "n_devices": jax.device_count(),
        "spec": spec.as_json_dict(),
        "ok": ok,
        "summary": {
            "entries": len(reports),
            "skipped": sum(1 for r in reports if r.skipped),
            "budget_violations": n_viol,
            "crosscheck_failures": n_xfail,
            "lint_errors": n_lint_err,
            "lint_warnings": (len(pallas) + len(sigs) - n_lint_err),
            "precision_leaks": len(dtypes["precision_leaks"]),
        },
        "sentinels": sentinels,
        "entries": [r.as_json_dict() for r in reports],
        "crosscheck": [c.as_json_dict() for c in checks],
        "pallas_lint": [f.as_json_dict() for f in pallas],
        "signature_lint": [f.as_json_dict() for f in sigs],
        "dtype_lint": dtypes,
    }


def _print_human(payload: dict) -> None:
    print(f"static audit: {payload['summary']['entries']} entries on "
          f"{payload['n_devices']} device(s), jax {payload['jax_version']}")
    for e in payload["entries"]:
        if e["skipped"]:
            print(f"  SKIP {e['name']} (needs a >= 2 device mesh)")
            continue
        mark = "ok  " if e["ok"] else "FAIL"
        print(f"  {mark} {e['name']}: {e['dispatches']} dispatch(es), "
              f"{e['total_collectives']} collective(s), "
              f"<= {e['max_collectives_per_step']}/step")
        for v in e["violations"]:
            print(f"       !! {v}")
    if payload["crosscheck"]:
        print("cost-model cross-check (StageCost vs counted):")
        for c in payload["crosscheck"]:
            mark = "ok  " if c["ok"] else "FAIL"
            print(f"  {mark} {c['stage']}.{c['field']}: model "
                  f"{c['model_value']:g} vs counted {c['counted_value']:g} "
                  f"({c['relation']})")
    for f in payload["pallas_lint"] + payload["signature_lint"]:
        tag = "!!" if f["severity"] == "error" else "--"
        print(f"  {tag} [{f['check']}] {f['kernel']}: {f['detail']}")
    leaks = payload["dtype_lint"]["precision_leaks"]
    for leak in leaks:
        print(f"  !! precision leak: {leak}")
    sen = payload.get("sentinels")
    if sen:
        mark = "ok  " if sen["ok"] else "FAIL"
        print(f"  {mark} health sentinels: {sen['isfinite_sites']} fused "
              f"is_finite site(s) across {sen['entries']} contract(s), "
              f"+{sen['extra_dispatches_allowed']} dispatches allowed")
    print("AUDIT " + ("PASSED" if payload["ok"] else "FAILED"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="static HLO/jaxpr budget audit of every solver path")
    ap.add_argument("--devices", type=int, default=2,
                    help="forced host devices (>=2 audits the mesh "
                         "contracts; handled before jax import)")
    ap.add_argument("--quick", action="store_true",
                    help="only the 'quick'-tagged entries (CI fast lane)")
    ap.add_argument("--entry", default=None,
                    help="audit a single registry entry by name")
    ap.add_argument("--json", action="store_true",
                    help="print the payload as JSON instead of a summary")
    ap.add_argument("-o", "--out", default="artifacts/AUDIT.json",
                    help="artifact path ('' disables writing)")
    args = ap.parse_args(argv)

    payload = run_audit(quick=args.quick, entry=args.entry)
    if args.out and args.entry is None:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
    if args.json:
        print(json.dumps(payload, indent=1, sort_keys=True))
    else:
        _print_human(payload)
    return 0 if payload["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
