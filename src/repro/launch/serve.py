"""Serving driver: batched prefill + decode with the sharded KV cache.

CPU-scale usage (examples/ wraps this):
    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
        --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.models.model import (decode_step, encode, forward,
                                init_decode_state, init_params)
from repro.train.train_step import make_serve_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    B = args.batch
    prompt = jax.random.randint(jax.random.fold_in(key, 1),
                                (B, args.prompt_len), 0, cfg.vocab_size,
                                jnp.int32)
    memory = None
    if cfg.encoder_decoder:
        memory = encode(params, jax.random.normal(
            jax.random.fold_in(key, 2), (B, args.prompt_len, cfg.d_model),
            jnp.float32), cfg)

    serve = jax.jit(make_serve_step(cfg))
    state = init_decode_state(cfg, B,
                              capacity=args.prompt_len + args.gen,
                              memory=memory)

    # prefill by stepping the prompt through the decode path (keeps one
    # compiled program; a production server would lower a bulk prefill too)
    t0 = time.perf_counter()
    logits = None
    for t in range(args.prompt_len):
        logits, state = serve(params, prompt[:, t:t + 1], state)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    # greedy decode
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    out_tokens = [tok]
    t0 = time.perf_counter()
    for _ in range(args.gen - 1):
        logits, state = serve(params, tok, state)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_gen = time.perf_counter() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    tps = (args.gen - 1) * B / max(t_gen, 1e-9)
    print(f"arch={cfg.name} batch={B} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill={t_prefill:.2f}s decode={t_gen:.2f}s "
          f"throughput={tps:.1f} tok/s")
    print("sample token ids:", [int(t) for t in gen[0, :8]])
    assert bool(jnp.all(gen >= 0)) and bool(jnp.all(gen < cfg.vocab_size))
    print("serve OK")


if __name__ == "__main__":
    main()
