import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede every other import (see dryrun.py).

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core.lanczos import lanczos_solve_jit               # noqa: E402
from repro.core.operators import ExplicitC                      # noqa: E402
from repro.dist.sharded_la import (dist_cholesky, dist_gemm,  # noqa: E402
                                   dist_gemm_rs, dist_symv, dist_symv_rs,
                                   dist_trsm_left_t)
from repro.launch.dryrun import (_set_mesh,                   # noqa: E402
                                 parse_collective_bytes)
from repro.launch.mesh import make_production_mesh            # noqa: E402

"""Eigensolver-side multi-pod dry-run: lowers the PAPER's pipelines on the
production meshes (the LM dry-run lives in dryrun.py).

Stages lowered, mirroring Table 1 of the paper:
  GS1  dist_cholesky          (block-row, one broadcast per panel)
  GS2  dist_trsm_left_t x2    (the paper's preferred two-TRSM path)
  KE1  dist_symv              (the Krylov hot loop, 2D-sharded C)
  BT1  dist_trsm              (back-transform)
Artifacts (cost/memory/collectives) feed §Roofline for the paper-side rows.
"""


def run(mesh, mesh_name: str, n: int, s: int, outdir: str,
        dtype=jnp.float32) -> list[dict]:
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_spec = dp if len(dp) > 1 else dp[0]
    rowsh = NamedSharding(mesh, P(dp_spec, None))
    sh2d = NamedSharding(mesh, P(dp_spec, "model"))
    vsh = NamedSharding(mesh, P("model"))

    B_spec = jax.ShapeDtypeStruct((n, n), dtype, sharding=rowsh)
    A2d_spec = jax.ShapeDtypeStruct((n, n), dtype, sharding=sh2d)
    x_spec = jax.ShapeDtypeStruct((n,), dtype, sharding=vsh)
    Y_spec = jax.ShapeDtypeStruct((n, s), dtype, sharding=rowsh)

    stages = {
        "GS1_dist_cholesky": (lambda Bm: dist_cholesky(mesh, Bm), [B_spec]),
        "GS2_dist_trsm": (lambda U, A: dist_trsm_left_t(mesh, U, A),
                          [B_spec, B_spec]),
        "KE1_dist_symv": (lambda C, x: dist_symv(mesh, C, x),
                          [A2d_spec, x_spec]),
        "KE1_dist_symv_rs": (lambda C, x: dist_symv_rs(mesh, C, x),
                             [A2d_spec, x_spec]),
        "TT4_dist_gemm": (lambda Q, Z: dist_gemm(mesh, Q, Z),
                          [A2d_spec, jax.ShapeDtypeStruct(
                              (n, s), dtype,
                              sharding=NamedSharding(mesh, P("model", None)))]),
        "TT4_dist_gemm_rs": (lambda Q, Z: dist_gemm_rs(mesh, Q, Z),
                             [A2d_spec, jax.ShapeDtypeStruct(
                                 (n, s), dtype,
                                 sharding=NamedSharding(mesh,
                                                        P("model", None)))]),
        "BT1_dist_trsm": (lambda U, Y: dist_trsm_left_t(mesh, U, Y),
                          [B_spec, Y_spec]),
        # the WHOLE thick-restart Lanczos solver (lax.while_loop driver) on
        # the 2D-sharded operator: proves the paper's iterative method —
        # not just its matvec — compiles for the production mesh.
        "KE_full_solver_jit": (
            lambda C, v0: lanczos_solve_jit(ExplicitC(C), v0, s=16, m=48,
                                            which="SA", max_restarts=8),
            [A2d_spec, jax.ShapeDtypeStruct(
                (n,), dtype, sharding=NamedSharding(mesh, P()))]),
    }

    recs = []
    for name, (fn, specs) in stages.items():
        t0 = time.time()
        rec = {"stage": name, "mesh": mesh_name, "n": n, "s": s,
               "status": "ok"}
        try:
            with _set_mesh(mesh):
                lowered = jax.jit(fn).lower(*specs)
            compiled = lowered.compile()
            from repro.analysis.roofline import cost_analysis_dict
            ca = cost_analysis_dict(compiled)
            rec["cost_analysis"] = {
                "flops": float(ca.get("flops", -1.0)),
                "bytes_accessed": float(ca.get("bytes accessed", -1.0)),
            }
            rec["collectives"] = parse_collective_bytes(compiled.as_text())
            ma = compiled.memory_analysis()
            if ma is not None:
                rec["memory_analysis"] = {
                    k: int(getattr(ma, k))
                    for k in ("argument_size_in_bytes",
                              "output_size_in_bytes", "temp_size_in_bytes")
                    if hasattr(ma, k)}
        except Exception as e:  # noqa: BLE001
            rec["status"] = "FAIL"
            rec["error"] = f"{type(e).__name__}: {e}"
        rec["t_total_s"] = round(time.time() - t0, 2)
        recs.append(rec)
        coll = rec.get("collectives", {}).get("total_bytes", -1)
        print(f"[{rec['status']:4s}] {mesh_name:12s} {name:20s} "
              f"t={rec['t_total_s']:6.1f}s "
              f"flops={rec.get('cost_analysis', {}).get('flops', -1):.3e} "
              f"coll={coll:.3e} "
              f"{rec.get('error', '')[:120]}", flush=True)
    os.makedirs(outdir, exist_ok=True)
    with open(os.path.join(outdir, f"eigen_{mesh_name}_n{n}.json"), "w") as f:
        json.dump(recs, f, indent=1)
    return recs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=16_384,
                    help="problem size (paper: 9,997 and 17,243; default is "
                         "the DFT scale rounded to the mesh)")
    ap.add_argument("--s", type=int, default=448)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--outdir", default="artifacts/eigen_dryrun")
    args = ap.parse_args()

    n_fail = 0
    if args.mesh in ("single", "both"):
        mesh = make_production_mesh(multi_pod=False)
        n_fail += sum(r["status"] != "ok"
                      for r in run(mesh, "pod16x16", args.n, args.s,
                                   args.outdir))
    if args.mesh in ("multi", "both"):
        mesh = make_production_mesh(multi_pod=True)
        n_fail += sum(r["status"] != "ok"
                      for r in run(mesh, "pods2x16x16", args.n, args.s,
                                   args.outdir))
    print(f"eigen dry-run complete; failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
