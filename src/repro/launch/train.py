"""End-to-end training driver: data pipeline -> jitted step -> checkpoints,
auto-resume, straggler monitoring, and paper-technique spectral probes.

CPU-scale usage (examples/ wraps this):
    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --smoke \
        --steps 200 --batch 8 --seq 128
On a real cluster the same driver runs under the production mesh with the
shardings from dist/partitioning.py (see dryrun.py, which lowers exactly
this step function at full scale).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.data.tokens import DataConfig, TokenPipeline
from repro.dist import checkpoint as ckpt
from repro.dist.straggler import StragglerMonitor
from repro.models.config import ModelConfig
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--spectral-every", type=int, default=0,
                    help="Lanczos curvature probe period (0 = off)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    opt_cfg = OptimizerConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                              decay_steps=args.steps)
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=args.seq,
                                    global_batch=args.batch,
                                    seed=args.seed))
    key = jax.random.PRNGKey(args.seed)
    state = init_train_state(key, cfg)
    start_step = 0

    if args.ckpt_dir:
        restored = ckpt.load_latest(args.ckpt_dir, state)
        if restored is not None:
            start_step, state, extra = restored
            pipe.seek(extra.get("cursor", start_step))
            print(f"resumed from step {start_step}", flush=True)

    step_fn = jax.jit(make_train_step(cfg, opt_cfg))
    monitor = StragglerMonitor(n_hosts=1)

    def batch_to_dev(b):
        out = {k: jnp.asarray(v) for k, v in b.items()}
        if cfg.encoder_decoder:
            out["embeds"] = jax.random.normal(
                jax.random.fold_in(key, pipe.step),
                (args.batch, args.seq, cfg.d_model), jnp.float32)
        return out

    losses = []
    for step in range(start_step, args.steps):
        t0 = time.perf_counter()
        batch = batch_to_dev(pipe.next_batch())
        state, metrics = step_fn(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        monitor.record(0, dt)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step={step} loss={float(metrics['loss']):.4f} "
                  f"nll={float(metrics['nll']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} dt={dt*1e3:.0f}ms",
                  flush=True)
        if args.spectral_every and step % args.spectral_every == 0:
            from repro.train.loss import ce_loss
            from repro.models.model import forward
            from repro.train.spectral import curvature_spectrum

            def probe_loss(params, b):
                logits, _ = forward(params, b["tokens"], cfg, remat=False)
                return ce_loss(logits, b["labels"])[0]

            spec = curvature_spectrum(probe_loss, state.params, batch, m=16)
            print(f"  [spectral] sharpness={spec['sharpness']:.3e} "
                  f"lambda_min={spec['lambda_min']:.3e}", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, step + 1, state,
                      extra={"cursor": pipe.step})
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps, state,
                  extra={"cursor": pipe.step})
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"loss first10={first:.4f} last10={last:.4f} "
          f"improved={bool(last < first)}")
    slow = monitor.stragglers()
    if slow:
        plan = monitor.rebalance_plan(microbatches_per_host=1)
        print(f"stragglers={slow} rebalance_plan={plan}", flush=True)


if __name__ == "__main__":
    main()
