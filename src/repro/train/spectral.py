"""Curvature spectrum probes — the paper's technique inside the trainer.

Variant KI's defining move is Lanczos on an *implicit* operator (never
materialize C, apply U^{-T} A U^{-1} per iteration). The training-time
analogue is Lanczos on the loss Hessian via hessian-vector products: the
operator is implicit (jvp-of-grad), symmetric, and only its extremal
eigenpairs are wanted — exactly the GSYEIG s << n regime.

``curvature_spectrum`` runs an m-step full-reorthogonalization Lanczos
(no restarts — spectral density probes don't need ARPACK-grade residuals)
and returns the extremal Ritz values, the standard sharpness diagnostic.
The trainer exposes it via --spectral-every.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree


def make_hvp(loss_fn: Callable, params, batch):
    """Returns (hvp(v), dim): implicit Hessian-vector operator (KI-style)."""
    flat, unravel = ravel_pytree(params)

    def loss_flat(p_flat):
        return loss_fn(unravel(p_flat), batch)

    @jax.jit
    def hvp(v):
        return jax.jvp(jax.grad(loss_flat), (flat,), (v,))[1]

    return hvp, flat.shape[0]


@partial(jax.jit, static_argnames=("matvec", "m"))
def _lanczos_tridiag(matvec, v0: jax.Array, m: int):
    """m-step Lanczos with full re-orthogonalization; returns (alpha, beta)."""
    n = v0.shape[0]
    V = jnp.zeros((n, m + 1), v0.dtype).at[:, 0].set(v0 / jnp.linalg.norm(v0))
    alpha = jnp.zeros((m,), v0.dtype)
    beta = jnp.zeros((m,), v0.dtype)

    def body(j, carry):
        V, alpha, beta = carry
        w = matvec(V[:, j])
        mask = (jnp.arange(m + 1) <= j).astype(v0.dtype)
        h = (V.T @ w) * mask
        w = w - V @ h
        h2 = (V.T @ w) * mask
        w = w - V @ h2
        a = (h + h2)[j]
        b = jnp.linalg.norm(w)
        V = V.at[:, j + 1].set(w / jnp.maximum(b, 1e-30))
        return V, alpha.at[j].set(a), beta.at[j].set(b)

    V, alpha, beta = jax.lax.fori_loop(0, m, body, (V, alpha, beta))
    return alpha, beta


def curvature_spectrum(loss_fn: Callable, params, batch, m: int = 32,
                       key=None) -> dict:
    """Extremal Hessian Ritz values (sharpness / most-negative curvature)."""
    if key is None:
        key = jax.random.PRNGKey(0)
    hvp, dim = make_hvp(loss_fn, params, batch)
    v0 = jax.random.normal(key, (dim,), jnp.float32)
    m = min(m, dim - 1)
    alpha, beta = _lanczos_tridiag(hvp, v0, m)
    T = (jnp.diag(alpha) + jnp.diag(beta[:m - 1], 1)
         + jnp.diag(beta[:m - 1], -1))
    theta = jnp.linalg.eigvalsh(T)
    return {
        "sharpness": float(theta[-1]),       # lambda_max(H)
        "lambda_min": float(theta[0]),       # most negative curvature
        "ritz_values": theta,
        "dim": dim,
    }
