"""The jittable train / serve step factories shared by the trainer, the
smoke tests, and the multi-pod dry-run (which lowers exactly these).
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import (DecodeState, decode_step, encode, forward,
                                init_decode_state, init_params)
from .loss import ce_loss
from .optimizer import AdamWState, OptimizerConfig, adamw_update, \
    init_opt_state


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    step: jax.Array


def init_train_state(key, cfg: ModelConfig) -> TrainState:
    params = init_params(key, cfg)
    return TrainState(params=params, opt=init_opt_state(params),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(cfg: ModelConfig, opt_cfg: OptimizerConfig,
                    aux_coef: float = 1e-2, remat: bool = True,
                    unroll: bool = False):
    """Returns train_step(state, batch) -> (state, metrics).

    batch = {"tokens": (B, S) int32, "labels": (B, S) int32}
    (+ "embeds" (B, S_enc, D) for frontend-stub archs / enc-dec memory).
    """

    def loss_fn(params, batch):
        memory = None
        if cfg.encoder_decoder:
            memory = encode(params, batch["embeds"], cfg)
        logits, aux = forward(params, batch["tokens"], cfg, memory=memory,
                              remat=remat, unroll=unroll)
        loss, metrics = ce_loss(logits, batch["labels"])
        loss = loss + aux_coef * aux
        metrics["moe_aux"] = aux
        return loss, metrics

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, batch)
        new_params, new_opt, opt_metrics = adamw_update(
            grads, state.opt, state.params, opt_cfg)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return TrainState(params=new_params, opt=new_opt,
                          step=state.step + 1), metrics

    return train_step


def make_serve_step(cfg: ModelConfig, unroll: bool = False):
    """Returns serve_step(params, tokens (B,1), state) -> (logits, state) —
    the one-new-token decode the decode_*/long_* dry-run cells lower."""

    def serve_step(params, tokens, state: DecodeState):
        return decode_step(params, tokens, state, cfg, unroll=unroll)

    return serve_step


def make_prefill(cfg: ModelConfig, unroll: bool = False):
    def prefill(params, tokens, embeds: Optional[jax.Array] = None):
        memory = None
        if cfg.encoder_decoder:
            memory = encode(params, embeds, cfg)
        logits, _ = forward(params, tokens, cfg, memory=memory, remat=False,
                            unroll=unroll)
        return logits

    return prefill
