"""Cross-entropy LM loss with z-loss regularizer, f32 numerics."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ce_loss(logits: jax.Array, labels: jax.Array,
            z_loss_coef: float = 1e-4) -> tuple[jax.Array, dict]:
    """logits (B, S, V) f32, labels (B, S) int32. Mean over all tokens."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - tgt
    zl = z_loss_coef * (logz ** 2)
    loss = jnp.mean(nll + zl)
    metrics = {"nll": jnp.mean(nll), "z_loss": jnp.mean(zl),
               "ppl_proxy": jnp.exp(jnp.minimum(jnp.mean(nll), 20.0))}
    return loss, metrics
