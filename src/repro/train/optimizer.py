"""AdamW + cosine schedule + global-norm clipping, implemented natively.

(No optax in this environment — the optimizer is part of the substrate the
framework must own anyway.) State is a pytree mirroring params, so it shards
with the same PartitionSpecs (ZeRO-style: optimizer state inherits the param
sharding; with DP-sharded params this is the sharded-optimizer regime).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


class AdamWState(NamedTuple):
    mu: Any
    nu: Any
    count: jax.Array


def init_opt_state(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p), params)
    return AdamWState(mu=zeros,
                      nu=jax.tree.map(lambda p: jnp.zeros_like(p), params),
                      count=jnp.zeros((), jnp.int32))


def lr_schedule(step: jax.Array, cfg: OptimizerConfig) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.lr * jnp.minimum(1.0, step / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(jax.tree.map(
        lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree))
    return jnp.sqrt(sum(leaves))


def adamw_update(grads, state: AdamWState, params, cfg: OptimizerConfig):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: g * scale, grads)
    count = state.count + 1
    lr = lr_schedule(count, cfg)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                      state.mu, grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                      state.nu, grads)

    def upd(p, m, v):
        mhat = m / b1c
        vhat = v / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p
        return (p - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(mu=mu, nu=nu, count=count), {
        "grad_norm": gnorm, "lr": lr}
