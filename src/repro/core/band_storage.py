"""Compact symmetric band storage (LAPACK lower 'SB' convention).

Packed layout: a symmetric matrix A of bandwidth w is stored as a
``(w + 1, n)`` array with

    band[d, i] = A[i + d, i],   d = 0..w  (main + lower diagonals),

entries past the matrix edge (``i + d >= n``) are zero. This is the
storage the TT pipeline's intermediate lives in between stage 1
(``core.sbr.reduce_to_band``) and stage 2 (the wavefront bulge chase in
``core.sbr.band_to_tridiag``): O(n w) memory instead of O(n^2), and every
chase update touches an O(w)-column window instead of a full row pair.

``kernels/band_mv`` keeps the transposed ``(n, w+1)`` upper layout
(``bm[i, d] = A[i, i+d]``); for symmetric matrices the two are each
other's transpose — see ``to_band_mv_layout`` / ``from_band_mv_layout``.

All routines are pure-jnp, fixed-shape (``w`` static), jit- and
vmap-friendly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pack_band(A: jax.Array, w: int, symmetrize: bool = False) -> jax.Array:
    """Pack the (main + w lower) diagonals of ``A`` into (w+1, n) storage.

    With ``symmetrize=True`` each packed diagonal is the average of the
    corresponding lower and upper diagonal of ``A`` (the packed analogue of
    ``linalg_utils.symmetrize`` followed by a band mask).
    """
    n = A.shape[-1]
    rows = []
    for d in range(w + 1):
        lo = jnp.diagonal(A, offset=-d, axis1=-2, axis2=-1)
        if symmetrize and d > 0:
            lo = 0.5 * (lo + jnp.diagonal(A, offset=d, axis1=-2, axis2=-1))
        pad = [(0, 0)] * (lo.ndim - 1) + [(0, n - lo.shape[-1])]
        rows.append(jnp.pad(lo, pad))
    return jnp.stack(rows, axis=-2)


def unpack_band(band: jax.Array) -> jax.Array:
    """Expand (w+1, n) packed storage back to the dense symmetric (n, n).

    ``A[i, j] = band[|i-j|, min(i, j)]`` within the band, zero outside —
    one gather, so it vmaps over leading batch dims.
    """
    wp1, n = band.shape[-2], band.shape[-1]
    i = jnp.arange(n)[:, None]
    j = jnp.arange(n)[None, :]
    dd = jnp.abs(i - j)
    vals = band[..., jnp.clip(dd, 0, wp1 - 1), jnp.minimum(i, j)]
    return jnp.where(dd < wp1, vals, 0.0)


def clean_band(band: jax.Array) -> jax.Array:
    """Zero the out-of-range tail entries (``i + d >= n``) of packed storage."""
    wp1, n = band.shape[-2], band.shape[-1]
    d = jnp.arange(wp1)[:, None]
    i = jnp.arange(n)[None, :]
    return jnp.where(i + d < n, band, 0.0)


def band_extract_tridiag(band: jax.Array):
    """Return (d, e) — the main and first sub-diagonal of packed storage."""
    n = band.shape[-1]
    return band[..., 0, :], band[..., 1, : n - 1]


def to_band_mv_layout(band: jax.Array) -> jax.Array:
    """(w+1, n) lower-packed -> the (n, w+1) upper layout of kernels/band_mv.

    For symmetric A, ``bm[i, d] = A[i, i+d] = A[(i+d), i] = band[d, i]``:
    the conversion is a transpose.
    """
    return jnp.swapaxes(band, -1, -2)


def from_band_mv_layout(bm: jax.Array) -> jax.Array:
    """Inverse of :func:`to_band_mv_layout`."""
    return jnp.swapaxes(bm, -1, -2)


__all__ = ["pack_band", "unpack_band", "clean_band", "band_extract_tridiag",
           "to_band_mv_layout", "from_band_mv_layout"]
