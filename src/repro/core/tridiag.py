"""TD1 — one-stage Householder tridiagonalization (DSYTRD analogue).

Q^T C Q = T with Q = H_0 H_1 ... H_{n-3}. The reflectors are kept in
factored form (V, tau) — like LAPACK, Q is never built explicitly, and the
back-transform applies the reflectors directly (TD3 / DORMTR analogue).

The loop is a fixed-shape ``lax.fori_loop``: every iteration does a full-size
masked symmetric mat-vec plus a rank-2 update (exactly the BLAS-2 profile the
paper blames for DSYTRD's poor performance on throughput hardware — that
memory-bound profile is what our roofline analysis quantifies on TPU).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .linalg_utils import extract_tridiag, householder_masked


class TridiagResult(NamedTuple):
    d: jax.Array      # (n,)  diagonal of T
    e: jax.Array      # (n-1,) subdiagonal of T
    V: jax.Array      # (n, n) Householder vectors, column j = v_j (v_j[j+1] = 1)
    tau: jax.Array    # (n,)  reflector scales (tau[j] for column j)


def tridiagonalize(C: jax.Array) -> TridiagResult:
    """Reduce symmetric C to tridiagonal T via n-2 Householder similarity steps."""
    n = C.shape[0]
    dtype = C.dtype

    def body(j, carry):
        M, V, tau = carry
        col = M[:, j]
        v, tj, _ = householder_masked(col, j + 1)
        # two-sided rank-2 update: M <- H M H, H = I - tau v v^T
        w = tj * (M @ v)
        w = w - (0.5 * tj * (v @ w)) * v
        M = M - jnp.outer(v, w) - jnp.outer(w, v)
        V = V.at[:, j].set(v)
        tau = tau.at[j].set(tj)
        return M, V, tau

    V0 = jnp.zeros((n, n), dtype)
    tau0 = jnp.zeros((n,), dtype)
    M, V, tau = jax.lax.fori_loop(0, max(n - 2, 0), body, (C, V0, tau0))
    d, e = extract_tridiag(M)
    return TridiagResult(d=d, e=e, V=V, tau=tau)


def tridiagonalize_blocked(C: jax.Array, panel: int = 32) -> TridiagResult:
    """Blocked DSYTRD (latency-optimized): per-panel BLAS-2 column work +
    one rank-2b BLAS-3 trailing update (SYR2K) per panel.

    This is the paper's central BLAS-2 vs BLAS-3 distinction made concrete:
    the unblocked ``tridiagonalize`` touches the full trailing matrix per
    column (n matvecs + n rank-2 updates = all BLAS-2); here only the panel
    does matvecs and the trailing update is a single fused SYR2K per panel
    (primed for kernels/syr2k on the TPU target). Same (V, tau) contract.

    Panel recurrences (LAPACK dlatrd): within a panel starting at column c,
    having processed columns c..j-1 with accumulators V_p, W_p:
        a_j   = (A - V_p W_p^T - W_p V_p^T) e_j        (update column j)
        v_j   = householder(a_j)
        w_j   = tau (A v - V_p (W_p^T v) - W_p (V_p^T v));
        w_j  -= (tau/2)(w_j^T v) v
    then A <- A - V_p W_p^T - W_p V_p^T once per panel.
    """
    n = C.shape[0]
    dtype = C.dtype
    n_cols = max(n - 2, 0)
    n_panels = -(-n_cols // panel) if n_cols else 0

    def panel_body(p, carry):
        M, V, tau = carry
        c0 = p * panel
        Vp = jnp.zeros((n, panel), dtype)
        Wp = jnp.zeros((n, panel), dtype)

        def col_body(jj, inner):
            Vp, Wp, V, tau = inner
            j = c0 + jj
            active = j < n_cols
            # column j refreshed with the panel's pending rank-2b updates
            colM = M[:, j]
            col = colM - Vp @ Wp[j, :] - Wp @ Vp[j, :]
            v, tj, _ = householder_masked(col, j + 1)
            tj = jnp.where(active, tj, 0.0)
            # w = tau (A v - Vp (Wp^T v) - Wp (Vp^T v))
            w = M @ v - Vp @ (Wp.T @ v) - Wp @ (Vp.T @ v)
            w = tj * w
            w = w - (0.5 * tj * (v @ w)) * v
            Vp = Vp.at[:, jj].set(jnp.where(active, v, 0.0))
            Wp = Wp.at[:, jj].set(jnp.where(active, w, 0.0))
            V = V.at[:, j].set(jnp.where(active, v, V[:, j]))
            tau = tau.at[j].set(tj)
            return Vp, Wp, V, tau

        Vp, Wp, V, tau = jax.lax.fori_loop(0, panel, col_body,
                                           (Vp, Wp, V, tau))
        # BLAS-3 trailing update (the SYR2K the TPU kernel owns)
        M = M - Vp @ Wp.T - Wp @ Vp.T
        return M, V, tau

    V0 = jnp.zeros((n, n), dtype)
    tau0 = jnp.zeros((n,), dtype)
    if n_panels:
        M, V, tau = jax.lax.fori_loop(0, n_panels, panel_body,
                                      (C, V0, tau0))
    else:
        M, V, tau = C, V0, tau0
    d, e = extract_tridiag(M)
    return TridiagResult(d=d, e=e, V=V, tau=tau)


def apply_q(res: TridiagResult, Z: jax.Array) -> jax.Array:
    """TD3 — Y := Q Z, applying the stored reflectors (DORMTR analogue).

    Q = H_0 H_1 ... H_{n-3}, so Y = H_0 (H_1 (... (H_{n-3} Z))).
    """
    n = res.V.shape[0]

    def body(i, Z):
        j = n - 3 - i  # reversed order
        v = res.V[:, j]
        tj = res.tau[j]
        Z = Z - tj * jnp.outer(v, v @ Z)
        return Z

    if n < 3:
        return Z
    return jax.lax.fori_loop(0, n - 2, body, Z)


def apply_qt(res: TridiagResult, Z: jax.Array) -> jax.Array:
    """Y := Q^T Z (forward reflector order)."""
    n = res.V.shape[0]

    def body(j, Z):
        v = res.V[:, j]
        tj = res.tau[j]
        return Z - tj * jnp.outer(v, v @ Z)

    if n < 3:
        return Z
    return jax.lax.fori_loop(0, n - 2, body, Z)
