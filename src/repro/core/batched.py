"""Batched GSYEIG: whole variant pipelines vmapped over stacked pencils.

The paper's two driver applications solve *sequences* of same-shape pencils
(one per MD timestep / DFT SCF iteration). Solving them one `solve` call at
a time leaves throughput on the table twice over: every stage pays its
dispatch latency per pencil, and the hardware never sees a batch dimension.
``solve_batched`` fixes both — each variant's full pipeline (GS1 -> GS2 ->
reduction -> tridiagonal eigensolver -> back-transforms) is compiled ONCE as
a single vmapped program over ``(batch, n, n)`` operand stacks.

Compiled pipelines are cached in a shape-bucket table keyed on
``(n, s, variant, which, ...)`` so a serving engine (see
``repro.serve.eigen_engine``) can stream requests through hot programs.

All four paper variants are supported:
  TD / TT — direct pipelines, every stage vmapped
  KE / KI — the fully jitted ``lanczos_solve_jit`` driver vmapped (fixed
            restart budget; per-pencil convergence flags are returned)
"""
from __future__ import annotations

import time
from functools import partial
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .back_transform import back_transform_generalized
from .cholesky import cholesky_upper
from .lanczos import default_subspace, lanczos_solve_jit
from .operators import ExplicitC, ImplicitC
from .precision import (compute_dtype, default_refine_steps, ensure_strong,
                        validate_precision)
from .refinement import default_guard, refine_eigenpairs_fixed
from .residuals import b_normalize
from .sbr import apply_q2, band_chase, reduce_to_band
from .standard_form import to_standard_two_trsm
from .tridiag import apply_q, tridiagonalize
from .tridiag_eig import eigh_tridiag_selected

BATCHED_VARIANTS = ("TD", "TT", "KE", "KI")


class BatchedSolveResult(NamedTuple):
    evals: jax.Array       # (batch, s) ascending per pencil
    X: jax.Array           # (batch, n, s) B-orthonormal eigenvectors
    converged: jax.Array   # (batch,) bool (always True for TD/TT)
    healthy: jax.Array     # (batch,) bool fused finite-sentinel verdict
    info: Dict[str, Any]


# --------------------------------------------------------------------------
# per-pencil pipelines (vmapped below);
# signature: (A, B, key) -> (lam, X, ok, healthy)
# --------------------------------------------------------------------------


def _output_sentinel(lam, X):
    """Fused per-pencil health sentinel: two reductions folded into the
    ONE vmapped bucket program — zero extra dispatches (the static
    auditor pins ``max_dispatches`` of every ``solve_batched_*`` entry).
    A non-SPD B (NaN Cholesky) or a demoted-stage overflow propagates
    into (lam, X), so finiteness of the outputs covers every stage."""
    return jnp.isfinite(lam).all() & jnp.isfinite(X).all()

def _standard_form(A, B):
    U = cholesky_upper(B)
    C = to_standard_two_trsm(A, U)
    return U, C


def _finalize_invert(lam, X, B_orig):
    """Undo the inverse-pair trick per pencil (mirror of gsyeig._finalize)."""
    lam = 1.0 / lam
    order = jnp.argsort(lam)
    return lam[order], b_normalize(X[:, order], B_orig)


def _refine_fixed(lam, X, A0, B0, which0: str, refine_steps: int, key):
    """Fused fixed-step refinement against the ORIGINAL pencil (after the
    invert-undo, so `which0` is the caller's end)."""
    if refine_steps <= 0:
        return lam, X
    s, n = X.shape[1], X.shape[0]
    return refine_eigenpairs_fixed(A0, B0, lam, X, which=which0,
                                   steps=refine_steps,
                                   guard=default_guard(s, n),
                                   key=jax.random.fold_in(key, 7))


def _pipeline_direct(A, B, key, *, s: int, variant: str, which: str,
                     band_width: int, invert: bool, tt3: str = "batched",
                     cdtype=None, refine_steps: int = 0):
    A0, B0, which0 = A, B, which
    B_orig = B
    if invert:
        A, B = B, A
        which = "largest" if which == "smallest" else "smallest"
    n = A.shape[0]
    U, C = _standard_form(A, B)
    # mixed precision: the reduction + back-transform stages run in the
    # compute dtype; Cholesky/standard form (above) and the tridiagonal
    # eigensolve stay fp64, exactly as in gsyeig.solve
    Cw = C if cdtype is None else C.astype(cdtype)
    ks = jnp.arange(s) if which == "smallest" else jnp.arange(n - s, n)
    if variant == "TD":
        res = tridiagonalize(Cw)
        lam, Z = eigh_tridiag_selected(res.d.astype(jnp.float64),
                                       res.e.astype(jnp.float64),
                                       ks, key, method=tt3)
        Y = apply_q(res, Z if cdtype is None else Z.astype(cdtype))
    else:  # TT
        # the fused one-program panel sweep (kernels/house_panel + SYR2K
        # ladder) vmaps as-is: default_n_chunks sees the per-pencil n;
        # the TT3 stage (kernels/tridiag_eig) is likewise plain traceable
        # jnp, so the bucket's tridiagonal solves are part of this ONE
        # vmapped program — no per-pencil host dispatch anywhere
        band = reduce_to_band(Cw, w=band_width)
        chase = band_chase(band.Wb, band_width)
        lam, Z = eigh_tridiag_selected(chase.d.astype(jnp.float64),
                                       chase.e.astype(jnp.float64),
                                       ks, key, method=tt3)
        Zc = Z if cdtype is None else Z.astype(cdtype)
        Y = band.Q1 @ apply_q2(chase, Zc, band_width)
    Y = Y.astype(A.dtype)
    X = back_transform_generalized(U, Y)
    if invert:
        lam, X = _finalize_invert(lam, X, B_orig)
    lam, X = _refine_fixed(lam, X, A0, B0, which0, refine_steps, key)
    return lam, X, jnp.asarray(True), _output_sentinel(lam, X)


def _pipeline_krylov(A, B, key, *, s: int, variant: str, which: str,
                     m: int, max_restarts: int, invert: bool, p: int,
                     filter_degree: int, cdtype_name: str | None = None,
                     refine_steps: int = 0):
    A0, B0, which0 = A, B, which
    B_orig = B
    if invert:
        A, B = B, A
        which = "largest" if which == "smallest" else "smallest"
    U, C = _standard_form(A, B)
    op = ExplicitC(C) if variant == "KE" else ImplicitC(A, U)
    arp_which = "SA" if which == "smallest" else "LA"
    v0 = jax.random.normal(key, (A.shape[0], p), A.dtype)
    lam, Y, _, converged, healthy = lanczos_solve_jit(
        op, v0, s, m, which=arp_which, max_restarts=max_restarts, p=p,
        filter_degree=filter_degree, compute_dtype=cdtype_name)
    order = jnp.argsort(lam)
    lam, Y = lam[order], Y[:, order]
    X = back_transform_generalized(U, Y)
    if invert:
        lam, X = _finalize_invert(lam, X, B_orig)
    lam, X = _refine_fixed(lam, X, A0, B0, which0, refine_steps, key)
    return lam, X, converged, healthy & _output_sentinel(lam, X)


# --------------------------------------------------------------------------
# shape-bucketed pipeline cache
# --------------------------------------------------------------------------

# (n, s, variant, which, band_width, m, max_restarts, invert, p,
#  filter_degree, dtype, tt3) -> jitted
_PIPELINE_CACHE: Dict[Tuple, Any] = {}
# (pipeline_cache_key, batch) -> AOT-compiled executable; splitting the
# lower+compile step out of the dispatch is what lets ``solve_batched``
# report execution-only wall time (and an honest ``cache_hit`` flag)
_EXEC_CACHE: Dict[Tuple, Any] = {}
_CACHE_STATS = {"hits": 0, "misses": 0}


def pipeline_cache_key(n: int, s: int, variant: str, which: str, *,
                       band_width: int = 8, m: int | None = None,
                       max_restarts: int = 200, invert: bool = False,
                       p: int = 1, filter_degree: int = 0,
                       dtype=jnp.float64, tt3: str = "batched",
                       precision: str = "fp64",
                       refine_steps: int | None = None) -> Tuple:
    if variant in ("KE", "KI") and m is None:
        m = default_subspace(s, n, p)
    if refine_steps is None:
        refine_steps = default_refine_steps(precision)
    return (int(n), int(s), variant, which, int(band_width),
            None if m is None else int(m), int(max_restarts), bool(invert),
            int(p), int(filter_degree), jnp.dtype(dtype).name, tt3,
            validate_precision(precision), int(refine_steps))


def get_pipeline(n: int, s: int, variant: str, which: str, *,
                 band_width: int = 8, m: int | None = None,
                 max_restarts: int = 200, invert: bool = False,
                 p: int = 1, filter_degree: int = 0,
                 dtype=jnp.float64, tt3: str = "batched",
                 precision: str = "fp64", refine_steps: int | None = None):
    """The jitted vmapped pipeline for one shape bucket (cached).

    ``p`` (Lanczos block size) and ``filter_degree`` (Chebyshev start-block
    filter) parameterize the Krylov pipelines; ``tt3`` selects the
    tridiagonal-stage method of the direct pipelines (see
    ``core.tridiag_eig.eigh_tridiag_selected``); ``precision`` /
    ``refine_steps`` select the compute dtype of the GEMM-heavy stages and
    the fused fp64 fixed-step refinement that buys the accuracy back (see
    ``core.precision`` / ``core.refinement``). All are compile-time
    choices, hence part of the bucket key."""
    assert variant in BATCHED_VARIANTS, variant
    ckey = pipeline_cache_key(n, s, variant, which, band_width=band_width,
                              m=m, max_restarts=max_restarts, invert=invert,
                              p=p, filter_degree=filter_degree, dtype=dtype,
                              tt3=tt3, precision=precision,
                              refine_steps=refine_steps)
    fn = _PIPELINE_CACHE.get(ckey)
    if fn is not None:
        _CACHE_STATS["hits"] += 1
        return fn, ckey
    _CACHE_STATS["misses"] += 1
    steps = ckey[-1]
    cdtype = None if precision == "fp64" else compute_dtype(precision)
    if variant in ("TD", "TT"):
        one = partial(_pipeline_direct, s=s, variant=variant, which=which,
                      band_width=band_width, invert=invert, tt3=tt3,
                      cdtype=cdtype, refine_steps=steps)
    else:
        m_eff = m if m is not None else default_subspace(s, n, p)
        one = partial(_pipeline_krylov, s=s, variant=variant, which=which,
                      m=m_eff, max_restarts=max_restarts, invert=invert,
                      p=p, filter_degree=filter_degree,
                      cdtype_name=None if cdtype is None
                      else jnp.dtype(cdtype).name,
                      refine_steps=steps)
    fn = jax.jit(jax.vmap(one))
    _PIPELINE_CACHE[ckey] = fn
    return fn, ckey


def cache_stats() -> Dict[str, int]:
    return dict(_CACHE_STATS, entries=len(_PIPELINE_CACHE),
                exec_entries=len(_EXEC_CACHE))


def clear_pipeline_cache() -> None:
    _PIPELINE_CACHE.clear()
    _EXEC_CACHE.clear()
    _CACHE_STATS.update(hits=0, misses=0)


# --------------------------------------------------------------------------
# public driver
# --------------------------------------------------------------------------

def solve_batched(
    A: jax.Array,
    B: jax.Array,
    s: int,
    variant: str = "TD",
    which: str = "smallest",
    invert: bool = False,
    band_width: int = 8,
    m: int | None = None,
    max_restarts: int = 200,
    key: jax.Array | None = None,
    p: int = 1,
    filter_degree: int = 0,
    tt3: str = "batched",
    precision: str = "fp64",
    refine_steps: int | None = None,
) -> BatchedSolveResult:
    """Solve a stack of same-shape pencils ``A[i] X = B[i] X Lambda``.

    ``A``, ``B``: (batch, n, n). Returns per-pencil ascending eigenvalues
    (batch, s) and B-orthonormal eigenvectors (batch, n, s). ``invert``
    applies the paper's MD inverse-pair trick per pencil (requires A SPD).
    ``p`` / ``filter_degree`` select the block size and Chebyshev filter of
    the Krylov pipelines (ignored by TD/TT); ``tt3`` the direct pipelines'
    tridiagonal-stage method.

    The program comes from two caches: the shape-bucket jit cache (one
    traced pipeline per ``(n, s, variant, which, ...)``) and an AOT
    executable cache per ``(bucket, batch)``. A miss pays XLA compilation
    ONCE, reported separately as ``info['compile_s']`` with
    ``info['cache_hit'] = False`` — ``wall_s`` / ``pencils_per_s`` are
    execution-only either way, so cold-bucket throughput numbers are real.
    ``info['n_unconverged']`` counts pencils whose Krylov driver retired
    at the restart budget (with an ``info['warnings']`` entry when any
    did); TD/TT pencils always converge.

    ``precision`` demotes the GEMM-heavy stages of every pencil to the
    compute dtype of ``core.precision`` and fuses ``refine_steps``
    (default: ``default_refine_steps(precision)``) fixed fp64 refinement
    sweeps against the original pencils into the same compiled program.
    """
    assert A.ndim == 3 and A.shape == B.shape, (A.shape, B.shape)
    validate_precision(precision)
    A = ensure_strong(A)
    B = ensure_strong(B)
    batch, n, _ = A.shape
    if key is None:
        key = jax.random.PRNGKey(20120520)
    keys = jax.random.split(key, batch)
    fn, ckey = get_pipeline(n, s, variant, which, band_width=band_width,
                            m=m, max_restarts=max_restarts, invert=invert,
                            p=p, filter_degree=filter_degree, dtype=A.dtype,
                            tt3=tt3, precision=precision,
                            refine_steps=refine_steps)
    exec_key = (ckey, int(batch))
    compiled = _EXEC_CACHE.get(exec_key)
    cache_hit = compiled is not None
    compile_s = 0.0
    if not cache_hit:
        t0 = time.perf_counter()
        compiled = fn.lower(A, B, keys).compile()
        compile_s = time.perf_counter() - t0
        _EXEC_CACHE[exec_key] = compiled
    t0 = time.perf_counter()
    lam, X, converged, healthy = compiled(A, B, keys)
    jax.block_until_ready(lam)
    wall = time.perf_counter() - t0
    n_unconverged, n_unhealthy = (int(x) for x in jax.device_get(
        (jnp.sum(~converged), jnp.sum(~healthy))))
    info = {"variant": variant, "n": int(n), "s": int(s),
            "batch": int(batch), "which": which, "invert": bool(invert),
            "precision": precision, "refine_steps": int(ckey[-1]),
            "cache_key": ckey, "cache_hit": cache_hit,
            "compile_s": compile_s, "wall_s": wall,
            "pencils_per_s": batch / max(wall, 1e-12),
            "n_unconverged": n_unconverged, "n_unhealthy": n_unhealthy}
    if n_unconverged:
        info["warnings"] = [
            f"{variant}: {n_unconverged}/{batch} pencils retired at the "
            f"restart budget (max_restarts={max_restarts}) without "
            f"converging; their residuals may exceed tolerance"]
    if n_unhealthy:
        info.setdefault("warnings", []).append(
            f"{variant}: {n_unhealthy}/{batch} pencils produced NON-FINITE "
            f"eigenpairs (non-SPD B or overflow in a demoted stage); see "
            f"result.healthy for the per-pencil verdicts")
    return BatchedSolveResult(evals=lam, X=X, converged=converged,
                              healthy=healthy, info=info)


__all__ = ["solve_batched", "BatchedSolveResult", "BATCHED_VARIANTS",
           "get_pipeline", "pipeline_cache_key", "cache_stats",
           "clear_pipeline_cache"]
