"""Operator abstraction for the Krylov-subspace variants.

The paper's ARPACK reverse-communication interface becomes a small pytree
protocol: an operator is a NamedTuple of arrays plus `apply_op`, which the
Lanczos driver closes over. Variants:

  * ExplicitC  — KE: y = C w (one SYMV, 2 n^2 flops/iter)
  * ImplicitC  — KI: y = U^{-T}(A(U^{-1} w))  (TRSV + SYMV + TRSV, 4 n^2)

Each can route its SYMV through the Pallas kernel path (``use_kernel=True``
set by the driver) or plain jnp (XLA dot).
"""
from __future__ import annotations

from typing import NamedTuple, Union

import jax
import jax.numpy as jnp

_solve_tri = jax.scipy.linalg.solve_triangular


class ExplicitC(NamedTuple):
    C: jax.Array


class ImplicitC(NamedTuple):
    A: jax.Array
    U: jax.Array


Operator = Union[ExplicitC, ImplicitC]


def _symm(M: jax.Array, w: jax.Array, use_kernel: bool) -> jax.Array:
    """y = M w for a vector or an (n, p) block — the block Lanczos core
    feeds whole blocks through ONE fused multi-RHS product (SYMM/GEMM)
    instead of p SYMVs."""
    if use_kernel:
        from repro.kernels.symv import ops as symv_ops
        if w.ndim == 1:
            return symv_ops.symv(M, w)
        return symv_ops.symm_block(M, w)
    if M.dtype == jnp.bfloat16:
        # XLA fallback of the kernel's fp32-accumulating bf16 MXU path
        return jnp.matmul(M, w, preferred_element_type=jnp.float32) \
            .astype(M.dtype)
    return M @ w


def apply_op(op: Operator, w: jax.Array, use_kernel: bool = False) -> jax.Array:
    """One operator application; the hot loop of KE (KE1) / KI (KI1-KI3).

    ``w`` may be a vector (n,) or an (n, p) Lanczos block; every stage
    (SYMM and the triangular solves) handles the multi-RHS case natively.
    """
    if isinstance(op, ExplicitC):
        return _symm(op.C, w, use_kernel)
    if isinstance(op, ImplicitC):
        # KI1: wbar = U^{-1} w
        wbar = _solve_tri(op.U, w, trans=0, lower=False)
        # KI2: what = A wbar
        what = _symm(op.A, wbar, use_kernel)
        # KI3: z = U^{-T} what
        return _solve_tri(op.U, what, trans=1, lower=False)
    raise TypeError(f"unknown operator {type(op)}")


def op_dim(op: Operator) -> int:
    if isinstance(op, ExplicitC):
        return op.C.shape[0]
    return op.A.shape[0]


def matvecs_per_apply(op: Operator) -> int:
    """Bookkeeping for the benchmark tables: flop-equivalent 2n^2 units."""
    return 1 if isinstance(op, ExplicitC) else 2
