"""Top-level GSYEIG driver: A X = B X Lambda, s << n wanted eigenpairs.

Four variants, exactly the paper's:
  TD — Cholesky + standard form + direct tridiagonalization + bisect/invit
  TT — Cholesky + standard form + two-stage (band) reduction + bisect/invit
  KE — Cholesky + standard form + thick-restart Lanczos on explicit C
  KI — Cholesky + Lanczos on implicit C = U^{-T} A U^{-1} (no GS2)

`which='smallest'|'largest'` selects the end of the spectrum;
`invert=True` applies the paper's MD trick (solve the inverse pair (B, A)
for its largest eigenpairs — valid when A is also SPD — and map back).
`variant='auto'` routes through the cost model in
``repro.analysis.variant_model`` (see ``info['router']`` for the decision).

Every stage is individually jitted and timed (paper Tables 2/6 keys).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.resilience import faults
from repro.resilience.health import (array_finite, chol_health, host_finite,
                                     verdict_from_stages)
from repro.resilience.recovery import (SolverError, cholesky_shift_taus,
                                       rung, validate_on_failure)

from .back_transform import back_transform_generalized
from .cholesky import cholesky_blocked, cholesky_upper, diag_shifted
from .lanczos import default_subspace, lanczos_solve
from .operators import ExplicitC, ImplicitC
from .precision import compute_dtype, ensure_strong, validate_precision
from .refinement import REFINE_TOL, refine_eigenpairs
from . import sbr as _sbr
from .sbr import apply_q2, band_chase, default_n_chunks, reduce_to_band
from .standard_form import to_standard_sygst, to_standard_two_trsm
from .tridiag import apply_q, tridiagonalize, tridiagonalize_blocked
from .tridiag_eig import eigh_tridiag_selected

VARIANTS = ("TD", "TT", "KE", "KI")


@dataclass
class GSyEigResult:
    evals: jax.Array                 # (s,) ascending (original problem)
    X: jax.Array                     # (n, s) B-orthonormal eigenvectors
    stage_times: Dict[str, float] = field(default_factory=dict)
    info: Dict[str, Any] = field(default_factory=dict)


def _timed(times: Dict[str, float], key: str):
    def wrap(fn, *args, **kwargs):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        times[key] = times.get(key, 0.0) + (time.perf_counter() - t0)
        return out
    return wrap


# module-level jitted stages (cached across driver calls with equal shapes).
# GS1/GS2 carry FUSED health sentinels: the isfinite/pivot reductions are
# part of the same program as the factorization they guard, so stage
# verdicts cost zero extra dispatches (the auditor's
# ``resilience/stage_sentinels`` entry pins this)
def _chol_fused(B):
    U = cholesky_upper(B)
    ok, min_diag = chol_health(U)
    return U, ok, min_diag


def _chol_blocked_fused(B, block):
    U = cholesky_blocked(B, block)
    ok, min_diag = chol_health(U)
    return U, ok, min_diag


def _chol_ladder_fused(B, taus):
    """Degradation ladder, rung 1, as ONE program: Cholesky every
    diagonally-shifted candidate ``B + tau*max|diag B|*I`` in a single
    vmapped dispatch, returning the stacked factors and per-rung health
    flags. Rung-by-rung retries would cost a dispatch plus a host sync
    per tau; fusing the ladder makes even a fully exhausted ladder cost
    one dispatch and one fetch, which is what keeps failed lanes from
    sinking healthy serving throughput (the chaos bench gates this)."""
    def one(tau):
        U = cholesky_upper(diag_shifted(B, tau))
        ok, _ = chol_health(U)
        return U, ok
    return jax.vmap(one)(taus)


def _gs2_trsm_fused(A, U):
    C = to_standard_two_trsm(A, U)
    return C, array_finite(C)


def _gs2_sygst_fused(A, U, block):
    C = to_standard_sygst(A, U, block=block)
    return C, array_finite(C)


_jit_chol = jax.jit(_chol_fused)
_jit_chol_blocked = jax.jit(_chol_blocked_fused, static_argnames=("block",))
_jit_chol_ladder = jax.jit(_chol_ladder_fused)
_jit_gs2_trsm = jax.jit(_gs2_trsm_fused)
_jit_gs2_sygst = jax.jit(_gs2_sygst_fused, static_argnames=("block",))
_jit_td1 = jax.jit(tridiagonalize)
_jit_td1_blocked = jax.jit(tridiagonalize_blocked, static_argnames=("panel",))
_jit_td3 = jax.jit(apply_q)
# TT4: back-transform the (n, s) Ritz slab through the recorded TT2
# rotation stream, then one GEMM against the explicit Q1 — no (n, n) Q2
_jit_tt4 = jax.jit(lambda chase, Q1, Z, w: Q1 @ apply_q2(chase, Z, w),
                   static_argnames=("w",))
_jit_bt1 = jax.jit(back_transform_generalized)


def _solve_once(
    A: jax.Array,
    B: jax.Array,
    s: int,
    variant: str = "TD",
    which: str = "smallest",
    invert: bool = False,
    gs2: str = "trsm",          # 'trsm' (2n^3, paper's pick) or 'sygst' (n^3)
    gs1: str = "fused",         # 'fused' (DPOTRF analogue) or 'blocked'
    td1: str = "unblocked",     # 'unblocked' (BLAS-2 DSYTRD) or 'blocked'
    band_width: int = 16,
    block: int = 256,
    m: int | None = None,
    tol: float = 0.0,
    max_restarts: int = 500,
    use_kernel: bool = False,
    key: jax.Array | None = None,
    mesh=None,
    clustered: bool = False,
    machine=None,
    krylov_block: int | None = None,
    filter: int | None = None,        # noqa: A002 — the paper-facing name
    precision: str = "fp64",
    refine: bool | None = None,
    refine_tol: float = REFINE_TOL,
    refine_max_steps: int = 60,
    on_failure: str = "warn",
    recovery: list | None = None,
) -> GSyEigResult:
    """One attempt of the pipeline (the public ``solve`` wraps this with
    the degradation ladder). Stage health verdicts land in
    ``info['_stage_health']`` for the wrapper to fold into
    ``info['health']``; a breakdown or non-finite stage raises a
    diagnosed ``SolverError`` unless ``on_failure == 'ignore'``.

    `mesh=` (a jax.sharding.Mesh with a 'model' axis plus data axes)
    dispatches the KE and TT variants onto the distributed pipelines in
    ``repro.dist.eigensolver`` — same driver logic, every stage routed
    through ``repro.dist.sharded_la`` (KE: every matvec a ``dist_symv``;
    TT: ELPA2-style distributed two-stage band reduction).

    ``variant='auto'`` asks the flop/bandwidth cost model in
    ``repro.analysis.variant_model`` to pick the fastest variant for
    ``(n, s, band_width, mesh)``; the choice and its predicted-time table
    land in ``result.info['router']``. ``clustered=True`` tells the router
    the wanted end of the spectrum is clustered (DFT-like valence bands),
    which inflates the Lanczos iteration estimate — the decisive input for
    the KE-vs-TT crossover. ``machine=`` optionally supplies a (possibly
    measurement-calibrated, see ``MachineParams.from_artifact``)
    throughput model for the router.

    Krylov-side knobs (KE/KI only): ``krylov_block`` is the Lanczos block
    size p — each s-step segment advances p basis vectors with one fused
    multi-RHS matvec (``None`` = auto: 4 on a mesh, where the block
    structure is what buys the two-collectives-per-step schedule, 1
    locally). ``filter`` is the Chebyshev start-block filter degree
    (``None`` = auto: 16 when ``clustered=True`` — the clustered wanted
    end is exactly the case the filter exists for — else off; 0 forces
    off). Both land in ``result.info['krylov']``.

    ``precision=`` selects the compute dtype of the GEMM-heavy stages
    (``'fp64'`` default, ``'mixed'`` = fp32, ``'fast'`` = bf16 with fp32
    accumulation — see ``core.precision``); Cholesky/standard form, the
    tridiagonal eigensolve and all convergence math stay fp64. When the
    pipeline demoted anything, ``refine`` (default: on for non-fp64)
    runs fp64 iterative refinement of the returned eigenpairs against
    the *original* pencil until ``refine_tol`` (the Table-3 tolerance)
    is met — step count and residual trajectory land in
    ``result.info['refinement']``, the wall time in
    ``stage_times['RF']``."""
    validate_precision(precision)
    validate_on_failure(on_failure)
    if recovery is None:
        recovery = []
    stage_health: Dict[str, bool] = {}
    cdtype = compute_dtype(precision)
    demoted = precision != "fp64"
    if refine is None:
        refine = demoted
    # the declared working dtype is fp64: promote weak-typed (Python-
    # scalar-born) pencils on entry so the first downstream op cannot
    # silently decide the precision
    A = ensure_strong(A)
    B = ensure_strong(B)
    n = A.shape[0]
    times: Dict[str, float] = {}
    info: Dict[str, Any] = {"variant": variant, "n": n, "s": s,
                            "invert": invert, "which": which,
                            "precision": precision}
    # Krylov knobs resolve once, for the router and both solve paths
    p = krylov_block if krylov_block is not None else (
        4 if mesh is not None else 1)
    filter_degree = filter if filter is not None else (
        16 if clustered else 0)
    if variant == "auto":
        from repro.analysis.variant_model import (DISTRIBUTED_VARIANTS,
                                                  choose_variant)
        mesh_shape = tuple(mesh.devices.shape) if mesh is not None else None
        # any mesh (even a degenerate 1x1) narrows the candidates to the
        # variants the mesh dispatch below actually implements
        allow = DISTRIBUTED_VARIANTS if mesh is not None else None
        choice = choose_variant(n, s, band_width=band_width, m=m,
                                clustered=clustered, mesh_shape=mesh_shape,
                                allow=allow, machine=machine,
                                krylov_block=p, filter_degree=filter_degree,
                                precision=precision)
        variant = choice.variant
        info["variant"] = variant
        info["router"] = choice.as_json_dict()
    assert variant in VARIANTS, variant
    if key is None:
        key = jax.random.PRNGKey(20120520)
    if variant in ("KE", "KI"):
        info["krylov"] = {"p": int(p), "filter_degree": int(filter_degree)}

    A_orig, B_orig, which_orig = A, B, which
    refine_cfg = ({"tol": refine_tol, "max_steps": refine_max_steps}
                  if refine else None)
    if invert:
        # paper's MD trick: largest eigenpairs of the inverse pair (B, A)
        A, B = B, A
        which = "largest" if which == "smallest" else "smallest"

    if mesh is not None:
        if variant not in ("KE", "TT"):
            raise NotImplementedError(
                f"mesh= dispatch implements the KE and TT variants, "
                f"got {variant}")
        if gs2 != "trsm" or use_kernel:
            # the distributed pipelines are blocked-Cholesky + two-TRSM with
            # shard_map stages; reject flags they cannot honor rather than
            # silently substituting
            raise NotImplementedError(
                "mesh= implements gs2='trsm' without the Pallas kernel path")
        if variant == "KE":
            from repro.dist.eigensolver import solve_ke_distributed
            lam, X, dinfo = solve_ke_distributed(
                mesh, A, B, s, m=m, which=which, tol=tol,
                max_restarts=max_restarts, key=key, return_info=True,
                p=p, filter_degree=filter_degree, precision=precision)
        else:
            from repro.dist.eigensolver import solve_tt_distributed
            lam, X, dinfo = solve_tt_distributed(
                mesh, A, B, s, which=which, band_width=band_width, key=key,
                return_info=True, precision=precision)
        times.update(dinfo.pop("stage_times"))
        info.update(dinfo)
        stage_health[f"{variant}_dist"] = bool(dinfo.get("healthy", True))
        info["_stage_health"] = stage_health
        if not stage_health[f"{variant}_dist"] and on_failure != "ignore":
            raise SolverError(
                f"distributed {variant} produced a non-finite restart "
                f"state", stage=f"{variant}_dist", reason="nonfinite_stage",
                hint="probable GS1 breakdown (non-SPD B) or overflow in a "
                     "demoted stage; retry with precision='fp64' or check "
                     "the pencil", recovery=recovery,
                health=verdict_from_stages(stage_health).as_json_dict())
        if not info.get("converged", True):
            info.setdefault("warnings", []).append(
                f"{variant} retired UNCONVERGED after "
                f"{info.get('n_restart', max_restarts)} restarts "
                f"(max_restarts={max_restarts}); eigenpairs are the best "
                f"Ritz approximations at exit")
        return _finalize(lam, X, A_orig, B_orig, which_orig, invert,
                         times, info, refine_cfg)

    # ---- GS1: B = U^T U --------------------------------------------------
    # the factor's health sentinel is fused into the same program (zero
    # extra dispatches); fetching the scalar verdict is a transfer the
    # _timed block_until_ready already paid for
    Bg = faults.poison_stage("GS1", B)
    chol_stage = (partial(_jit_chol_blocked, block=block)
                  if gs1 == "blocked" else _jit_chol)
    U, gs1_ok, _ = _timed(times, "GS1")(chol_stage, Bg)
    gs1_ok = bool(jax.device_get(gs1_ok))
    if not gs1_ok and on_failure != "ignore":
        if not host_finite(Bg):
            stage_health["GS1"] = False
            raise SolverError(
                "non-finite B entering GS1 (Cholesky)", stage="GS1",
                reason="nonfinite_stage",
                hint="the input pencil itself is corrupted; transient "
                     "corruption is retryable under on_failure='recover'",
                recovery=recovery,
                health=verdict_from_stages(stage_health).as_json_dict())
        # degradation ladder, rung 1: relative diagonal-shift retries —
        # roundoff-level indefiniteness is recoverable, a truly non-SPD
        # B exhausts the ladder into a diagnosed SolverError. All rungs
        # run as ONE vmapped dispatch with a single fetch of the
        # per-rung verdicts, so an exhausted ladder stays cheap
        taus = cholesky_shift_taus()
        Us, oks = _timed(times, "GS1")(
            _jit_chol_ladder, Bg, jnp.asarray(taus, dtype=Bg.dtype))
        oks = [bool(x) for x in jax.device_get(oks)]
        for i, tau in enumerate(taus):
            if oks[i]:
                recovery.append(rung("cholesky_shift", "GS1", "recovered",
                                     tau=float(tau)))
                info["gs1_shift"] = float(tau)
                U = Us[i]
                gs1_ok = True
                break
            recovery.append(rung("cholesky_shift", "GS1", "failed",
                                 tau=float(tau)))
        if not gs1_ok:
            stage_health["GS1"] = False
            raise SolverError(
                "GS1 Cholesky breakdown: B is not SPD (all diagonal-shift "
                "rungs failed)", stage="GS1", reason="cholesky_breakdown",
                hint="check the B operand — the generalized problem "
                     "requires B symmetric positive definite; shifts up to "
                     f"tau={cholesky_shift_taus()[-1]:g}*max|diag B| did "
                     "not rescue it", recovery=recovery,
                health=verdict_from_stages(stage_health).as_json_dict())
    stage_health["GS1"] = gs1_ok

    # ---- GS2: C = U^{-T} A U^{-1} (not for KI) ---------------------------
    C = None
    if variant in ("TD", "TT", "KE"):
        Ag = faults.poison_stage("GS2", A)
        if gs2 == "sygst":
            C, gs2_ok = _timed(times, "GS2")(_jit_gs2_sygst, Ag, U,
                                             block=block)
        else:
            C, gs2_ok = _timed(times, "GS2")(_jit_gs2_trsm, Ag, U)
        gs2_ok = bool(jax.device_get(gs2_ok))
        stage_health["GS2"] = gs2_ok
        if not gs2_ok and on_failure != "ignore":
            raise SolverError(
                "non-finite standard-form C after GS2", stage="GS2",
                reason="nonfinite_stage",
                hint="non-finite A, or U from a near-breakdown GS1; "
                     "transient corruption is retryable under "
                     "on_failure='recover'", recovery=recovery,
                health=verdict_from_stages(stage_health).as_json_dict())

    want_small = which == "smallest"
    if variant in ("TD", "TT"):
        ks = jnp.arange(s) if want_small else jnp.arange(n - s, n)
        # the reflector/rotation stages run in the compute dtype; the
        # tridiagonal eigensolve (TD2/TT3) is promoted back to fp64
        Cw = C if not demoted else C.astype(cdtype)
        if variant == "TD":
            Cw = faults.poison_stage("TD1", Cw)
            if td1 == "blocked":
                res = _timed(times, "TD1")(_jit_td1_blocked, Cw, panel=32)
            else:
                res = _timed(times, "TD1")(_jit_td1, Cw)
            # host-side sentinel on the small (n,)/(n-1,) tridiagonal
            # outputs the TD2 stage fetches anyway — zero dispatches (a
            # wrapping jit would break the composite stage's own timing)
            stage_health["TD1"] = host_finite(res.d, res.e)
            if not stage_health["TD1"] and on_failure != "ignore":
                raise SolverError(
                    "non-finite tridiagonal after TD1", stage="TD1",
                    reason="nonfinite_stage",
                    hint="corrupted C entering the reflector sweep "
                         "(demoted-stage overflow or upstream NaN)",
                    recovery=recovery,
                health=verdict_from_stages(stage_health).as_json_dict())
            lam, Z = _timed(times, "TD2")(
                eigh_tridiag_selected, res.d.astype(jnp.float64),
                res.e.astype(jnp.float64), ks, key)
            Y = _timed(times, "TD3")(_jit_td3, res, Z.astype(cdtype))
        else:
            # TT1 split: the sweep is ONE compiled program (reduce_to_band
            # is internally jitted); record the ladder choice + dispatch
            # count so the stage timing is attributable
            Cw = faults.poison_stage("TT1", Cw)
            n_chunks = default_n_chunks(n, band_width)
            d0 = _sbr.dispatch_count()
            band = _timed(times, "TT1")(reduce_to_band, Cw, w=band_width,
                                        n_chunks=n_chunks)
            info["tt1"] = {"n_chunks": int(n_chunks),
                           "dispatches": int(_sbr.dispatch_count() - d0)}
            # host sentinel on the (w+1, n) band the chase consumes
            stage_health["TT1"] = host_finite(band.Wb)
            if not stage_health["TT1"] and on_failure != "ignore":
                raise SolverError(
                    "non-finite band matrix after the TT1 sweep",
                    stage="TT1", reason="nonfinite_stage",
                    hint="corrupted C entering the panel sweep "
                         "(demoted-stage overflow or upstream NaN)",
                    recovery=recovery,
                health=verdict_from_stages(stage_health).as_json_dict())
            chase = _timed(times, "TT2")(band_chase, band.Wb, band_width)
            stage_health["TT2"] = host_finite(chase.d, chase.e)
            if not stage_health["TT2"] and on_failure != "ignore":
                raise SolverError(
                    "non-finite tridiagonal after the TT2 chase",
                    stage="TT2", reason="nonfinite_stage",
                    hint="the rotation wavefront hit non-finite band "
                         "entries", recovery=recovery,
                    health=verdict_from_stages(stage_health).as_json_dict())
            lam, Z = _timed(times, "TT3")(
                eigh_tridiag_selected, chase.d.astype(jnp.float64),
                chase.e.astype(jnp.float64), ks, key)
            Y = _timed(times, "TT4")(_jit_tt4, chase, band.Q1,
                                     Z.astype(cdtype), w=band_width)
        Y = Y.astype(jnp.float64)
    else:
        arp_which = "SA" if want_small else "LA"
        if variant == "KE":
            op = ExplicitC(faults.poison_stage("KE_iter", C))
            prefix = "KE"
        else:
            op = ImplicitC(faults.poison_stage("KI_iter", A), U)
            prefix = "KI"
        if m is None:
            m = default_subspace(s, n, p)
        elif p > 1 and m % p:
            m = -(-m // p) * p          # block-align a user-supplied m
        tol, max_restarts = faults.force_nonconverge(tol, max_restarts)
        t0 = time.perf_counter()
        lres = lanczos_solve(op, s, which=arp_which, m=m, tol=tol,
                             max_restarts=max_restarts, key=key,
                             use_kernel=use_kernel, p=p,
                             filter_degree=filter_degree,
                             compute_dtype=cdtype if demoted else None)
        jax.block_until_ready(lres.evecs)
        times[f"{prefix}_iter"] = time.perf_counter() - t0
        # plain-Python payloads only: info must survive json.dump in the
        # benchmark scripts (a jax array here broke them)
        info.update(n_matvec=int(lres.n_matvec), n_restart=int(lres.n_restart),
                    converged=bool(lres.converged),
                    resid_bounds=[float(r) for r in
                                  jnp.asarray(lres.resid_bounds)])
        stage_health[f"{prefix}_iter"] = bool(lres.healthy)
        if not lres.healthy and on_failure != "ignore":
            raise SolverError(
                f"{prefix} restart state went non-finite after "
                f"{int(lres.n_restart)} restarts", stage=f"{prefix}_iter",
                reason="nonfinite_stage",
                hint="NaN/inf in the Lanczos basis — corrupted operator "
                     "or demoted-matvec overflow; transient corruption is "
                     "retryable under on_failure='recover'",
                recovery=recovery,
                health=verdict_from_stages(stage_health).as_json_dict())
        if not lres.converged:
            info.setdefault("warnings", []).append(
                f"{prefix} retired UNCONVERGED after {int(lres.n_restart)} "
                f"restarts (max_restarts={max_restarts}); eigenpairs are "
                f"the best Ritz approximations at exit")
        lam, Y = lres.evals, lres.evecs
        # Lanczos returns wanted-first ordering; sort ascending like TD/TT
        order = jnp.argsort(lam)
        lam, Y = lam[order], Y[:, order]

    # ---- BT1: X = U^{-1} Y ----------------------------------------------
    X = _timed(times, "BT1")(_jit_bt1, U, Y)

    info["_stage_health"] = stage_health
    return _finalize(lam, X, A_orig, B_orig, which_orig, invert, times,
                     info, refine_cfg)


def _finalize(lam, X, A_orig, B_orig, which_orig: str, invert: bool,
              times: Dict[str, float], info: Dict[str, Any],
              refine_cfg: Dict[str, Any] | None = None) -> GSyEigResult:
    """Shared epilogue of the local and distributed paths: undo the
    inverse-pair trick, refine against the original fp64 pencil when
    asked, and total the stage timings."""
    if invert:
        lam = 1.0 / lam
        order = jnp.argsort(lam)
        lam, X = lam[order], X[:, order]
        # the inverse-pair solve returns A-orthonormal vectors; renormalize
        # each column to unit B-norm for the original problem's metric
        from .residuals import b_normalize
        X = b_normalize(X, B_orig)

    if refine_cfg is not None:
        t0 = time.perf_counter()
        lam, X, rinfo = refine_eigenpairs(
            A_orig, B_orig, lam, X, which=which_orig, **refine_cfg)
        jax.block_until_ready(X)
        times["RF"] = time.perf_counter() - t0
        info["refinement"] = rinfo

    times["Tot."] = float(sum(v for k, v in times.items() if k != "Tot."))
    return GSyEigResult(evals=lam, X=X, stage_times=times, info=info)


def solve(
    A: jax.Array,
    B: jax.Array,
    s: int,
    variant: str = "TD",
    which: str = "smallest",
    invert: bool = False,
    gs2: str = "trsm",
    gs1: str = "fused",
    td1: str = "unblocked",
    band_width: int = 16,
    block: int = 256,
    m: int | None = None,
    tol: float = 0.0,
    max_restarts: int = 500,
    use_kernel: bool = False,
    key: jax.Array | None = None,
    mesh=None,
    clustered: bool = False,
    machine=None,
    krylov_block: int | None = None,
    filter: int | None = None,        # noqa: A002 — the paper-facing name
    precision: str = "fp64",
    refine: bool | None = None,
    refine_tol: float = REFINE_TOL,
    refine_max_steps: int = 60,
    on_failure: str = "warn",
    max_retries: int = 2,
) -> GSyEigResult:
    """GSYEIG with failure containment: ``_solve_once`` (see its
    docstring for the solver knobs) wrapped in the degradation ladder of
    ``repro.resilience.recovery``.

    ``on_failure`` selects the policy:

      ``'warn'`` (default) — stage-boundary health sentinels diagnose
        failures: a GS1 breakdown tries the diagonal-shift rungs, any
        remaining non-finite stage or output raises ``SolverError``
        (never silent NaN eigenpairs); unconverged Krylov solves retire
        with a warning, exactly as before.
      ``'recover'`` — additionally climbs the ladder: transient
        non-finite failures are retried up to ``max_retries`` times
        (fresh key); an unconverged KE/KI escalates the restart budget
        and Chebyshev filter, then falls back to the direct TT variant;
        a mixed/fast refinement stalling above tolerance reruns at fp64.
      ``'ignore'`` — the pre-resilience behavior (no raises, no
        retries); the health verdict is still recorded.

    Every solve carries ``info['health']`` (per-stage verdicts, JSON-
    clean) and ``info['recovery']`` (the rungs taken, possibly empty).
    """
    validate_on_failure(on_failure)
    recovery: list = []
    kw: Dict[str, Any] = dict(
        variant=variant, which=which, invert=invert, gs2=gs2, gs1=gs1,
        td1=td1, band_width=band_width, block=block, m=m, tol=tol,
        max_restarts=max_restarts, use_kernel=use_kernel, key=key,
        mesh=mesh, clustered=clustered, machine=machine,
        krylov_block=krylov_block, filter=filter, precision=precision,
        refine=refine, refine_tol=refine_tol,
        refine_max_steps=refine_max_steps)

    def attempt(attempt_kw):
        res = _solve_once(A, B, s, on_failure=on_failure,
                          recovery=recovery, **attempt_kw)
        stages = res.info.pop("_stage_health", {})
        # final output sentinel: host-side on the (s,)/(n, s) results the
        # caller fetches anyway — zero extra dispatches
        out_ok = host_finite(res.evals, res.X)
        stages["OUT"] = out_ok
        res.info["health"] = verdict_from_stages(stages).as_json_dict()
        res.info["recovery"] = recovery
        if not out_ok and on_failure != "ignore":
            raise SolverError(
                "solver produced non-finite eigenpairs", stage="OUT",
                reason="nonfinite_output",
                hint="every stage sentinel passed but the output is "
                     "corrupt — suspect the back-transform operands; "
                     "transient corruption is retryable under "
                     "on_failure='recover'", recovery=recovery,
                health=res.info["health"])
        return res

    retries = 0
    retry_rung = None
    while True:
        try:
            res = attempt(kw)
            break
        except SolverError as err:
            transient = err.diagnosis["reason"] in ("nonfinite_stage",
                                                    "nonfinite_output")
            if not (on_failure == "recover" and transient
                    and retries < max_retries):
                raise
            retries += 1
            retry_rung = rung("transient_retry", err.diagnosis["stage"],
                              "attempt", attempt=retries)
            recovery.append(retry_rung)
            base_key = (kw["key"] if kw["key"] is not None
                        else jax.random.PRNGKey(20120520))
            kw = dict(kw, key=jax.random.fold_in(base_key, 1000 + retries))
    if retry_rung is not None:
        retry_rung["outcome"] = "recovered"

    # --- ladder: unconverged Krylov -> escalate -> TT fallback -----------
    if on_failure == "recover" and not res.info.get("converged", True):
        resolved = res.info["variant"]
        fd = int(res.info.get("krylov", {}).get("filter_degree", 0))
        esc_restarts = int(max_restarts) * 4
        esc_filter = max(16, fd)
        r = rung("escalate_krylov", f"{resolved}_iter", "attempt",
                 max_restarts=esc_restarts, filter_degree=esc_filter)
        recovery.append(r)
        res2 = attempt(dict(kw, variant=resolved,
                            max_restarts=esc_restarts, filter=esc_filter))
        if res2.info.get("converged", True):
            r["outcome"] = "recovered"
            res = res2
        else:
            r["outcome"] = "failed"
            fb = rung("fallback_variant", f"{resolved}_iter", "attempt",
                      variant="TT")
            recovery.append(fb)
            res = attempt(dict(kw, variant="TT"))
            fb["outcome"] = ("recovered"
                             if res.info.get("converged", True) else "failed")

    # --- ladder: demoted refinement stalled above tol -> fp64 rerun ------
    rinfo = res.info.get("refinement")
    if (on_failure == "recover" and precision != "fp64" and rinfo
            and not rinfo.get("converged", True) and rinfo.get("stalled")):
        r = rung("escalate_precision", "RF", "attempt",
                 from_precision=precision, to_precision="fp64")
        recovery.append(r)
        res = attempt(dict(kw, variant=res.info["variant"],
                           precision="fp64", refine=True))
        r["outcome"] = ("recovered"
                        if res.info.get("refinement",
                                        {}).get("converged", True)
                        else "failed")
    return res
