"""Shared dense linear-algebra helpers for the eigensolver core.

All routines are pure-jnp, fixed-shape, and jit-friendly. They implement the
LAPACK building blocks (dlarfg-style Householder reflectors, compact-WY
accumulation, Givens rotations) that the paper's four pipelines are made of.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .precision import matmul_acc


def symmetrize(M: jax.Array) -> jax.Array:
    """Return (M + M^T)/2 — used after two-sided updates to kill drift."""
    return 0.5 * (M + M.T)


def householder(x: jax.Array):
    """LAPACK dlarfg: given x (k,), return (v, tau, beta) with
    (I - tau v v^T) x = beta e_1 and v[0] = 1.

    If the tail of x is (numerically) zero, tau = 0 and beta = x[0]
    (identity reflector).
    """
    alpha = x[0]
    sigma = jnp.sum(x[1:] ** 2)
    safe = sigma > 0.0
    norm_x = jnp.sqrt(alpha * alpha + sigma)
    # beta = -sign(alpha) * ||x||, sign(0) treated as +1 to avoid c=0.
    sgn = jnp.where(alpha >= 0.0, 1.0, -1.0)
    beta = jnp.where(safe, -sgn * norm_x, alpha)
    denom = jnp.where(safe, alpha - beta, 1.0)
    v = jnp.concatenate([jnp.ones((1,), x.dtype), x[1:] / denom])
    v = jnp.where(safe, v, jnp.zeros_like(v).at[0].set(1.0))
    tau = jnp.where(safe, (beta - alpha) / beta, 0.0)
    return v, tau, beta


def householder_masked(x: jax.Array, pivot: jax.Array):
    """Householder reflector for the tail x[pivot:] of a full-length vector.

    Entries at indices < pivot are ignored; the returned v is full-length with
    v[pivot] = 1 and zeros before `pivot`. Works with a traced `pivot`, so it
    can live inside lax loops (the workhorse of the tridiagonalization).
    Returns (v, tau, beta).
    """
    n = x.shape[0]
    idx = jnp.arange(n)
    xm = jnp.where(idx >= pivot, x, 0.0)
    alpha = jnp.take(x, pivot, mode="clip")
    sigma = jnp.sum(xm**2) - alpha * alpha
    sigma = jnp.maximum(sigma, 0.0)
    safe = sigma > 0.0
    norm_x = jnp.sqrt(alpha * alpha + sigma)
    sgn = jnp.where(alpha >= 0.0, 1.0, -1.0)
    beta = jnp.where(safe, -sgn * norm_x, alpha)
    denom = jnp.where(safe, alpha - beta, 1.0)
    v = jnp.where(idx > pivot, xm / denom, 0.0)
    v = v.at[pivot].set(1.0)
    v = jnp.where(safe, v, jnp.zeros_like(v).at[pivot].set(1.0))
    tau = jnp.where(safe, (beta - alpha) / beta, 0.0)
    return v, tau, beta


def qr_wy(E: jax.Array):
    """Householder QR with compact-WY accumulation.

    E is (p, w) with p >= 1. Returns (V, T, R) such that
        Q = I_p - V T V^T  (orthogonal, p x p),   Q^T E = R (upper trapezoidal)
    V is (p, w) unit lower trapezoidal, T is (w, w) upper triangular.
    The number of nontrivial reflectors is min(p, w); trailing columns of V/T
    are zero-padded so shapes stay static.
    """
    p, w = E.shape
    nr = min(p, w)
    V = jnp.zeros((p, w), E.dtype)
    T = jnp.zeros((w, w), E.dtype)
    R = E
    for j in range(nr):
        v, tau, _ = householder_masked(R[:, j], jnp.asarray(j))
        # apply reflector to trailing columns (including j to produce R)
        proj = v @ R  # (w,)
        R = R - tau * jnp.outer(v, proj)
        V = V.at[:, j].set(v)
        # T update: T[:j, j] = -tau * T[:j, :j] @ (V[:, :j]^T v)
        if j > 0:
            z = V[:, :j].T @ v
            T = T.at[:j, j].set(-tau * (T[:j, :j] @ z))
        T = T.at[j, j].set(tau)
    # clean numerical noise below the diagonal of R
    R = jnp.triu(R)
    return V, T, R


def qr_wy_masked(E: jax.Array, row_start) -> tuple:
    """Householder QR of the sub-panel E[row_start:, :] in fixed shapes.

    E is full-height (n, w); reflector j pivots at row ``row_start + j`` and
    only touches rows >= row_start (entries above are untouched — exactly the
    blocked band-reduction panel op). Returns (V, T, R) with V (n, w) masked
    (zeros above the pivot rows), T (w, w), R = Q^T E (full height: rows
    above row_start pass through unchanged).

    Unlike ``qr_wy`` this traces a FIXED-shape graph regardless of the panel
    position, so a fori_loop over panels compiles once (the per-panel
    trace-time specialization was a 3-minute XLA compile at n=256).
    """
    n, w = E.shape
    V = jnp.zeros((n, w), E.dtype)
    T = jnp.zeros((w, w), E.dtype)
    R = E
    for j in range(w):
        v, tau, _ = householder_masked(R[:, j], row_start + j)
        R = R - tau * jnp.outer(v, v @ R)
        V = V.at[:, j].set(v)
        if j > 0:
            z = V[:, :j].T @ v
            T = T.at[:j, j].set(-tau * (T[:j, :j] @ z))
        T = T.at[j, j].set(tau)
    return V, T, R


def apply_wy_left_t(V: jax.Array, T: jax.Array, M: jax.Array) -> jax.Array:
    """Compute Q^T M with Q = I - V T V^T  =>  M - V T^T (V^T M)."""
    return M - V @ (T.T @ (V.T @ M))


def apply_wy_right(M: jax.Array, V: jax.Array, T: jax.Array) -> jax.Array:
    """Compute M Q with Q = I - V T V^T  =>  M - ((M V) T) V^T."""
    return M - (M @ V) @ T @ V.T


def apply_wy_two_sided(C: jax.Array, V: jax.Array, T: jax.Array) -> jax.Array:
    """Compute Q^T C Q for symmetric C with Q = I - V T V^T (4 GEMMs)."""
    X = C @ V  # (p, w)
    XT = X @ T  # (p, w)
    W = V.T @ XT  # (w, w)
    out = C - XT @ V.T - V @ XT.T + V @ (T.T @ W) @ V.T
    return symmetrize(out)


def wy_syr2k_panel(C: jax.Array, V: jax.Array, T: jax.Array) -> jax.Array:
    """The Z panel of the SYR2K-form two-sided update (LAPACK DSYRDB).

    With X = C V and S = T^T (V^T X) T (symmetric because C is),

        Q^T C Q = C - Z V^T - V Z^T,   Z = X T - (1/2) V S,

    so the two-sided compact-WY update collapses to ONE rank-2w SYR2K
    against the (n, w) panels (V, Z) — the form both the fused single-host
    sweep (``core.sbr.reduce_to_band``, via ``kernels/syr2k`` on TPU) and
    the distributed sweep (``dist.sharded_la``) consume.
    """
    mm = matmul_acc
    X = mm(C, V)
    S = mm(mm(T.T, mm(V.T, X)), T)
    return mm(X, T) - 0.5 * mm(V, S)


def apply_wy_two_sided_syr2k(C: jax.Array, V: jax.Array,
                             T: jax.Array) -> jax.Array:
    """Q^T C Q for symmetric C via the SYR2K form (see `wy_syr2k_panel`)."""
    Z = wy_syr2k_panel(C, V, T)
    return symmetrize(C - matmul_acc(Z, V.T) - matmul_acc(V, Z.T))


def givens(a: jax.Array, b: jax.Array):
    """Return (c, s) with [c s; -s c]^T applied to rows mixing (a; b) -> (r; 0).

    Concretely: c*a + s*b = r, -s*a + c*b = 0. Safe when a = b = 0 (identity).
    """
    r = jnp.sqrt(a * a + b * b)
    safe = r > 0.0
    c = jnp.where(safe, a / jnp.where(safe, r, 1.0), 1.0)
    s = jnp.where(safe, b / jnp.where(safe, r, 1.0), 0.0)
    return c, s


def rotate_rows(M: jax.Array, p: jax.Array, q: jax.Array, c, s) -> jax.Array:
    """Rows p, q of M <- (c*row_p + s*row_q, -s*row_p + c*row_q). Traced p/q ok."""
    row_p = M[p, :]
    row_q = M[q, :]
    M = M.at[p, :].set(c * row_p + s * row_q)
    M = M.at[q, :].set(-s * row_p + c * row_q)
    return M


def rotate_cols(M: jax.Array, p: jax.Array, q: jax.Array, c, s) -> jax.Array:
    """Cols p, q of M <- (c*col_p + s*col_q, -s*col_p + c*col_q)."""
    col_p = M[:, p]
    col_q = M[:, q]
    M = M.at[:, p].set(c * col_p + s * col_q)
    M = M.at[:, q].set(-s * col_p + c * col_q)
    return M


def extract_tridiag(M: jax.Array):
    """Return (d, e): diagonal and first subdiagonal of M."""
    n = M.shape[0]
    d = jnp.diagonal(M)
    e = M[jnp.arange(1, n), jnp.arange(0, n - 1)]
    return d, e


def gershgorin_bounds(d: jax.Array, e: jax.Array):
    """Eigenvalue bounds for the symmetric tridiagonal (d, e)."""
    n = d.shape[0]
    ea = jnp.abs(e)
    left = jnp.concatenate([jnp.zeros((1,), d.dtype), ea])
    right = jnp.concatenate([ea, jnp.zeros((1,), d.dtype)])
    radius = left + right
    lo = jnp.min(d - radius)
    hi = jnp.max(d + radius)
    span = jnp.maximum(hi - lo, jnp.finfo(d.dtype).tiny)
    return lo - 1e-3 * span, hi + 1e-3 * span
