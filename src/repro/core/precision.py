"""Precision policy for the mixed-precision solver pipeline.

Three levels, threaded as ``precision=`` through ``gsyeig.solve``,
``core.batched.solve_batched`` and the distributed drivers:

  ``fp64``  — every stage in float64 (the default; identical to before)
  ``mixed`` — GEMM-heavy stages in float32
  ``fast``  — GEMM-heavy stages in bfloat16 with float32 accumulation

Only the GEMM-heavy stages demote (the TT1 panel sweep + SYR2K trailing
updates, the TT2 rotation wavefront, the TT4 back-transform, the KE/KI
fused matvec, and the TD reflector stages); Cholesky/standard form, the
tridiagonal eigensolve and all convergence/residual math stay float64,
and ``core.refinement`` restores fp64 accuracy of the returned
eigenpairs against the original pencil — the ELPA2-GPU / hybrid-solver
split (arXiv:2002.10991, arXiv:1207.1773).

The demotions each level is allowed to introduce are *declared* here
(``declared_downcasts``) so the static auditor can enforce them as a
policy instead of exempting the mixed pipeline from its dtype lint.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

PRECISIONS = ("fp64", "mixed", "fast")

_COMPUTE = {"fp64": jnp.float64, "mixed": jnp.float32, "fast": jnp.bfloat16}
# bf16 MXU paths accumulate in fp32; fp32 and fp64 accumulate in kind
_ACC = {"fp64": jnp.float64, "mixed": jnp.float32, "fast": jnp.float32}

# the exact convert_element_type edges each level may introduce — the
# static auditor's per-contract dtype policy (anything else is a leak)
_DECLARED = {
    "fp64": (),
    "mixed": ("float64->float32",),
    "fast": ("float64->bfloat16", "float64->float32"),
}


def validate_precision(precision: str) -> str:
    if precision not in PRECISIONS:
        raise ValueError(
            f"precision must be one of {PRECISIONS}, got {precision!r}")
    return precision


def compute_dtype(precision: str):
    """Storage/compute dtype of the demoted GEMM-heavy stages."""
    return _COMPUTE[validate_precision(precision)]


def acc_dtype(precision: str):
    """Accumulation dtype for reduced-precision contractions."""
    return _ACC[validate_precision(precision)]


def compute_eps(precision: str) -> float:
    return float(jnp.finfo(compute_dtype(precision)).eps)


def declared_downcasts(precision: str) -> Tuple[str, ...]:
    return _DECLARED[validate_precision(precision)]


def default_refine_steps(precision: str) -> int:
    """Fixed refinement step count for the traceable (batched) pipelines.

    Sized for the slowest workload in the benchmark matrix (the MD-like
    log spectrum at n=256, whose wanted-end relative gaps contract
    ~0.1-0.2x per sweep): enough sweeps to land BELOW the 1e-12 Table-3
    tolerances from fp32 (resp. bf16) pipeline output with an order of
    margin (BENCH_mixed measured worst 4e-14 / 2e-14 at these counts).
    Each sweep is O(n^2 (s + guard)) — cheap next to the O(n^3) pipeline
    it refines."""
    return {"fp64": 0, "mixed": 8, "fast": 16}[validate_precision(precision)]


def demote(x, precision: str):
    """Cast an array (or pytree of arrays) to the compute dtype."""
    dt = compute_dtype(precision)
    return jax.tree_util.tree_map(lambda a: a.astype(dt), x)


def promote(x, dtype=jnp.float64):
    """Cast an array (or pytree of arrays) back to the working dtype."""
    return jax.tree_util.tree_map(lambda a: a.astype(dtype), x)


def ensure_strong(x, dtype=jnp.float64):
    """Promote a weak-typed (Python-scalar-born) input to the working dtype.

    ``jnp.full((n, n), 0.5)`` and friends carry ``weak_type=True``, which
    the auditor reports (``weak_type_inputs``) because it lets the first
    downstream op silently decide the precision. Strongly-typed inputs
    pass through untouched, whatever their dtype.
    """
    x = jnp.asarray(x)
    if getattr(x, "weak_type", False) or not jnp.issubdtype(
            x.dtype, jnp.floating):
        x = jax.lax.convert_element_type(x, dtype)
    return x


def matmul_acc(a, b):
    """``a @ b`` with fp32 accumulation for sub-fp32 operands.

    The XLA-fallback counterpart of the Pallas kernels' bf16 MXU paths:
    ``preferred_element_type`` pins the accumulator, the result is cast
    back to the operand dtype.
    """
    if a.dtype == jnp.bfloat16 or b.dtype == jnp.bfloat16:
        out = jnp.matmul(a, b, preferred_element_type=jnp.float32)
        return out.astype(a.dtype)
    return a @ b
