"""KE/KI — implicitly-restarted Lanczos (ARPACK DSAUPD/DSEUPD analogue).

We implement the symmetric thick-restart formulation (Wu & Simon, TRLan),
which is mathematically equivalent to ARPACK's implicit QR restart for
symmetric operators but maps onto fixed-shape JAX buffers: a single
(n, m+1) basis buffer, a dense (m+1, m+1) projected matrix, and restart =
eigh of an m x m block. Full (two-pass) re-orthogonalization is used, the
O(nm)-per-iteration worst case the paper quotes.

Two drivers:
  * ``lanczos_solve``      — host-driven restart loop (data-dependent
    iteration counts, per-stage timing for the benchmark tables). The
    m-step extension runs as ONE jitted ``lax.fori_loop`` segment and the
    convergence test is a single-scalar ``jax.device_get``, so each restart
    costs O(1) device dispatches (the per-matvec host loop used to cost m,
    and the old ``bool(jnp.all(conv))`` synced a whole array). The module
    counts host->device dispatches (``dispatch_count``) so the regression
    test can pin this down.
  * ``lanczos_solve_jit``  — single jitted lax.while_loop (fixed max_restarts)
    used by the distributed/dry-run path.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .instrument import DispatchCounter
from .operators import ExplicitC, ImplicitC, Operator, apply_op, op_dim


class LanczosResult(NamedTuple):
    evals: jax.Array        # (s,)
    evecs: jax.Array        # (n, s) Ritz vectors (orthonormal)
    n_matvec: int           # operator applications
    n_restart: int
    converged: bool
    resid_bounds: jax.Array  # (s,) |beta_m * S[m-1, i]| at exit


# ---------------------------------------------------------------------------
# single Lanczos step + the jitted m-step segment
# ---------------------------------------------------------------------------

def _step_impl(matvec, V: jax.Array, T: jax.Array, j: jax.Array):
    """Extend the factorization by one column: V (n, m+1), T ((m+1, m+1)).

    ``matvec`` is any traceable y = C w closure — ``apply_op`` on the local
    Operator pytrees, or a ``dist_symv`` over a device mesh (see
    ``repro.dist.eigensolver``)."""
    n, mp1 = V.shape
    v_j = V[:, j]
    w = matvec(v_j)
    cols = jnp.arange(mp1)
    mask = (cols <= j).astype(V.dtype)
    # two-pass full re-orthogonalization (Kahan twice-is-enough)
    h1 = (V.T @ w) * mask
    w = w - V @ h1
    h2 = (V.T @ w) * mask
    w = w - V @ h2
    h = h1 + h2
    beta = jnp.linalg.norm(w)
    T = T.at[:, j].set(h)
    T = T.at[j, :].set(h)   # keep T numerically symmetric
    T = T.at[j + 1, j].set(beta)
    T = T.at[j, j + 1].set(beta)
    v_next = w / jnp.maximum(beta, jnp.finfo(V.dtype).tiny)
    V = V.at[:, j + 1].set(v_next)
    return V, T, beta


def _segment_impl(matvec, V: jax.Array, T: jax.Array, j0):
    """Steps j0..m-1 as ONE lax.fori_loop — one dispatch per restart.

    ``j0`` is traced (0 on the first sweep, ``keep`` after a thick
    restart), so a single compilation serves the whole solve."""
    m = V.shape[1] - 1

    def body(j, carry):
        def run(args):
            V, T, _ = args
            return _step_impl(matvec, V, T, j)

        return jax.lax.cond(j >= j0, run, lambda a: a, carry)

    return jax.lax.fori_loop(0, m, body,
                             (V, T, jnp.zeros((), V.dtype)))


@partial(jax.jit, static_argnames=("use_kernel",), donate_argnums=(1, 2))
def _lanczos_segment(op: Operator, V: jax.Array, T: jax.Array, j0,
                     use_kernel: bool = False):
    """Operator-pytree segment: op rides along as a traced argument so one
    compilation serves every problem of the same shape."""
    return _segment_impl(lambda v: apply_op(op, v, use_kernel=use_kernel),
                         V, T, j0)


def _make_segment(op, use_kernel: bool):
    """Segment driver for either op flavor.

    Operator pytrees reuse the module-level jitted segment (compile cache
    shared across solves); bare matvec callables — the distributed path —
    get a per-solve jit of the closure (the closure is stable across the
    restart loop, so each solve compiles the segment once)."""
    if isinstance(op, (ExplicitC, ImplicitC)):
        return lambda V, T, j0: _lanczos_segment(op, V, T, j0,
                                                 use_kernel=use_kernel)
    if callable(op):
        jit_seg = jax.jit(partial(_segment_impl, op), donate_argnums=(0, 1))
        return lambda V, T, j0: jit_seg(V, T, j0)
    raise TypeError(f"op must be an Operator or a matvec callable: {op!r}")


@partial(jax.jit, static_argnames=("s", "keep", "m", "which"))
def _restart_math(V: jax.Array, T: jax.Array, beta_m: jax.Array,
                  tol_eff: jax.Array, s: int, keep: int, m: int, which: str):
    """eigh of T_m, Ritz selection, residual bounds, thick-restart state AND
    the convergence verdict — everything per-restart in one jitted program,
    so the host only fetches one scalar (``all_conv``) to decide."""
    Tm = 0.5 * (T[:m, :m] + T[:m, :m].T)
    theta, S = jnp.linalg.eigh(Tm)  # ascending
    if which == "LA":  # want the largest: reorder descending so wanted = first
        theta = theta[::-1]
        S = S[:, ::-1]
    resid = jnp.abs(beta_m * S[m - 1, :])  # Ritz residual bounds, all m
    # ARPACK dsconv criterion: bound_i <= tol * max(eps^{2/3}, |theta_i|)
    eps = jnp.finfo(V.dtype).eps
    eps23 = eps ** (2.0 / 3.0)
    conv = resid[:s] <= tol_eff * jnp.maximum(jnp.abs(theta[:s]), eps23)
    all_conv = jnp.all(conv)
    # thick restart: keep leading `keep` Ritz pairs
    V_new_cols = V[:, :m] @ S[:, :keep]                     # (n, keep)
    v_res = V[:, m]                                          # residual vector
    V_restart = jnp.zeros_like(V)
    V_restart = V_restart.at[:, :keep].set(V_new_cols)
    V_restart = V_restart.at[:, keep].set(v_res)
    T_new = jnp.zeros_like(T)
    T_new = T_new.at[jnp.arange(keep), jnp.arange(keep)].set(theta[:keep])
    b = beta_m * S[m - 1, :keep]
    T_new = T_new.at[keep, :keep].set(b)
    T_new = T_new.at[:keep, keep].set(b)
    return theta, S, resid, V_restart, T_new, all_conv


# dispatch accounting (observability + the regression test's hook)
_dispatch = DispatchCounter()

#: host->device dispatches issued by ``lanczos_solve`` since the last
#: ``reset_dispatch_count()`` (each jitted-program invocation counts 1)
dispatch_count = _dispatch.count
reset_dispatch_count = _dispatch.reset


def default_subspace(s: int, n: int) -> int:
    """ARPACK-style default NCV: m in [2s, n), at least 20."""
    return int(min(max(2 * s + 1, 20), n - 1))


def restart_schedule(s: int, m: int) -> tuple:
    """(keep, per_restart) of the thick-restart drivers below: each restart
    keeps ``keep`` Ritz pairs and extends by ``per_restart = m - keep``
    matvecs. The single source of truth — the cost model's dispatch/restart
    estimate (``analysis.variant_model``) derives from it too."""
    keep = min(s + max((m - s) // 2, 1), m - 2)
    return keep, max(m - keep, 1)


def lanczos_solve(op, s: int, which: str = "SA", m: int | None = None,
                  tol: float = 0.0, max_restarts: int = 500,
                  key: jax.Array | None = None, use_kernel: bool = False,
                  v0: jax.Array | None = None,
                  callback=None, n: int | None = None) -> LanczosResult:
    """Host-driven thick-restart Lanczos for s extremal eigenpairs of `op`.

    `op` is an Operator pytree (ExplicitC/ImplicitC) or any matvec callable
    w -> C w — the distributed path passes a ``dist_symv`` closure. For
    callables, the problem dimension comes from `v0` (or the explicit `n`).
    which: 'SA' (smallest algebraic) or 'LA' (largest algebraic).
    tol=0.0 reproduces ARPACK's default (machine precision criterion).
    `callback(k_restart, V, T, j)` enables checkpoint hooks (see dist/).

    Per restart the host issues O(1) device dispatches: one jitted m-step
    segment, one ``_restart_math``, and a single-scalar ``jax.device_get``
    for the convergence verdict.
    """
    if isinstance(op, (ExplicitC, ImplicitC)):
        n = op_dim(op)
        dtype = (op.C if isinstance(op, ExplicitC) else op.A).dtype
    else:
        if n is None:
            if v0 is None:
                raise ValueError("callable op needs `v0` or `n`")
            n = v0.shape[0]
        dtype = v0.dtype if v0 is not None else jnp.float64
    if m is None:
        m = default_subspace(s, n)
    assert 2 * s < m + 1 <= n + 1, (s, m, n)
    keep, _ = restart_schedule(s, m)
    segment = _make_segment(op, use_kernel)
    eps = float(jnp.finfo(dtype).eps)
    tol_eff = tol if tol > 0.0 else eps

    if key is None:
        key = jax.random.PRNGKey(272727)
    V = jnp.zeros((n, m + 1), dtype)
    T = jnp.zeros((m + 1, m + 1), dtype)
    if v0 is None:
        v0 = jax.random.normal(key, (n,), dtype)
    V = V.at[:, 0].set(v0 / jnp.linalg.norm(v0))

    n_matvec = 0
    j0 = 0
    theta = S = resid = None
    for k_restart in range(max_restarts):
        V, T, beta = _dispatch(segment, V, T, jnp.asarray(j0))
        n_matvec += m - j0
        theta, S, resid, V_restart, T_new, all_conv = _dispatch(
            _restart_math, V, T, beta, jnp.asarray(tol_eff, dtype),
            s=s, keep=keep, m=m, which=which)
        if callback is not None:
            callback(k_restart, V, T, m)
        if bool(jax.device_get(all_conv)):
            evecs = V[:, :m] @ S[:, :s]
            evecs, _ = jnp.linalg.qr(evecs)
            return LanczosResult(theta[:s], evecs, n_matvec, k_restart + 1,
                                 True, resid[:s])
        # thick restart
        V, T = V_restart, T_new
        j0 = keep

    evecs = V[:, :m] @ S[:, :s]
    evecs, _ = jnp.linalg.qr(evecs)
    return LanczosResult(theta[:s], evecs, n_matvec, max_restarts, False,
                         resid[:s])


# ---------------------------------------------------------------------------
# fully jitted driver (fixed trip counts) for the distributed/dry-run path
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("s", "m", "which", "max_restarts",
                                   "use_kernel"))
def lanczos_solve_jit(op: Operator, v0: jax.Array, s: int, m: int,
                      which: str = "SA", max_restarts: int = 50,
                      use_kernel: bool = False):
    """lax.while_loop thick-restart Lanczos; lowers to a single XLA program.

    Returns (evals (s,), evecs (n, s), n_restarts_used, converged).
    """
    n = v0.shape[0]
    dtype = v0.dtype
    eps = jnp.finfo(dtype).eps
    keep, _ = restart_schedule(s, m)

    V0 = jnp.zeros((n, m + 1), dtype).at[:, 0].set(v0 / jnp.linalg.norm(v0))
    T0 = jnp.zeros((m + 1, m + 1), dtype)
    matvec = lambda v: apply_op(op, v, use_kernel=use_kernel)  # noqa: E731

    def cond(state):
        k, _, _, _, converged, _, _ = state
        return jnp.logical_and(k < max_restarts, jnp.logical_not(converged))

    def body(state):
        k, V, T, j0_val, _, _, _ = state
        V, T, beta = _segment_impl(matvec, V, T, j0_val)
        theta, S, resid, V_restart, T_new, conv = _restart_math(
            V, T, beta, eps, s, keep, m, which
        )
        evecs = V[:, :m] @ S[:, :s]
        return (k + 1, V_restart, T_new, jnp.asarray(keep), conv, theta[:s],
                evecs)

    state0 = (jnp.asarray(0), V0, T0, jnp.asarray(0), jnp.asarray(False),
              jnp.zeros((s,), dtype), jnp.zeros((n, s), dtype))
    k, V, T, j0_val, converged, evals, evecs = jax.lax.while_loop(
        cond, body, state0
    )
    q, _ = jnp.linalg.qr(evecs)
    return evals, q, k, converged
