"""KE/KI — implicitly-restarted BLOCK Lanczos (ARPACK DSAUPD/DSEUPD analogue).

We implement the symmetric thick-restart formulation (Wu & Simon, TRLan)
generalized to a *block / s-step* method: the factorization advances by a
whole (n, p) block per step — ONE fused multi-RHS matvec (a GEMM /
``kernels/symv.symm_block`` instead of p SYMVs), two-pass block
re-orthogonalization, and a QR of the residual block. For ``p == 1`` this
reduces exactly to the classical single-vector method (same shapes, same
restart schedule). The block structure is what makes the distributed KE
pipeline communication-avoiding: per block step the mesh pays ONE psum
(the matvec coupling) plus ONE all_gather (which doubles as the broadcast
because every shard runs the O(n m p) orthogonalization math redundantly —
the same trick ``sharded_la.band_sweep_program`` uses for panel QR),
instead of one collective round trip per matvec (see
``repro.dist.eigensolver.ke_restart_program``).

State maps onto fixed-shape JAX buffers: a single (n, m+p) basis buffer, a
dense (m+p, m+p) projected matrix, and restart = eigh of an m x m block.
Full (two-pass) re-orthogonalization is used, the O(nm)-per-iteration
worst case the paper quotes.

Two drivers:
  * ``lanczos_solve``      — host-driven restart loop (data-dependent
    iteration counts, per-stage timing for the benchmark tables). The
    whole-segment extension runs as ONE jitted program and the
    convergence test is a single-scalar ``jax.device_get``, so each
    restart costs O(1) device dispatches. The module counts host->device
    dispatches (``dispatch_count``) so the regression test can pin this.
  * ``lanczos_solve_jit``  — single jitted lax.while_loop (fixed
    max_restarts) used by the batched/dry-run path.

Both drivers support Chebyshev polynomial filtering of the starting block
(``filter_degree > 0``): spectral bounds come from a cheap k-step probe
(``core.filtering``) and the filter damps the unwanted end so clustered
DFT-like spectra converge inside the restart budget.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .instrument import DispatchCounter
from .operators import ExplicitC, ImplicitC, Operator, apply_op, op_dim


class LanczosResult(NamedTuple):
    evals: jax.Array        # (s,)
    evecs: jax.Array        # (n, s) Ritz vectors (orthonormal)
    n_matvec: int           # operator applications
    n_restart: int
    converged: bool
    resid_bounds: jax.Array  # (s,) ||B_q S[m-p:m, i]|| at exit
    healthy: bool = True     # fused finite-sentinel verdict at exit


# ---------------------------------------------------------------------------
# one block step + the jitted whole-segment program
# ---------------------------------------------------------------------------

def _qr_posdiag(W: jax.Array):
    """Reduced QR with the R diagonal forced nonnegative (deterministic;
    for p == 1 this is exactly the classical v = w/||w||, beta = ||w||)."""
    Q, R = jnp.linalg.qr(W)
    sgn = jnp.sign(jnp.diagonal(R))
    sgn = jnp.where(sgn == 0, jnp.ones_like(sgn), sgn)
    return Q * sgn[None, :], R * sgn[:, None]


def _block_step_impl(matvec, V: jax.Array, T: jax.Array, j: jax.Array,
                     p: int):
    """Extend the factorization by one (n, p) block: columns
    [j*p, (j+1)*p) of V (n, m+p), T ((m+p, m+p)).

    ``matvec`` is any traceable Y = C X closure taking an (n, p) block —
    ``apply_op`` on the local Operator pytrees (multi-RHS), or the fused
    psum+all_gather matvec inside a ``shard_map`` region (see
    ``repro.dist.eigensolver``). One call = p operator applications."""
    n, mpp = V.shape
    c0 = j * p
    Vj = jax.lax.dynamic_slice(V, (jnp.zeros((), c0.dtype), c0), (n, p))
    W = matvec(Vj)
    cols = jnp.arange(mpp)
    mask = (cols < c0 + p).astype(V.dtype)[:, None]
    # two-pass full block re-orthogonalization (Kahan twice-is-enough)
    H1 = (V.T @ W) * mask
    W = W - V @ H1
    H2 = (V.T @ W) * mask
    W = W - V @ H2
    H = H1 + H2                              # (m+p, p) projection coeffs
    Q, B = _qr_posdiag(W)                    # residual block QR
    # block column of T: H on rows < (j+1)p, the new coupling B below
    Hb = H + jax.lax.dynamic_update_slice(
        jnp.zeros_like(H), B, (c0 + p, jnp.zeros((), c0.dtype)))
    T = jax.lax.dynamic_update_slice(T, Hb, (jnp.zeros((), c0.dtype), c0))
    T = jax.lax.dynamic_update_slice(T, Hb.T, (c0, jnp.zeros((), c0.dtype)))
    V = jax.lax.dynamic_update_slice(V, Q, (jnp.zeros((), c0.dtype), c0 + p))
    return V, T, B


def _segment_impl(matvec, V: jax.Array, T: jax.Array, j0, p: int = 1):
    """Block steps j0..q-1 as ONE lax.fori_loop — one dispatch per restart.

    ``j0`` is a traced BLOCK index (0 on the first sweep, ``keep // p``
    after a thick restart), so a single compilation serves the whole
    solve. Returns ``(V, T, B_q)`` with B_q the last (p, p) coupling."""
    n, mpp = V.shape
    q = (mpp - p) // p

    def body(j, carry):
        def run(args):
            V, T, _ = args
            return _block_step_impl(matvec, V, T, j, p)

        return jax.lax.cond(j >= j0, run, lambda a: a, carry)

    return jax.lax.fori_loop(0, q, body,
                             (V, T, jnp.zeros((p, p), V.dtype)))


@partial(jax.jit, static_argnames=("use_kernel", "p", "compute_dtype"),
         donate_argnums=(1, 2))
def _lanczos_segment(op: Operator, V: jax.Array, T: jax.Array, j0,
                     use_kernel: bool = False, p: int = 1,
                     compute_dtype: str | None = None):
    """Operator-pytree segment: op rides along as a traced argument so one
    compilation serves every problem of the same shape. ``compute_dtype``
    (a dtype NAME, static) demotes ONLY the operator application — the
    orthogonalization stays in V's dtype — without leaving this shared
    jit cache (a per-solve jit of a demoting closure would recompile the
    segment on every ``lanczos_solve`` call)."""
    if compute_dtype is not None:
        cdtype = jnp.dtype(compute_dtype)
        op_c = jax.tree_util.tree_map(lambda a: a.astype(cdtype), op)
        mv = lambda X: apply_op(op_c, X.astype(cdtype),  # noqa: E731
                                use_kernel=use_kernel).astype(V.dtype)
    else:
        mv = lambda X: apply_op(op, X, use_kernel=use_kernel)  # noqa: E731
    return _segment_impl(mv, V, T, j0, p)


def _make_segment(op, use_kernel: bool, p: int,
                  compute_dtype: str | None = None):
    """Segment driver for either op flavor.

    Operator pytrees reuse the module-level jitted segment (compile cache
    shared across solves), including the demoted-matvec case via the
    static ``compute_dtype`` name; bare matvec callables — e.g. a
    distributed closure — get a per-solve jit (the closure is stable
    across the restart loop, so each solve compiles the segment once)."""
    if isinstance(op, (ExplicitC, ImplicitC)):
        return lambda V, T, j0: _lanczos_segment(
            op, V, T, j0, use_kernel=use_kernel, p=p,
            compute_dtype=compute_dtype)
    if callable(op):
        jit_seg = jax.jit(partial(_segment_impl, op, p=p),
                          donate_argnums=(0, 1))
        return lambda V, T, j0: jit_seg(V, T, j0)
    raise TypeError(f"op must be an Operator or a matvec callable: {op!r}")


@partial(jax.jit, static_argnames=("s", "keep", "m", "p", "which"))
def _restart_math(V: jax.Array, T: jax.Array, B_q: jax.Array,
                  tol_eff: jax.Array, s: int, keep: int, m: int, p: int,
                  which: str, resid_floor_rel: float = 0.0):
    """eigh of T_m, Ritz selection, residual bounds, thick-restart state AND
    the convergence verdict — everything per-restart in one jitted program,
    so the host only fetches one scalar (``all_conv``) to decide.

    Residual bound of Ritz pair i is ``||B_q S[m-p:m, i]||`` (the block
    generalization of |beta_m S[m-1, i]|); the thick restart keeps the
    leading ``keep`` Ritz vectors (keep is a multiple of p) plus the
    (n, p) residual block, with the (p, keep) coupling
    ``B_q S[m-p:m, :keep]`` in the arrowhead of the new T.

    ``resid_floor_rel`` is the mixed-precision escape hatch: a demoted
    matvec floors the attainable residual at ~eps_compute * ||C|| (not
    eps * |theta_i|), so the criterion also accepts bounds under
    ``resid_floor_rel * max|theta|`` — fp64 refinement recovers the rest."""
    Tm = 0.5 * (T[:m, :m] + T[:m, :m].T)
    theta, S = jnp.linalg.eigh(Tm)  # ascending
    if which == "LA":  # want the largest: reorder descending so wanted = first
        theta = theta[::-1]
        S = S[:, ::-1]
    b = B_q @ S[m - p:m, :]                 # (p, m) residual couplings
    resid = jnp.linalg.norm(b, axis=0)      # Ritz residual bounds, all m
    # ARPACK dsconv criterion: bound_i <= tol * max(eps^{2/3}, |theta_i|)
    eps = jnp.finfo(V.dtype).eps
    eps23 = eps ** (2.0 / 3.0)
    thresh = tol_eff * jnp.maximum(jnp.abs(theta[:s]), eps23)
    thresh = jnp.maximum(thresh, resid_floor_rel * jnp.max(jnp.abs(theta)))
    conv = resid[:s] <= thresh
    all_conv = jnp.all(conv)
    # fused health sentinel (zero extra dispatches — it rides out with
    # the verdict the host fetches anyway): a non-finite basis or T
    # propagates into theta/resid, so this catches NaN/inf anywhere in
    # the restart's state
    healthy = jnp.isfinite(theta).all() & jnp.isfinite(resid).all()
    # thick restart: keep leading `keep` Ritz pairs + the residual block
    V_new_cols = V[:, :m] @ S[:, :keep]                     # (n, keep)
    V_res = V[:, m:m + p]                                   # residual block
    V_restart = jnp.zeros_like(V)
    V_restart = V_restart.at[:, :keep].set(V_new_cols)
    V_restart = V_restart.at[:, keep:keep + p].set(V_res)
    T_new = jnp.zeros_like(T)
    T_new = T_new.at[jnp.arange(keep), jnp.arange(keep)].set(theta[:keep])
    T_new = T_new.at[keep:keep + p, :keep].set(b[:, :keep])
    T_new = T_new.at[:keep, keep:keep + p].set(b[:, :keep].T)
    return theta, S, resid, V_restart, T_new, all_conv, healthy


# dispatch accounting (observability + the regression test's hook)
_dispatch = DispatchCounter()

#: host->device dispatches issued by ``lanczos_solve`` since the last
#: ``reset_dispatch_count()`` (each jitted-program invocation counts 1)
dispatch_count = _dispatch.count
reset_dispatch_count = _dispatch.reset


def default_subspace(s: int, n: int, p: int = 1) -> int:
    """ARPACK-style default NCV: m in [2s, n), at least 20 — rounded up to
    a multiple of the block size p (and down so the (n, m+p) basis fits).

    For blocks the subspace additionally scales with p: the Krylov
    polynomial degree reachable per sweep is m/p, so keeping m fixed while
    raising p would trade convergence for communication 1:1. m ~ 10p keeps
    ~10 block steps per sweep (the single-vector default's depth at p=1)."""
    m = int(min(max(2 * s + 1, 20), n - 1))
    if p > 1:
        m = max(m, min(10 * p, n // 2))
        m = -(-m // p) * p                  # round up to a block multiple
        m = min(m, ((n - p) // p) * p)      # basis must fit: m + p <= n
    return m


def restart_schedule(s: int, m: int, p: int = 1) -> tuple:
    """(keep, per_restart) of the thick-restart drivers below: each restart
    keeps ``keep`` Ritz pairs (a multiple of the block size p, so restarts
    stay block-aligned) and extends by ``per_restart = m - keep`` matvecs
    (``per_restart // p`` block steps). The single source of truth — the
    cost model's dispatch/collective/restart estimates
    (``analysis.variant_model``) derive from it too."""
    keep = min(s + max((m - s) // 2, 1), m - 2)
    if p > 1:
        keep = min(-(-keep // p) * p, m - p)
    return keep, max(m - keep, 1)


def _seed_block(v0, n: int, p: int, key, dtype):
    """(n, p) starting block: v0 (or a random vector) in column 0, random
    fill for the rest; orthonormalized by the caller (QR / filter+QR)."""
    if v0 is None:
        return jax.random.normal(key, (n, p), dtype)
    v0 = jnp.asarray(v0, dtype)
    if v0.ndim == 1:
        if p == 1:
            return v0[:, None]
        rest = jax.random.normal(jax.random.fold_in(key, 1), (n, p - 1),
                                 dtype)
        return jnp.concatenate([v0[:, None], rest], axis=1)
    assert v0.shape == (n, p), (v0.shape, n, p)
    return v0


def lanczos_solve(op, s: int, which: str = "SA", m: int | None = None,
                  tol: float = 0.0, max_restarts: int = 500,
                  key: jax.Array | None = None, use_kernel: bool = False,
                  v0: jax.Array | None = None,
                  callback=None, n: int | None = None, p: int = 1,
                  filter_degree: int = 0,
                  compute_dtype=None) -> LanczosResult:
    """Host-driven thick-restart block Lanczos for s extremal eigenpairs.

    `op` is an Operator pytree (ExplicitC/ImplicitC) or any traceable
    block-matvec callable X -> C X on (n, p) blocks (for ``p == 1`` a
    plain ``lambda v: C @ v`` works on the (n, 1) column). For callables,
    the problem dimension comes from `v0` (or the explicit `n`).
    which: 'SA' (smallest algebraic) or 'LA' (largest algebraic).
    tol=0.0 reproduces ARPACK's default (machine precision criterion).
    ``p`` is the block / s-step size: each segment step advances p basis
    vectors with ONE fused multi-RHS matvec. ``filter_degree > 0``
    Chebyshev-filters the starting block (degree-d polynomial damping the
    unwanted end; bounds from a k-step probe — see ``core.filtering``),
    which is what makes clustered spectra converge inside the budget.
    `callback(k_restart, V, T, m)` enables checkpoint hooks (see dist/).

    ``compute_dtype`` (a dtype, or None = off) demotes ONLY the operator
    application — the basis, T and all restart/convergence math stay in
    the working dtype, and the convergence criterion is floored at the
    demoted matvec's attainable residual (``core.refinement`` recovers
    full accuracy afterwards).

    Per restart the host issues O(1) device dispatches: one jitted
    whole-segment program, one ``_restart_math``, and a single-scalar
    ``jax.device_get`` for the convergence verdict.
    """
    if isinstance(op, (ExplicitC, ImplicitC)):
        n = op_dim(op)
        dtype = (op.C if isinstance(op, ExplicitC) else op.A).dtype
        matvec = lambda X: apply_op(op, X, use_kernel=use_kernel)  # noqa: E731
    else:
        if n is None:
            if v0 is None:
                raise ValueError("callable op needs `v0` or `n`")
            n = v0.shape[0]
        dtype = v0.dtype if v0 is not None else jnp.float64
        matvec = op
    resid_floor_rel = 0.0
    seg_cdtype = None
    cdtype = None if compute_dtype is None else jnp.dtype(compute_dtype)
    if cdtype is not None and cdtype != jnp.dtype(dtype):
        if isinstance(op, (ExplicitC, ImplicitC)):
            # op stays a pytree: the module-level jitted segment demotes
            # internally (static compute_dtype name), so the compile
            # cache keeps being shared across solves. matvec (used by the
            # filter / bound probes) demotes the same way.
            op_c = jax.tree_util.tree_map(lambda a: a.astype(cdtype), op)
            mv0 = lambda X: apply_op(op_c, X.astype(cdtype),  # noqa: E731
                                     use_kernel=use_kernel)
            seg_cdtype = jnp.dtype(cdtype).name
        else:
            base = matvec
            mv0 = lambda X: base(X.astype(cdtype))  # noqa: E731
        matvec = lambda X: mv0(X).astype(dtype)  # noqa: E731
        if seg_cdtype is None:
            op = matvec      # callable op: per-solve jit as before
        resid_floor_rel = 8.0 * float(jnp.finfo(cdtype).eps)
    if m is None:
        m = default_subspace(s, n, p)
    assert m % p == 0 and m + p <= n + (1 if p == 1 else 0), (m, p, n)
    assert 2 * s < m + 1, (s, m)
    keep, _ = restart_schedule(s, m, p)
    segment = _make_segment(op, use_kernel, p, compute_dtype=seg_cdtype)
    eps = float(jnp.finfo(dtype).eps)
    tol_eff = tol if tol > 0.0 else eps

    if key is None:
        key = jax.random.PRNGKey(272727)
    X0 = _seed_block(v0, n, p, key, dtype)
    n_matvec = 0
    if filter_degree > 0:
        from .filtering import (chebyshev_filter_jit, estimate_bounds_jit,
                                filter_interval, probe_steps)
        kb = probe_steps(s, n)
        theta_p, beta_k = _dispatch(estimate_bounds_jit, matvec,
                                    jax.random.normal(
                                        jax.random.fold_in(key, 2), (n,),
                                        dtype), kb)
        a, b, a0 = filter_interval(theta_p, beta_k, s, which)
        X0 = _dispatch(chebyshev_filter_jit, matvec, X0, filter_degree,
                       a, b, a0)
        n_matvec += kb + filter_degree * p
    V = jnp.zeros((n, m + p), dtype)
    T = jnp.zeros((m + p, m + p), dtype)
    Q0, _ = _qr_posdiag(X0)
    V = V.at[:, :p].set(Q0)

    j0 = 0
    theta = S = resid = None
    for k_restart in range(max_restarts):
        V, T, B_q = _dispatch(segment, V, T, jnp.asarray(j0))
        n_matvec += m - j0 * p
        theta, S, resid, V_restart, T_new, all_conv, healthy = _dispatch(
            _restart_math, V, T, B_q, jnp.asarray(tol_eff, dtype),
            s=s, keep=keep, m=m, p=p, which=which,
            resid_floor_rel=resid_floor_rel)
        if callback is not None:
            callback(k_restart, V, T, m)
        # one fetch for both fused verdicts (same dispatch budget as the
        # single-scalar convergence test this replaces)
        conv_ok, health_ok = (bool(x) for x in
                              jax.device_get((all_conv, healthy)))
        if not health_ok:
            # the restart state is poisoned: stop burning restarts on
            # NaNs (a NaN residual never compares <= thresh) and report
            evecs = V[:, :m] @ S[:, :s]
            return LanczosResult(theta[:s], evecs, n_matvec, k_restart + 1,
                                 False, resid[:s], healthy=False)
        if conv_ok:
            evecs = V[:, :m] @ S[:, :s]
            evecs, _ = jnp.linalg.qr(evecs)
            return LanczosResult(theta[:s], evecs, n_matvec, k_restart + 1,
                                 True, resid[:s])
        # thick restart
        V, T = V_restart, T_new
        j0 = keep // p

    evecs = V[:, :m] @ S[:, :s]
    evecs, _ = jnp.linalg.qr(evecs)
    return LanczosResult(theta[:s], evecs, n_matvec, max_restarts, False,
                         resid[:s])


# ---------------------------------------------------------------------------
# fully jitted driver (fixed trip counts) for the batched/dry-run path
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("s", "m", "which", "max_restarts",
                                   "use_kernel", "p", "filter_degree",
                                   "compute_dtype"))
def lanczos_solve_jit(op: Operator, v0: jax.Array, s: int, m: int,
                      which: str = "SA", max_restarts: int = 50,
                      use_kernel: bool = False, p: int = 1,
                      filter_degree: int = 0,
                      compute_dtype: str | None = None):
    """lax.while_loop thick-restart block Lanczos; ONE XLA program.

    ``v0`` is (n,) for p == 1 or an (n, p) starting block. Returns
    (evals (s,), evecs (n, s), n_restarts_used, converged, healthy) —
    ``healthy`` is the fused finite-sentinel verdict, and an unhealthy
    state also terminates the while loop (a NaN residual never passes
    the convergence compare, so without it the loop would spin to
    max_restarts on a poisoned basis). Shares the
    block segment/restart core with ``lanczos_solve`` — the two drivers
    cannot drift. ``compute_dtype`` (a dtype NAME, static) demotes the
    operator application only, exactly as in ``lanczos_solve``.
    """
    n = v0.shape[0]
    dtype = v0.dtype
    eps = jnp.finfo(dtype).eps
    assert m % p == 0, (m, p)
    keep, _ = restart_schedule(s, m, p)
    resid_floor_rel = 0.0
    if compute_dtype is not None and jnp.dtype(compute_dtype) != dtype:
        cdtype = jnp.dtype(compute_dtype)
        op_c = jax.tree_util.tree_map(lambda a: a.astype(cdtype), op)
        matvec = lambda X: apply_op(  # noqa: E731
            op_c, X.astype(cdtype), use_kernel=use_kernel).astype(dtype)
        resid_floor_rel = 8.0 * float(jnp.finfo(cdtype).eps)
    else:
        matvec = lambda X: apply_op(op, X, use_kernel=use_kernel)  # noqa: E731

    X0 = v0[:, None] if v0.ndim == 1 else v0
    assert X0.shape == (n, p), (X0.shape, p)
    if filter_degree > 0:
        from .filtering import (chebyshev_filter, estimate_bounds,
                                filter_interval, probe_steps)
        kb = probe_steps(s, n)
        theta_p, beta_k = estimate_bounds(matvec, X0[:, 0], kb)
        a, b, a0 = filter_interval(theta_p, beta_k, s, which)
        X0 = chebyshev_filter(matvec, X0, filter_degree, a, b, a0)
    Q0, _ = _qr_posdiag(X0)
    V0 = jnp.zeros((n, m + p), dtype).at[:, :p].set(Q0)
    T0 = jnp.zeros((m + p, m + p), dtype)

    def cond(state):
        k, _, _, _, converged, healthy, _, _ = state
        return (k < max_restarts) & jnp.logical_not(converged) & healthy

    def body(state):
        k, V, T, j0_val, _, _, _, _ = state
        V, T, B_q = _segment_impl(matvec, V, T, j0_val, p)
        theta, S, resid, V_restart, T_new, conv, healthy = _restart_math(
            V, T, B_q, eps, s, keep, m, p, which,
            resid_floor_rel=resid_floor_rel
        )
        evecs = V[:, :m] @ S[:, :s]
        return (k + 1, V_restart, T_new, jnp.asarray(keep // p), conv,
                healthy, theta[:s], evecs)

    state0 = (jnp.asarray(0), V0, T0, jnp.asarray(0), jnp.asarray(False),
              jnp.asarray(True), jnp.zeros((s,), dtype),
              jnp.zeros((n, s), dtype))
    k, V, T, j0_val, converged, healthy, evals, evecs = jax.lax.while_loop(
        cond, body, state0
    )
    q, _ = jnp.linalg.qr(evecs)
    return evals, q, k, converged, healthy
