"""TD2/TT3 — symmetric tridiagonal eigensolver for s << n wanted pairs.

The paper uses MR^3 (DSTEMR); its defining property for the study is that the
tridiagonal stage costs O(ns) and is negligible. MR^3's recursive
representation tree is sequential and branch-divergent — a poor fit for
TPU/SIMD — so we realize the same O(ns) contract with the classic
embarrassingly-parallel pair (see DESIGN.md §3.3):

  * eigenvalues:  Sturm-count bisection, vectorized across all wanted indices
  * eigenvectors: shifted inverse iteration with pivoted tridiagonal LU
                  (DGTTRF-style), vmapped across eigenvalues, with
                  cluster-wise reorthogonalization (DSTEIN-style).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .linalg_utils import gershgorin_bounds


def _pivmin(d: jax.Array, e: jax.Array) -> jax.Array:
    scale = jnp.maximum(jnp.max(jnp.abs(d)), jnp.max(jnp.abs(e)) if e.size else 0.0)
    scale = jnp.maximum(scale, 1.0)
    return jnp.finfo(d.dtype).tiny / jnp.finfo(d.dtype).eps * scale


def sturm_count(d: jax.Array, e: jax.Array, x: jax.Array,
                unroll: int = 1) -> jax.Array:
    """Number of eigenvalues of tridiag(d, e) strictly below x (scalar x).

    ``unroll`` unrolls the sequential Sturm recurrence ``unroll`` rows per
    scan step — pure loop unrolling, so the result is bitwise identical for
    every value; ``kernels/tridiag_eig`` uses it to amortize the per-step
    loop overhead that dominates this stage off-TPU.
    """
    pivmin = _pivmin(d, e)
    e2 = jnp.concatenate([jnp.zeros((1,), d.dtype), e * e])

    def body(carry, inp):
        q_prev, count = carry
        di, ei2 = inp
        q_safe = jnp.where(jnp.abs(q_prev) < pivmin,
                           jnp.where(q_prev < 0, -pivmin, pivmin), q_prev)
        q = (di - x) - ei2 / q_safe
        count = count + (q < 0).astype(jnp.int32)
        return (q, count), None

    init = (jnp.ones((), d.dtype), jnp.zeros((), jnp.int32))
    (q, count), _ = jax.lax.scan(body, init, (d, e2), unroll=unroll)
    # first step used q_prev=1 with e2=0 so it's exact
    return count


def sturm_counts(d: jax.Array, e: jax.Array, xs: jax.Array,
                 unroll: int = 1) -> jax.Array:
    """``sturm_count`` vectorized over a batch of shift points."""
    return jax.vmap(lambda x: sturm_count(d, e, x, unroll=unroll))(xs)


@partial(jax.jit, static_argnames=("max_iters", "unroll"))
def bisect_eigenvalues(d: jax.Array, e: jax.Array, ks: jax.Array,
                       max_iters: int = 80, unroll: int = 1) -> jax.Array:
    """k-th smallest eigenvalues, 0-indexed by the int array ``ks``.

    ``ks`` may be in any order — each lane bisects its own index
    independently and ``lam[i]`` answers ``ks[i]`` as given. (Downstream
    ``inverse_iteration`` is NOT order-agnostic: its gap-based clustering
    needs sorted shifts, which is why ``eigh_tridiag_selected``
    sorts-and-restores.) ``unroll`` is bitwise-neutral loop unrolling of
    the Sturm scans (see ``sturm_count``).
    """
    lo0, hi0 = gershgorin_bounds(d, e)
    lo = jnp.full(ks.shape, lo0, d.dtype)
    hi = jnp.full(ks.shape, hi0, d.dtype)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        cnt = sturm_counts(d, e, mid, unroll=unroll)
        go_right = cnt <= ks  # lambda_k >= mid
        lo = jnp.where(go_right, mid, lo)
        hi = jnp.where(go_right, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, max_iters, body, (lo, hi))
    return 0.5 * (lo + hi)


def _gttrf_gtts2(d: jax.Array, e: jax.Array, lam: jax.Array, b: jax.Array):
    """Solve (T - lam I) x = b with partial pivoting (DGTTRF + DGTTS2).

    Sequential lax.scan factorization; pivots clamped away from zero so that
    inverse iteration at a converged eigenvalue stays finite (DSTEIN-style).
    """
    n = d.shape[0]
    dtype = d.dtype
    pivmin = _pivmin(d, e)
    diag = d - lam
    if n == 1:
        dsafe = jnp.where(jnp.abs(diag[0]) < pivmin, pivmin, diag[0])
        return b / dsafe

    sub = e            # (n-1,) subdiagonal entries (row i+1, col i)
    sup = e            # (n-1,) superdiagonal
    sup_next = jnp.concatenate([sup[1:], jnp.zeros((1,), dtype)])  # du(i+1), 0 last

    def fact_body(carry, inp):
        dcur, ducur = carry
        dl_i, dnext, dunext = inp
        no_swap = jnp.abs(dcur) >= jnp.abs(dl_i)
        # --- no-swap branch
        dsafe = jnp.where(jnp.abs(dcur) < pivmin,
                          jnp.where(dcur < 0, -pivmin, pivmin), dcur)
        fact_ns = dl_i / dsafe
        # --- swap branch
        dlsafe = jnp.where(jnp.abs(dl_i) < pivmin,
                           jnp.where(dl_i < 0, -pivmin, pivmin), dl_i)
        fact_sw = dcur / dlsafe

        D_i = jnp.where(no_swap, dcur, dl_i)
        DU_i = jnp.where(no_swap, ducur, dnext)
        DU2_i = jnp.where(no_swap, 0.0, dunext)
        L_i = jnp.where(no_swap, fact_ns, fact_sw)
        dcur_new = jnp.where(no_swap, dnext - fact_ns * ducur,
                             ducur - fact_sw * dnext)
        ducur_new = jnp.where(no_swap, dunext, -fact_sw * dunext)
        return (dcur_new, ducur_new), (D_i, DU_i, DU2_i, L_i, no_swap)

    (d_last, _), (D, DU, DU2, L, no_swap) = jax.lax.scan(
        fact_body, (diag[0], sup[0]), (sub, diag[1:], sup_next)
    )
    D = jnp.concatenate([D, d_last[None]])  # (n,)

    # forward substitution with the recorded pivoting pattern
    def fwd_body(bcur, inp):
        b_next, L_i, ns = inp
        b_i = jnp.where(ns, bcur, b_next)
        bcur_new = jnp.where(ns, b_next - L_i * bcur, bcur - L_i * b_next)
        return bcur_new, b_i

    b_last, b_out = jax.lax.scan(fwd_body, b[0], (b[1:], L, no_swap))
    y = jnp.concatenate([b_out, b_last[None]])  # (n,)

    # back substitution: x_i = (y_i - DU_i x_{i+1} - DU2_i x_{i+2}) / D_i
    Dsafe = jnp.where(jnp.abs(D) < pivmin,
                      jnp.where(D < 0, -pivmin, pivmin), D)
    DUp = jnp.concatenate([DU, jnp.zeros((1,), dtype)])
    DU2p = jnp.concatenate([DU2, jnp.zeros((1,), dtype)])

    def back_body(carry, inp):
        x1, x2 = carry  # x_{i+1}, x_{i+2}
        y_i, du_i, du2_i, ds_i = inp
        x_i = (y_i - du_i * x1 - du2_i * x2) / ds_i
        return (x_i, x1), x_i

    inps = (y[::-1], DUp[::-1], DU2p[::-1], Dsafe[::-1])
    _, xs = jax.lax.scan(back_body, (jnp.zeros((), dtype), jnp.zeros((), dtype)), inps)
    return xs[::-1]


def _cluster_ids(lam: jax.Array, scale: jax.Array) -> jax.Array:
    """DSTEIN-style clustering: eigenvalues closer than 1e-3*scale share a group."""
    gaps = jnp.diff(lam)
    new_cluster = (gaps > 1e-3 * scale).astype(jnp.int32)
    return jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(new_cluster)])


def _mgs_clustered(X: jax.Array, cid: jax.Array) -> jax.Array:
    """Orthogonalize columns of X within clusters (masked MGS), renormalize."""
    s = X.shape[1]

    def body(i, X):
        xi = X[:, i]
        mask = (jnp.arange(s) < i) & (cid == cid[i])
        coeff = (X.T @ xi) * mask  # (s,)
        xi = xi - X @ coeff
        xi = xi / jnp.maximum(jnp.linalg.norm(xi), jnp.finfo(X.dtype).tiny)
        return X.at[:, i].set(xi)

    return jax.lax.fori_loop(1, s, body, X)


@partial(jax.jit, static_argnames=("iters",))
def inverse_iteration(d: jax.Array, e: jax.Array, lam: jax.Array,
                      key: jax.Array, iters: int = 3) -> jax.Array:
    """Eigenvectors for the (sorted) eigenvalues `lam`; returns Z (n, s)."""
    n = d.shape[0]
    s = lam.shape[0]
    scale = jnp.maximum(jnp.max(jnp.abs(d)), jnp.max(jnp.abs(e)) if e.size else 0.0)
    cid = _cluster_ids(lam, scale)
    X = jax.random.normal(key, (n, s), d.dtype)
    X = X / jnp.linalg.norm(X, axis=0, keepdims=True)

    solve_batch = jax.vmap(_gttrf_gtts2, in_axes=(None, None, 0, 1), out_axes=1)

    def one_round(_, X):
        X = solve_batch(d, e, lam, X)
        X = X / jnp.maximum(jnp.linalg.norm(X, axis=0, keepdims=True),
                            jnp.finfo(d.dtype).tiny)
        X = _mgs_clustered(X, cid)
        return X

    X = jax.lax.fori_loop(0, iters, one_round, X)
    return X


class TridiagEigResult(NamedTuple):
    lam: jax.Array  # (s,) eigenvalues, ascending within selection
    Z: jax.Array    # (n, s) eigenvectors of T


def default_tridiag_method() -> str:
    """Backend-resolved default for ``eigh_tridiag_selected``: the Pallas
    kernels compiled on a real TPU, the fused-XLA batched program (which
    beats interpret-mode Pallas by orders of magnitude) everywhere else."""
    return "kernel" if jax.default_backend() == "tpu" else "batched"


def eigh_tridiag_selected(d: jax.Array, e: jax.Array, ks: jax.Array,
                          key: jax.Array | None = None,
                          method: str | None = None) -> TridiagEigResult:
    """Selected eigenpairs of tridiag(d, e) at indices ``ks`` (any order).

    ``ks`` is sorted internally and the result unpermuted, so
    ``lam[i], Z[:, i]`` answer ``ks[i]`` as given — ``inverse_iteration``'s
    gap-based clustering and masked MGS assume ascending shifts, and
    feeding them unsorted eigenvalues silently mis-clusters and skips
    reorthogonalization (the shuffled-``ks`` regression in
    tests/test_tridiag_eig.py).

    method:
      None      — backend autodetect (:func:`default_tridiag_method`):
                  'kernel' on a real TPU, 'batched' elsewhere.
      'scan'    — the legacy two-program baseline (bisection jit + inverse
                  iteration jit, unroll=1 Sturm scans).
      'batched' — ONE fused program from ``kernels.tridiag_eig.ops`` with
                  unrolled Sturm scans; bitwise-identical values,
                  measurably faster (the BENCH_tridiag gate), and the
                  path ``core.batched`` vmaps.
      'kernel'  — the Pallas kernels (interpret mode off-TPU), for parity
                  tests and TPU execution.
    """
    if method is None:
        method = default_tridiag_method()
    if key is None:
        key = jax.random.PRNGKey(12021)
    ks = jnp.asarray(ks)
    order = jnp.argsort(ks)
    inv = jnp.argsort(order)
    ks_sorted = ks[order]
    if method == "scan":
        lam = bisect_eigenvalues(d, e, ks_sorted)
        Z = inverse_iteration(d, e, lam, key)
    elif method == "batched":
        from repro.kernels.tridiag_eig.ops import tridiag_eig_batched
        lam, Z = tridiag_eig_batched(d, e, ks_sorted, key)
    elif method == "kernel":
        from repro.kernels.tridiag_eig.ops import tridiag_eig_kernel
        lam, Z = tridiag_eig_kernel(d, e, ks_sorted, key)
    else:
        raise ValueError(f"unknown tridiag-eig method: {method!r}")
    return TridiagEigResult(lam=lam[inv], Z=Z[:, inv])
