"""TT1/TT2 — two-stage tridiagonalization (SBR toolbox analogue).

Stage 1 (``reduce_to_band``, DSYRDB): dense -> band of width w via panel QR +
compact-WY two-sided updates, compiled as ONE program: the panel
factorization is a single fused launch (``kernels/house_panel`` — Pallas on
TPU, the identical jnp expression elsewhere), the trailing update runs in
SYR2K form (one rank-2w update per panel, ``kernels/syr2k`` on TPU), and
the sweep over panels is a ``lax.fori_loop`` over a small static
shrinking-window ladder — so a full reduction costs O(1) host dispatches
instead of the O(n/w) round trips of the per-panel host loop (kept as
``reduce_to_band_stepwise``, the baseline of ``benchmarks/bench_sbr.py``;
``dispatch_count()`` exposes the difference to the regression tests).
All flops are GEMMs (the BLAS-3 / MXU-friendly profile that motivates
variant TT in the paper) and Q1 is accumulated *explicitly* by GEMMs, as
the paper describes (two matrix products per panel). Stage 1 is NOT cheap:
once the bulge chase went wavefront (PR 4) it is the dominant stage of a
TT solve, which is why the sweep structure above matters. The window
ladder is auto-sized by :func:`default_n_chunks` — at small n the ladder's
extra windows cost more than the ~1/3 flop saving buys (BENCH_sbr measured
speedup_tt1 = 0.52 at n=128/w=8), so small problems run ``n_chunks=1``.

Stage 2 (``band_to_tridiag``, DSBRDT): band -> tridiagonal via Givens bulge
chasing over COMPACT band storage (see ``core.band_storage``), scheduled in
Schwarz/Kaufman wavefront sweeps: per time step, every in-flight column
sweep advances one chase step, and all of those rotations — provably
disjoint by the stagger of the schedule — are applied as ONE fused batched
update (``kernels/rot_apply``: a Pallas kernel on TPU, the identical
vectorized XLA expression elsewhere). The chase only touches the O(n w)
band; the (c, s) stream is RECORDED per pass and replayed by the same
blocked kernel afterwards — onto Q1^T in sweep-major batches for the
explicit-Q API (:func:`band_to_tridiag`), or onto the thin (n, s)
eigenvector slab (:func:`apply_q2`, the production path: O(n^2 s log w)
instead of O(n^3 log w) when s << n).

The dense-storage one-rotation-per-dispatch reference implementation is
kept as ``band_to_tridiag_dense`` (the parity oracle and the baseline in
``benchmarks/bench_sbr.py``; it is the code the old O(10 s @ n=256) TT2
measurements came from).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.house_panel.ops import house_panel
from repro.kernels.rot_apply.ops import rot_apply

from .band_storage import clean_band, pack_band, unpack_band
from .instrument import DispatchCounter
from .linalg_utils import (
    apply_wy_two_sided_syr2k,
    extract_tridiag,
    givens,
    rotate_cols,
    rotate_rows,
    symmetrize,
    wy_syr2k_panel,
)
from .precision import matmul_acc


class BandResult(NamedTuple):
    Wb: jax.Array  # (w+1, n) packed band (see core.band_storage), W = Q1^T C Q1
    Q1: jax.Array  # (n, n) explicit orthogonal factor

    def dense(self) -> jax.Array:
        """The banded matrix expanded to dense (n, n) — tests/benchmarks."""
        return unpack_band(self.Wb)


# dispatch accounting (observability + the regression tests' hook): the
# counter makes "fused sweep = O(1), stepwise loop = O(n/w)" testable
_dispatch = DispatchCounter()

#: host->device dispatches issued by ``reduce_to_band`` /
#: ``reduce_to_band_stepwise`` since the last ``reset_dispatch_count()``
dispatch_count = _dispatch.count
reset_dispatch_count = _dispatch.reset


def _chunk_bounds(n_panels: int, n_chunks: int):
    """Static panel ranges for the shrinking-window ladder."""
    n_chunks = max(1, min(n_chunks, n_panels))
    bounds = [round(c * n_panels / n_chunks) for c in range(n_chunks + 1)]
    return [(bounds[c], bounds[c + 1]) for c in range(n_chunks)
            if bounds[c + 1] > bounds[c]]


def _n_panels(n: int, w: int) -> int:
    return len(range(0, max(n - w - 1, 0), w))


#: the window ladder is a measured pessimization when the problem is small
#: or the windows are panel-starved (the extra window programs cost more
#: than the ~1/3 flop saving buys): BENCH_sbr measured speedup_tt1 = 0.52
#: at n=128/w=8 and 0.66 at n=256/w=32 (6 panels over 4 windows), vs 3.4x
#: at n=256/w=8 (30 panels) and 1.8-2.5x everywhere at n=512
_WINDOW_MIN_N = 256        # below: never ladder
_WINDOW_AUTO_N = 512       # at/above: always ladder
_WINDOW_MIN_PANELS = 16    # in between: need enough panels to amortize


def default_n_chunks(n: int, w: int) -> int:
    """Auto-sized shrinking-window ladder: up to 4 trailing windows once
    the problem is big enough (``n >= 512``, or ``n >= 256`` with at least
    16 panels); 1 (full-matrix updates) otherwise."""
    n_panels = _n_panels(n, w)
    if n_panels == 0:
        return 1
    if n >= _WINDOW_AUTO_N or (n >= _WINDOW_MIN_N
                               and n_panels >= _WINDOW_MIN_PANELS):
        return min(4, n_panels)
    return 1


def _wy_rank2_update(Mt: jax.Array, V: jax.Array, T: jax.Array) -> jax.Array:
    """SYR2K-form two-sided update; the rank-2w product goes through the
    fused ``kernels/syr2k`` Pallas kernel on TPU (one HBM round trip per
    C tile) and the identical jnp expression elsewhere."""
    if jax.default_backend() == "tpu":
        from repro.kernels.syr2k.ops import syr2k
        Z = wy_syr2k_panel(Mt, V, T)
        return symmetrize(syr2k(Mt, V, Z, alpha=-1.0))
    return apply_wy_two_sided_syr2k(Mt, V, T)


@partial(jax.jit, static_argnames=("w", "n_chunks"))
def _reduce_to_band_program(C: jax.Array, w: int, n_chunks: int) -> BandResult:
    """The whole stage-1 sweep as ONE compiled program (see reduce_to_band)."""
    n = C.shape[0]
    Q1_0 = jnp.eye(n, dtype=C.dtype)
    n_panels = _n_panels(n, w)
    if n_panels == 0:
        return BandResult(Wb=pack_band(C, w, symmetrize=True), Q1=Q1_0)

    M, Q1 = C, Q1_0
    for p0, p1 in _chunk_bounds(n_panels, n_chunks):
        o = p0 * w           # window origin (static)
        S = n - o            # window size (static)

        def body(p, carry, o=o, S=S):
            Mt, Q1t = carry
            c0 = p * w - o                       # panel start inside window
            E = jax.lax.dynamic_slice(Mt, (0, c0), (S, w))
            V, T = house_panel(E, c0 + w)        # one fused panel launch
            Mt = _wy_rank2_update(Mt, V, T)
            # explicit Q1 accumulation (two GEMMs per panel, paper Sec. 2.2)
            Q1t = Q1t - matmul_acc(matmul_acc(matmul_acc(Q1t, V), T), V.T)
            return Mt, Q1t

        Mt = jax.lax.slice(M, (o, o), (n, n))
        Q1t = jax.lax.slice(Q1, (0, o), (n, n))
        Mt, Q1t = jax.lax.fori_loop(p0, p1, body, (Mt, Q1t))
        M = jax.lax.dynamic_update_slice(M, Mt, (o, o))
        Q1 = jax.lax.dynamic_update_slice(Q1, Q1t, (0, o))
    return BandResult(Wb=pack_band(M, w, symmetrize=True), Q1=Q1)


def reduce_to_band(C: jax.Array, w: int = 32,
                   n_chunks: int | None = None) -> BandResult:
    """Stage 1: Q1^T C Q1 = W with bandwidth w. Panel QR + WY updates.

    The ENTIRE sweep — panel factorization (``kernels/house_panel``),
    T-build, SYR2K-form trailing update, Q1 accumulation — is one jitted
    program: panels are grouped into a small static ladder of trailing
    windows (the reflectors of panel k are masked below row ``(k+1) w``,
    so the two-sided update acts as identity before the window and the
    (S, S) trailing slice is the only data it can change), and within one
    window the panel loop is a ``fori_loop`` with FIXED-shape bodies (one
    compile per window size, ``n_chunks`` sizes total). ``n_chunks=None``
    auto-sizes the ladder via :func:`default_n_chunks`; ``n_chunks=1``
    is the full-(n, n) masked behavior (and the right choice at small n).

    Returns the band in packed (w+1, n) storage (``BandResult.Wb``) plus the
    explicit Q1. Costs O(1) host dispatches per sweep (``dispatch_count()``;
    the per-panel host loop survives as :func:`reduce_to_band_stepwise`).
    """
    if n_chunks is None:
        n_chunks = default_n_chunks(C.shape[0], w)
    return _dispatch(_reduce_to_band_program, C, w=w, n_chunks=n_chunks)


# per-panel jitted pieces of the stepwise baseline (compile once each)
_jit_slice_cols = jax.jit(
    lambda M, c0, w: jax.lax.dynamic_slice(M, (0, c0), (M.shape[0], w)),
    static_argnames=("w",))
_jit_house_panel = jax.jit(house_panel)
_jit_wy_update = jax.jit(apply_wy_two_sided_syr2k)
_jit_wy_right = jax.jit(
    lambda Q, V, T: Q - matmul_acc(matmul_acc(matmul_acc(Q, V), T), V.T))
_jit_pack = jax.jit(lambda M, w: pack_band(M, w, symmetrize=True),
                    static_argnames=("w",))


def reduce_to_band_stepwise(C: jax.Array, w: int = 32) -> BandResult:
    """The old per-panel HOST loop: one panel slice + QR + trailing update +
    Q1 accumulation dispatched per panel (O(n/w) host round trips).

    Numerically the same sweep as :func:`reduce_to_band` with
    ``n_chunks=1``; kept as the dispatch-overhead baseline for
    ``benchmarks/bench_sbr.py --quick`` and the dispatch-count regression
    tests — do not use it in production paths.
    """
    n = C.shape[0]
    M, Q1 = C, jnp.eye(n, dtype=C.dtype)
    for k in range(_n_panels(n, w)):
        c0 = k * w
        E = _dispatch(_jit_slice_cols, M, jnp.asarray(c0), w)
        V, T = _dispatch(_jit_house_panel, E, jnp.asarray(c0 + w))
        M = _dispatch(_jit_wy_update, M, V, T)
        Q1 = _dispatch(_jit_wy_right, Q1, V, T)
    return BandResult(Wb=_dispatch(_jit_pack, M, w), Q1=Q1)


class TridiagFromBandResult(NamedTuple):
    d: jax.Array   # (n,)
    e: jax.Array   # (n-1,)
    Q: jax.Array   # (n, n) accumulated Q1*Q2


class BandChaseResult(NamedTuple):
    """Chase output with the rotation stream kept implicit.

    ``cs[i]`` is the (J+1, K0+1, 2) (c, s) table of the i-th executed pass
    (bandwidths ``_executed_passes(n, w)``, i.e. b = w..2 skipping the
    degenerate ones); slot (j, k) is chase step k of column j's sweep,
    unused slots hold the identity rotation. Feed to :func:`apply_q2` /
    :func:`accumulate_q2` — O(n w + n^2 log w) storage instead of an
    (n, n) explicit Q2.
    """
    d: jax.Array
    e: jax.Array
    cs: Tuple[jax.Array, ...]


# ---------------------------------------------------------------------------
# TT2: wavefront bulge chasing over packed band storage
# ---------------------------------------------------------------------------
#
# Schwarz bandwidth-decrement sweeps b = w..2. In the b-pass, column j's
# sweep annihilates W[j+b, j] and chases the resulting bulge down in steps
# of b: chase step k rotates the plane (r-1, r) with r = j + (k+1) b. A
# rotation at center r touches only matrix indices [r-b-2, r+b+1], so two
# in-flight sweeps whose centers stay >= 2b+4 apart commute EXACTLY (they
# update disjoint entries — the wavefront reordering agrees with the
# sequential order to rounding noise). Starting column j at time step g*j
# with stagger g = 2 + ceil(5/b) makes consecutive active centers differ by
# g*b - 1 >= 2b + 4, so at every time step ALL in-flight rotations form one
# disjoint wavefront -> one fused rot_apply per side of the band windows.
#
# Q2 is NOT carried through the chase: the (c, s) stream is recorded and
# replayed sweep-major (all rotations of one sweep touch pairwise-disjoint
# row pairs — they are b >= 2 apart — so a whole sweep is again one fused
# rot_apply), in chase order onto Q1^T for the explicit Q, or in reverse
# order onto the (n, s) Ritz slab for the cheap production back-transform.

_P_LEFT = 2  # left column margin of the padded chase storage


def _executed_passes(n: int, w: int):
    return [b for b in range(w, 1, -1) if n - b > 0]


def _pass_schedule(n: int, b: int):
    """Static schedule of the bandwidth-b pass: (stagger, steps, lanes, J, K0)."""
    J = n - b                      # columns j = 0..J-1 annihilate W[j+b, j]
    g = 2 + -(-5 // b)             # smallest g with g*b - 1 >= 2b + 4
    K0 = (n - 1 - b) // b + 1      # chase steps of the longest (first) sweep
    T_pass = g * (J - 1) + 1       # last column starts at g(J-1), runs 1 step
    G = K0 // g + 1                # max simultaneously active sweeps
    return g, T_pass, G, J, K0


def _chase_pass(Wp: jax.Array, b: int, w: int, n: int):
    """One wavefront bandwidth-decrement pass (bandwidth b -> b-1).

    ``Wp`` is (w+2, n_pad) packed band storage (one spare diagonal for the
    bulge, zero padding on both column edges — corner windows read/write
    zeros there, which is self-preserving). Returns the updated band and
    the recorded (J+1, K0+1, 2) rotation table of the pass.
    """
    g, T_pass, G, J, K0 = _pass_schedule(n, b)
    L = 2 * b + 4                  # local window: columns [r-b-2, r+b+1]
    npad = Wp.shape[1]
    dump = npad - L                # all-zero dump window for inactive lanes

    # static gather/scatter index templates
    pgrid = jnp.arange(L)[:, None]
    qgrid = jnp.arange(L)[None, :]
    dd = jnp.abs(pgrid - qgrid)                     # (L, L) |row - col|
    mm = jnp.minimum(pgrid, qgrid)                  # (L, L) min(row, col)
    dvalid = dd <= w + 1
    dclip = jnp.clip(dd, 0, w + 1)
    drow = jnp.arange(w + 2)[:, None]               # (w+2, 1)
    qcol = jnp.arange(L)[None, :]                   # (1, L)
    in_win = (drow + qcol) < L                      # packed entry inside window
    rowsel = jnp.clip(drow + qcol, 0, L - 1)
    qcols = jnp.broadcast_to(qcol, (w + 2, L))
    larange = jnp.arange(L)

    # (c, s) table; unused slots stay at the identity rotation
    CS0 = jnp.zeros((J + 1, K0 + 1, 2), Wp.dtype).at[..., 0].set(1.0)

    def step(t, carry):
        Wp, CS = carry
        # wavefront lane decode: lane l rides column jtop - l
        jtop = jnp.minimum(t // g, J - 1)
        j = jtop - jnp.arange(G)
        k = t - g * j                                   # chase step of lane
        Kj = (n - 1 - j - b) // b + 1                   # sweep length of col j
        active = (j >= 0) & (k >= 0) & (k < Kj)
        r = j + (k + 1) * b                             # rotation plane (r-1, r)
        sk = (k > 0).astype(j.dtype)                    # bulge (1) vs first (0)
        i0 = jnp.where(active, r - b - 2 + _P_LEFT, dump)

        # gather each lane's local dense (L, L) window from packed storage
        colidx = i0[:, None, None] + mm                 # (G, L, L)
        local = jnp.where(dvalid, Wp[dclip, colidx], 0.0)

        # rotation params: annihilate local[b+2, 2-sk] against local[b+1, 2-sk]
        # (the in-band element for k=0, the chased bulge for k>0)
        tcol = (2 - sk)[:, None]
        a_piv = jnp.take_along_axis(local[:, b + 1, :], tcol, axis=1)[:, 0]
        a_ann = jnp.take_along_axis(local[:, b + 2, :], tcol, axis=1)[:, 0]
        cth, sth = givens(a_piv, a_ann)
        cs = jnp.stack([cth, sth], axis=1)              # (G, 2)
        CS = CS.at[jnp.where(active, j, J),
                   jnp.where(active, k, K0)].set(cs)

        # two-sided rotation of local rows/cols (b+1, b+2) — one wavefront,
        # one fused rot_apply per side
        rows = rot_apply(local[:, b + 1: b + 3, :], cs)
        local = local.at[:, b + 1: b + 3, :].set(rows)
        cols = rot_apply(jnp.swapaxes(local[:, :, b + 1: b + 3], 1, 2), cs)
        local = local.at[:, :, b + 1: b + 3].set(jnp.swapaxes(cols, 1, 2))

        # scatter the packed windows back (lane windows are disjoint)
        wcols = i0[:, None] + larange[None, :]          # (G, L)
        old_win = jnp.moveaxis(Wp[:, wcols], 1, 0)      # (G, w+2, L)
        new_win = jnp.where(in_win, local[:, rowsel, qcols], old_win)
        Wp = Wp.at[:, wcols].set(jnp.moveaxis(new_win, 0, 1))
        return Wp, CS

    Wp, CS = jax.lax.fori_loop(0, T_pass, step, (Wp, CS0))
    # annihilated diagonals carry O(eps) residue; zero them so the next pass
    # sees an exact bandwidth-(b-1) matrix
    Wp = Wp.at[b:, :].set(0.0)
    return Wp, CS


def _band_chase_core(Wb: jax.Array, w: int):
    """Run all bandwidth passes; returns (d, e, per-pass rotation tables)."""
    wp1, n = Wb.shape
    assert wp1 == w + 1, (Wb.shape, w)
    # padded chase storage: one bulge diagonal, zero margins on both column
    # edges (left: windows of the first sweeps start at r-b-2 = -2; right:
    # corner windows overhang by up to b+1, plus a dump window for masked
    # wavefront lanes)
    npad = _P_LEFT + n + 3 * w + 8
    Wp = jnp.zeros((w + 2, npad), Wb.dtype)
    Wp = Wp.at[: w + 1, _P_LEFT: _P_LEFT + n].set(clean_band(Wb))
    cs_list = []
    for b in _executed_passes(n, w):
        Wp, CS = _chase_pass(Wp, b, w, n)
        cs_list.append(CS)
    d = Wp[0, _P_LEFT: _P_LEFT + n]
    e = Wp[1, _P_LEFT: _P_LEFT + n - 1]
    return d, e, tuple(cs_list)


def _replay_pass(Xp: jax.Array, CS: jax.Array, b: int, n: int,
                 reverse: bool):
    """Apply one pass's recorded rotations to padded row storage ``Xp``.

    Sweep-major: all K0 rotations of one column sweep touch pairwise
    disjoint row pairs (planes are b >= 2 apart), so a sweep is ONE fused
    rot_apply over (K0, 2, cols) gathers; sweeps run forward (chase order,
    for accumulating Q2 onto Q^T) or backward (for Q2 @ Z, where the last
    recorded rotation acts first and each (c, s) flips to (c, -s)).
    """
    J, K0 = CS.shape[0] - 1, CS.shape[1] - 1
    nr = Xp.shape[0] - 2
    ks = jnp.arange(K0)

    def body(i, Xp):
        j = (J - 1 - i) if reverse else i
        r = j + (ks + 1) * b
        valid = r < n
        rows = jnp.where(valid[:, None],
                         jnp.stack([r - 1, r], axis=1),
                         nr + jnp.array([0, 1]))
        cs = CS[j, :K0]
        if reverse:
            cs = cs * jnp.array([1.0, -1.0], cs.dtype)
        Xp = Xp.at[rows].set(rot_apply(Xp[rows], cs))
        return Xp

    return jax.lax.fori_loop(0, J, body, Xp)


def _pad_rows(X: jax.Array):
    return jnp.zeros((X.shape[0] + 2, X.shape[1]), X.dtype).at[:-2].set(X)


@partial(jax.jit, static_argnames=("w",))
def band_chase(Wb: jax.Array, w: int) -> BandChaseResult:
    """TT2 without explicit Q: chase the band, keep the rotation stream.

    The production form of stage 2: the chase itself costs O(n^2 w) on
    O(n w) storage, and the recorded stream back-transforms an (n, s) slab
    via :func:`apply_q2` for O(n^2 s log w) — no (n, n) Q2 is ever formed.
    """
    if w <= 1 or Wb.shape[1] <= 2:
        n = Wb.shape[1]
        e = Wb[1, : n - 1] if w >= 1 else jnp.zeros((n - 1,), Wb.dtype)
        return BandChaseResult(d=Wb[0, :], e=e, cs=())
    d, e, cs = _band_chase_core(Wb, w)
    return BandChaseResult(d=d, e=e, cs=cs)


@partial(jax.jit, static_argnames=("w",))
def apply_q2(chase: BandChaseResult, Z: jax.Array, w: int) -> jax.Array:
    """Compute Q2 @ Z from the recorded rotation stream (Z is (n, s)).

    Rotations recorded as Q <- Q G must hit Z as G_N ... G_1 applied
    left-to-right from the LAST one, i.e. passes in reverse (b = 2..w),
    sweeps within a pass in reverse, with each (c, s) transposed.
    """
    n = Z.shape[0]
    passes = _executed_passes(n, w)
    assert len(passes) == len(chase.cs), (len(passes), len(chase.cs))
    Zp = _pad_rows(Z)
    for b, CS in zip(reversed(passes), reversed(chase.cs)):
        Zp = _replay_pass(Zp, CS, b, n, reverse=True)
    return Zp[:-2]


@partial(jax.jit, static_argnames=("w",))
def accumulate_q2(chase: BandChaseResult, Q1: jax.Array,
                  w: int) -> jax.Array:
    """Explicit Q1 @ Q2 by replaying the stream onto Q1^T in chase order."""
    n = Q1.shape[1]
    passes = _executed_passes(n, w)
    assert len(passes) == len(chase.cs), (len(passes), len(chase.cs))
    Qtp = _pad_rows(Q1.T)
    for b, CS in zip(passes, chase.cs):
        Qtp = _replay_pass(Qtp, CS, b, n, reverse=False)
    return Qtp[:-2].T


@partial(jax.jit, static_argnames=("w",))
def band_to_tridiag(Wb: jax.Array, Q1: jax.Array,
                    w: int) -> TridiagFromBandResult:
    """Stage 2 with explicit Q: wavefront chase + blocked Q2 accumulation.

    ``Wb`` is the symmetric band in ``core.band_storage`` packed layout
    (``Wb[d, i] = W[i+d, i]``); ``Q1`` is the (n, n) factor the chase
    rotations are accumulated into from the right (pass ``jnp.eye(n)`` to
    get Q2 alone). Numerically this is the same rotation sequence as
    :func:`band_to_tridiag_dense` — the wavefront schedule only reorders
    provably-disjoint rotations — but it runs on O(n w) storage with fused
    batched updates instead of one masked (n, n) row/column update per
    rotation. When only s << n back-transformed vectors are needed, use
    :func:`band_chase` + :func:`apply_q2` and skip the O(n^3) explicit
    accumulation entirely.
    """
    chase = band_chase(Wb, w)
    if not chase.cs:
        return TridiagFromBandResult(d=chase.d, e=chase.e, Q=Q1)
    Q = accumulate_q2(chase, Q1, w)
    return TridiagFromBandResult(d=chase.d, e=chase.e, Q=Q)


@partial(jax.jit, static_argnames=("w",), donate_argnums=())
def band_to_tridiag_dense(W: jax.Array, Q1: jax.Array,
                          w: int) -> TridiagFromBandResult:
    """Dense-storage TT2 reference: one masked row/col rotation per step.

    The flop-shape-faithful but dispatch-bound original implementation
    (every rotation is an O(n) masked update of the full (n, n) matrix and
    of Q, serialized in a while_loop). Kept as the parity oracle for
    :func:`band_to_tridiag` and as the baseline of
    ``benchmarks/bench_sbr.py``; the packed wavefront version above is the
    production path.
    """
    n = W.shape[0]
    M = W
    Q = Q1
    dist = jnp.abs(jnp.arange(n)[:, None] - jnp.arange(n)[None, :])

    def chase_one(state):
        M, Q, r, c, b = state
        # annihilate M[r, c] with rows (r-1, r)
        a = M[r - 1, c]
        bb = M[r, c]
        cth, sth = givens(a, bb)
        M = rotate_rows(M, r - 1, r, cth, sth)
        M = rotate_cols(M, r - 1, r, cth, sth)
        # the (r-1, r)/(r, r-1) pair is the one entry the row-then-col
        # update rounds through two different expression orders; pin the
        # upper copy to the lower one so the matrix stays EXACTLY symmetric
        # (packed storage holds a single copy — without this the two
        # implementations diverge from an O(eps) asymmetry seed)
        M = M.at[r - 1, r].set(M[r, r - 1])
        Q = rotate_cols(Q, r - 1, r, cth, sth)
        # next bulge position
        c_new = r - 1
        r_new = r + b
        return M, Q, r_new, c_new, b

    def chase_cond(state):
        _, _, r, _, _ = state
        return r < n

    for b in range(w, 1, -1):
        def col_body(j, carry):
            M, Q = carry
            r0 = j + b
            state = (M, Q, r0, j, jnp.asarray(b))
            M, Q, _, _, _ = jax.lax.while_loop(chase_cond, chase_one, state)
            return M, Q

        if n - b > 0:
            M, Q = jax.lax.fori_loop(0, n - b, col_body, (M, Q))
            # the annihilated diagonals carry O(eps) residue; zero them so
            # the next sweep sees an exact bandwidth-(b-1) matrix (the same
            # invariant the packed wavefront chase maintains — this is what
            # keeps the two implementations in close agreement instead of
            # diverging through noise-conditioned rotations)
            M = jnp.where(dist >= b, 0.0, M)

    d, e = extract_tridiag(symmetrize(M))
    return TridiagFromBandResult(d=d, e=e, Q=Q)


def two_stage_tridiagonalize(C: jax.Array, w: int = 32):
    """TT1+TT2 composed: returns (d, e, Q) with Q^T C Q = T, Q explicit."""
    band = reduce_to_band(C, w=w)
    return band_to_tridiag(band.Wb, band.Q1, w)
