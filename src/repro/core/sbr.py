"""TT1/TT2 — two-stage tridiagonalization (SBR toolbox analogue).

Stage 1 (``reduce_to_band``, DSYRDB): dense -> band of width w via panel QR +
compact-WY two-sided updates. All flops are GEMMs (the BLAS-3 / MXU-friendly
profile that motivates variant TT in the paper). Q1 is accumulated
*explicitly* by GEMMs, as the paper describes (two matrix products per panel).

Stage 2 (``band_to_tridiag``, DSBRDT): band -> tridiagonal via Givens bulge
chasing (Schwarz/Kaufman bandwidth-decrement sweeps). Rotations are also
accumulated into Q from the right, so that TT4 is a single GEMM Y = Q Z.

Note on storage: we keep the band matrix in full dense (n, n) storage and
rotate full rows/columns with masked dynamic updates — flop-shape-faithful,
simple, and correct. The O(n^2 w)-storage band kernel (see kernels/band_mv)
is the TPU-side optimization; EXPERIMENTS.md discusses the gap.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .linalg_utils import (
    apply_wy_two_sided,
    extract_tridiag,
    givens,
    qr_wy_masked,
    rotate_cols,
    rotate_rows,
    symmetrize,
)


class BandResult(NamedTuple):
    W: jax.Array   # (n, n) banded (bandwidth w) symmetric matrix
    Q1: jax.Array  # (n, n) explicit orthogonal factor, W = Q1^T C Q1


@partial(jax.jit, static_argnames=("w",))
def reduce_to_band(C: jax.Array, w: int = 32) -> BandResult:
    """Stage 1: Q1^T C Q1 = W with bandwidth w. Panel QR + WY updates.

    One fori_loop over panels with FIXED-shape bodies: the panel is the
    full-height column slice, reflectors are masked below the band row
    (qr_wy_masked), and the two-sided update H M H runs at full (n, n) —
    H acts as identity on the already-reduced rows because V is masked, so
    the update simultaneously annihilates the panel and updates the trailing
    block (no shape specialization per panel => compiles once).
    """
    n = C.shape[0]
    Q1_0 = jnp.eye(n, dtype=C.dtype)
    n_panels = len(range(0, max(n - w - 1, 0), w))

    def body(k, carry):
        M, Q1 = carry
        c0 = k * w
        r0 = c0 + w
        E = jax.lax.dynamic_slice(M, (k * 0, c0), (n, w))
        V, T, _ = qr_wy_masked(E, r0)
        M = apply_wy_two_sided(M, V, T)
        # explicit Q1 accumulation (two GEMMs per panel, paper Sec. 2.2)
        Q1 = Q1 - ((Q1 @ V) @ T) @ V.T
        return M, Q1

    if n_panels > 0:
        M, Q1 = jax.lax.fori_loop(0, n_panels, body, (C, Q1_0))
    else:
        M, Q1 = C, Q1_0
    band_mask = jnp.abs(jnp.arange(n)[:, None] - jnp.arange(n)[None, :]) <= w
    return BandResult(W=symmetrize(jnp.where(band_mask, M, 0.0)), Q1=Q1)


class TridiagFromBandResult(NamedTuple):
    d: jax.Array   # (n,)
    e: jax.Array   # (n-1,)
    Q: jax.Array   # (n, n) accumulated Q1*Q2


@partial(jax.jit, static_argnames=("w",), donate_argnums=())
def band_to_tridiag(W: jax.Array, Q1: jax.Array, w: int) -> TridiagFromBandResult:
    """Stage 2: Givens bulge-chasing, bandwidth-decrement sweeps b = w..2.

    For each sweep bandwidth b: for each column j, annihilate W[j+b, j] with a
    rotation of rows/cols (j+b-1, j+b); the bulge appears at (p+b, p-1) for
    p = j+b and is chased down in steps of b. Each rotation is also applied to
    Q from the right (Q <- Q G), accumulating Q2 into Q1 (paper: TT2 keeps all
    updates BLAS-friendly; here each is an O(n) masked row/col update).
    """
    n = W.shape[0]
    M = W
    Q = Q1

    def chase_one(state):
        M, Q, r, c, b = state
        # annihilate M[r, c] with rows (r-1, r)
        a = M[r - 1, c]
        bb = M[r, c]
        cth, sth = givens(a, bb)
        M = rotate_rows(M, r - 1, r, cth, sth)
        M = rotate_cols(M, r - 1, r, cth, sth)
        Q = rotate_cols(Q, r - 1, r, cth, sth)
        # next bulge position
        c_new = r - 1
        r_new = r + b
        return M, Q, r_new, c_new, b

    def chase_cond(state):
        _, _, r, _, _ = state
        return r < n

    for b in range(w, 1, -1):
        def col_body(j, carry):
            M, Q = carry
            r0 = j + b
            state = (M, Q, r0, j, jnp.asarray(b))
            M, Q, _, _, _ = jax.lax.while_loop(chase_cond, chase_one, state)
            return M, Q

        if n - b > 0:
            M, Q = jax.lax.fori_loop(0, n - b, col_body, (M, Q))

    d, e = extract_tridiag(symmetrize(M))
    return TridiagFromBandResult(d=d, e=e, Q=Q)


def two_stage_tridiagonalize(C: jax.Array, w: int = 32):
    """TT1+TT2 composed: returns (d, e, Q) with Q^T C Q = T, Q explicit."""
    band = reduce_to_band(C, w=w)
    return band_to_tridiag(band.W, band.Q1, w)
