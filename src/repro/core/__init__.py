"""repro.core — dense symmetric-definite generalized eigensolvers (the paper's
contribution) as composable JAX modules."""
from .back_transform import (back_transform_generalized,
                             forward_transform_generalized)
from .band_storage import (band_extract_tridiag, clean_band, pack_band,
                           unpack_band)
from .batched import (BATCHED_VARIANTS, BatchedSolveResult, solve_batched)
from .cholesky import cholesky_blocked, cholesky_upper
from .gsyeig import VARIANTS, GSyEigResult, solve
from .lanczos import (LanczosResult, default_subspace, lanczos_solve,
                      lanczos_solve_jit)
from .operators import ExplicitC, ImplicitC, apply_op
from .precision import (PRECISIONS, compute_dtype, declared_downcasts,
                        default_refine_steps, ensure_strong,
                        validate_precision)
from .refinement import refine_eigenpairs
from .residuals import (AccuracyReport, accuracy_report, b_normalize,
                        b_orthogonality, relative_residual)
from .sbr import (accumulate_q2, apply_q2, band_chase, band_to_tridiag,
                  band_to_tridiag_dense, reduce_to_band,
                  two_stage_tridiagonalize)
from .standard_form import to_standard_sygst, to_standard_two_trsm
from .tridiag import (TridiagResult, apply_q, apply_qt,
                      tridiagonalize, tridiagonalize_blocked)
from .tridiag_eig import (bisect_eigenvalues, eigh_tridiag_selected,
                          inverse_iteration, sturm_count, sturm_counts)

__all__ = [
    "solve", "VARIANTS", "GSyEigResult",
    "solve_batched", "BATCHED_VARIANTS", "BatchedSolveResult",
    "cholesky_upper", "cholesky_blocked",
    "to_standard_two_trsm", "to_standard_sygst",
    "tridiagonalize", "tridiagonalize_blocked", "apply_q",
    "apply_qt", "TridiagResult",
    "reduce_to_band", "band_to_tridiag", "band_to_tridiag_dense",
    "band_chase", "apply_q2", "accumulate_q2", "two_stage_tridiagonalize",
    "pack_band", "unpack_band", "clean_band", "band_extract_tridiag",
    "sturm_count", "sturm_counts", "bisect_eigenvalues",
    "inverse_iteration", "eigh_tridiag_selected",
    "lanczos_solve", "lanczos_solve_jit", "LanczosResult", "default_subspace",
    "ExplicitC", "ImplicitC", "apply_op",
    "PRECISIONS", "validate_precision", "compute_dtype",
    "declared_downcasts", "default_refine_steps", "ensure_strong",
    "refine_eigenpairs",
    "back_transform_generalized", "forward_transform_generalized",
    "accuracy_report", "AccuracyReport", "b_orthogonality",
    "relative_residual", "b_normalize",
]
