"""Dispatch accounting shared by the solver hot paths.

``core.lanczos``, ``core.sbr``, and ``dist.eigensolver`` each expose a
module-level ``dispatch_count()`` / ``reset_dispatch_count()`` pair so the
regression tests can pin "this sweep is O(1) host dispatches" against the
per-panel / per-matvec baselines. The counting semantics live here, once:
every invocation routed through a :class:`DispatchCounter` counts 1 jitted
program dispatch (when tracing inside an outer jit the count reflects the
trace, which is exactly the number of programs the host would issue).
"""
from __future__ import annotations


class DispatchCounter:
    """Callable counter: ``counter(fn, *args)`` counts 1 and calls ``fn``."""

    def __init__(self) -> None:
        self._count = 0

    def count(self) -> int:
        return self._count

    def reset(self) -> None:
        self._count = 0

    def __call__(self, fn, *args, **kwargs):
        self._count += 1
        return fn(*args, **kwargs)
