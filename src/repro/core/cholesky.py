"""GS1 — Cholesky factorization B = U^T U (upper factor).

Two paths:
  * ``cholesky_upper``  — XLA's fused factorization (the "vendor library" path;
    the paper's DPOTRF/MAGMA_DPOTRF analogue).
  * ``cholesky_blocked`` — right-looking blocked algorithm (the PLASMA/lf+SM
    task-parallel analogue). Block operations are the units that map 1:1 onto
    the Pallas/sharded tiles; XLA fuses the per-block work.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cholesky_upper(B: jax.Array) -> jax.Array:
    """Return upper-triangular U with B = U^T U."""
    L = jnp.linalg.cholesky(B)
    return L.T


def diag_shifted(B: jax.Array, tau: float) -> jax.Array:
    """B + tau * max|diag B| * I — the GS1 breakdown-recovery shift.

    Relative to the diagonal scale so the same rung ladder (see
    ``resilience.recovery.cholesky_shift_taus``) serves pencils of any
    magnitude; the caller reports the shift it used and refinement still
    targets the original pencil."""
    n = B.shape[0]
    scale = jnp.max(jnp.abs(jnp.diagonal(B)))
    return B + (tau * scale) * jnp.eye(n, dtype=B.dtype)


def cholesky_blocked(B: jax.Array, block: int = 256) -> jax.Array:
    """Right-looking blocked Cholesky (upper factor), B = U^T U.

    for k in blocks:
        U_kk  = chol(B_kk)
        U_k,: = U_kk^{-T} B_k,:          (triangular solve on the block row)
        B_t,t = B_t,t - U_k,:^T U_k,:    (SYRK trailing update)
    """
    n = B.shape[0]
    M = B
    U = jnp.zeros_like(B)
    for k0 in range(0, n, block):
        k1 = min(k0 + block, n)
        Bkk = M[k0:k1, k0:k1]
        Ukk = jnp.linalg.cholesky(Bkk).T
        U = U.at[k0:k1, k0:k1].set(Ukk)
        if k1 < n:
            row = jax.scipy.linalg.solve_triangular(
                Ukk, M[k0:k1, k1:], trans=1, lower=False
            )
            U = U.at[k0:k1, k1:].set(row)
            M = M.at[k1:, k1:].add(-(row.T @ row))
    return jnp.triu(U)
