"""GS2 — reduction of the generalized problem to standard form.

C := U^{-T} A U^{-1}   (so A x = lambda B x  <=>  C y = lambda y, y = U x)

Two variants, exactly as discussed in the paper (Sec. 2.1):
  * ``to_standard_two_trsm``  — two triangular solves, 2 n^3 flops
    (the DTRSM path the paper found faster than DSYGST on their platform).
  * ``to_standard_sygst``     — blocked two-sided reduction exploiting
    symmetry, n^3 flops (the DSYGST path; also the PLASMA/lf+SM analogue).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .linalg_utils import symmetrize

_solve_tri = jax.scipy.linalg.solve_triangular


def to_standard_two_trsm(A: jax.Array, U: jax.Array) -> jax.Array:
    """C = U^{-T} A U^{-1} via two TRSMs (2 n^3 flops)."""
    # W = U^{-T} A  : solve U^T W = A
    W = _solve_tri(U, A, trans=1, lower=False)
    # C = W U^{-1}  : C U = W  <=>  U^T C^T = W^T
    C = _solve_tri(U, W.T, trans=1, lower=False).T
    return symmetrize(C)


def _sygs2(Akk: jax.Array, Ukk: jax.Array) -> jax.Array:
    """Unblocked diagonal-block reduction: U_kk^{-T} A_kk U_kk^{-1}."""
    W = _solve_tri(Ukk, Akk, trans=1, lower=False)
    return symmetrize(_solve_tri(Ukk, W.T, trans=1, lower=False).T)


def to_standard_sygst(A: jax.Array, U: jax.Array, block: int = 256) -> jax.Array:
    """Blocked DSYGST (itype=1, upper): C = U^{-T} A U^{-1} in ~n^3 flops.

    LAPACK-style blocked sweep; per block k (ranges [k0, k1), trailing t=[k1, n)):
        A_kk   <- U_kk^{-T} A_kk U_kk^{-1}
        A_k,t  <- U_kk^{-T} A_k,t
        A_k,t  <- A_k,t - 1/2 A_kk U_k,t
        A_t,t  <- A_t,t - U_k,t^T A_k,t - A_k,t^T U_k,t     (SYR2K)
        A_k,t  <- A_k,t - 1/2 A_kk U_k,t
        A_k,t  <- A_k,t U_tt^{-1}
    """
    n = A.shape[0]
    M = A
    for k0 in range(0, n, block):
        k1 = min(k0 + block, n)
        Ukk = U[k0:k1, k0:k1]
        Ckk = _sygs2(M[k0:k1, k0:k1], Ukk)
        M = M.at[k0:k1, k0:k1].set(Ckk)
        if k1 < n:
            Ukt = U[k0:k1, k1:]
            row = _solve_tri(Ukk, M[k0:k1, k1:], trans=1, lower=False)
            row = row - 0.5 * (Ckk @ Ukt)
            # SYR2K trailing update
            Mtt = M[k1:, k1:] - Ukt.T @ row - row.T @ Ukt
            M = M.at[k1:, k1:].set(symmetrize(Mtt))
            row = row - 0.5 * (Ckk @ Ukt)
            Utt = U[k1:, k1:]
            # row <- row * U_tt^{-1}:  solve X U_tt = row  <=> U_tt^T X^T = row^T
            row = _solve_tri(Utt, row.T, trans=1, lower=False).T
            M = M.at[k0:k1, k1:].set(row)
            M = M.at[k1:, k0:k1].set(row.T)
    return symmetrize(M)
