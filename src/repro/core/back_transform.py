"""TD3/TT4/BT1 — back-transforms from T-space to the generalized problem.

  TD3:  Y := Q Z   (apply factored Householder reflectors — DORMTR)
  TT4:  Y := (Q1 Q2) Z  (single GEMM with the explicitly accumulated Q)
  BT1:  X := U^{-1} Y  (triangular solve — DTRSM)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def back_transform_generalized(U: jax.Array, Y: jax.Array) -> jax.Array:
    """BT1: X = U^{-1} Y, the final map from STDEIG to GSYEIG eigenvectors."""
    return jax.scipy.linalg.solve_triangular(U, Y, trans=0, lower=False)


def forward_transform_generalized(U: jax.Array, X: jax.Array) -> jax.Array:
    """Y = U X (inverse of BT1), used by tests and restart bootstrapping."""
    return jnp.triu(U) @ X
