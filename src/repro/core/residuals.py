"""Accuracy metrics — exactly the two quantities of the paper's Tables 3/7.

  orth  = || I - X^T B X ||_F / || B ||_F
  resid = || A X - B X Lambda ||_F / max(||A||_F, ||B||_F)
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AccuracyReport(NamedTuple):
    b_orthogonality: jax.Array
    relative_residual: jax.Array


def b_orthogonality(X: jax.Array, B: jax.Array) -> jax.Array:
    s = X.shape[1]
    G = X.T @ (B @ X)
    return jnp.linalg.norm(G - jnp.eye(s, dtype=X.dtype)) / jnp.linalg.norm(B)


def relative_residual(A: jax.Array, B: jax.Array, X: jax.Array,
                      lam: jax.Array) -> jax.Array:
    R = A @ X - (B @ X) * lam[None, :]
    denom = jnp.maximum(jnp.linalg.norm(A), jnp.linalg.norm(B))
    return jnp.linalg.norm(R) / denom


def accuracy_report(A: jax.Array, B: jax.Array, X: jax.Array,
                    lam: jax.Array) -> AccuracyReport:
    return AccuracyReport(
        b_orthogonality=b_orthogonality(X, B),
        relative_residual=relative_residual(A, B, X, lam),
    )


def b_normalize(X: jax.Array, B: jax.Array) -> jax.Array:
    """Scale columns of X to unit B-norm (x^T B x = 1)."""
    nrm = jnp.sqrt(jnp.maximum(jnp.einsum("is,is->s", X, B @ X),
                               jnp.finfo(X.dtype).tiny))
    return X / nrm[None, :]
