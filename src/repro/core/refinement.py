"""fp64 iterative refinement of approximate generalized eigenpairs.

Closes the mixed-precision loop: the reduced-precision pipeline returns
eigenpair estimates of ``A X = B X Lambda`` that are accurate to roughly
the compute dtype's epsilon; this module refines them against the
*original fp64 pencil* until the Table-3 tolerances are met.

The method is correction-form subspace inverse iteration with a single
shared shift and a guard buffer:

  1. pick sigma strictly outside the wanted end of the spectrum and
     factor ``A - sigma B`` ONCE — in fp32 (the classic mixed-precision
     refinement split: the factorization is only a preconditioner, the
     residuals that drive convergence are fp64, so the error contracts
     multiplicatively and the fp32 factor costs half an fp64 LU);
  2. widen the s returned columns with a few random *guard* columns:
     the guards converge to the next-nearest eigenvectors and deflate
     them, moving the per-step contraction of pair i from
     ``|lam_i - sigma| / |lam_{s+1} - sigma|`` to
     ``|lam_i - sigma| / |lam_{q+1} - sigma|`` — decisive when the
     wanted end has tight relative gaps (the MD-like log spectrum);
  3. per step (all fp64 except the triangular solves):
     ``R = A X - B X diag(lam)``, ``X <- X - (A - sigma B)^{-1} R``,
     B-orthonormalize by Cholesky-QR, Rayleigh-Ritz on the fp64 pencil;
  4. stop when ``relative_residual`` and ``b_orthogonality`` (the exact
     Table-3 metrics of ``core.residuals``) are under tolerance on the
     wanted s pairs.

Eigenvalues are corrected quadratically by the Rayleigh-Ritz step, and
near-cluster contamination contributes residual only in proportion to
the (tiny) eigenvalue gap, so the *metrics* converge in a handful of
steps even for the DFT-like clustered spectra.

``refine_eigenpairs`` is the host-loop driver (early exit, trajectory
recording) used by ``gsyeig.solve``; ``refine_eigenpairs_fixed`` is the
traceable fixed-step variant the vmapped ``core.batched`` pipelines
fuse into their compiled programs.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.scipy.linalg import lu_factor, lu_solve, solve_triangular

# the shared Table-3 tolerance (tests/test_accuracy_harness.py asserts
# both metrics against this same value)
REFINE_TOL = 1e-12


def default_guard(s: int, n: int) -> int:
    """Guard-buffer width: enough deflation to matter, still O(s) cost.

    Sized ~3x the wanted count: on the MD-like log spectrum each extra
    deflated neighbor improves the per-step contraction by the local
    eigenvalue ratio, and tripling the buffer roughly squares the rate —
    fewer (n^2 q)-cost sweeps beat a narrower q per sweep."""
    return max(0, min(max(8, 3 * s), 32, n - s))


def _sigma(lam, which: str):
    """Shift strictly outside the wanted end of the spectrum.

    The margin is half the wanted-set spread plus a scale-aware floor so
    an eigenvalue-estimate error cannot land sigma on top of a true
    eigenvalue (a singular factorization). Estimates from a demoted
    pipeline can be off by ~eps_compute * ||C|| in absolute terms, which
    makes the *initial* sigma far from the wanted end and the contraction
    slow — the host driver below re-shifts and refactors as soon as the
    Rayleigh-Ritz values (which converge much faster than the vectors)
    imply a materially better shift.
    """
    lo, hi = jnp.min(lam), jnp.max(lam)
    scale = jnp.maximum(jnp.abs(lo), jnp.abs(hi))
    # keep the margin SMALL relative to the wanted-set spread: when the
    # wanted end spans a wide range (the log-spectrum largest end) the
    # contraction ratio degrades with every unit of shift-to-end distance,
    # and a shift that drifts slightly inside the spectrum is harmless —
    # the nearest eigenpairs are exactly the wanted + guarded ones
    margin = 0.05 * (hi - lo) + 0.01 * scale
    margin = jnp.maximum(margin, 1e-6 * (1.0 + scale))
    if which == "smallest":
        return lo - margin
    return hi + margin


@jax.jit
def _factor_f32(A, B, sigma):
    """fp32 LU of the shifted pencil (partial pivoting; indefinite is fine)."""
    K = (A - sigma * B).astype(jnp.float32)
    return lu_factor(K)


def _refine_step(lu, piv, A, B, lam, X):
    """One fp64 correction + Cholesky-QR B-orthonormalization + RR step."""
    R = A @ X - (B @ X) * lam[None, :]
    D = lu_solve((lu, piv), R.astype(jnp.float32)).astype(jnp.float64)
    Y = X - D
    # column equilibration before the Gram matrix (the inverse-iteration
    # map amplifies near-shift directions; keep the Cholesky-QR tame)
    Y = Y / jnp.maximum(jnp.linalg.norm(Y, axis=0), jnp.finfo(Y.dtype).tiny)
    G = Y.T @ (B @ Y)
    G = 0.5 * (G + G.T)
    L = jnp.linalg.cholesky(G)
    Z = solve_triangular(L, Y.T, lower=True).T
    H = Z.T @ (A @ Z)
    H = 0.5 * (H + H.T)
    lam, S = jnp.linalg.eigh(H)
    return lam, Z @ S


_jit_refine_step = jax.jit(_refine_step)


def _select(lam, X, s: int, which: str):
    """The wanted s of the q refined pairs (RR order is ascending)."""
    if which == "smallest":
        return lam[:s], X[:, :s]
    return lam[-s:], X[:, -s:]


@partial(jax.jit, static_argnames=("s", "which"))
def _metrics(A, B, lam, X, s: int, which: str):
    from .residuals import b_orthogonality, relative_residual
    lam_s, X_s = _select(lam, X, s, which)
    return (relative_residual(A, B, X_s, lam_s),
            b_orthogonality(X_s, B))


def _with_guards(lam, X, guard: int, which: str, key):
    """Append `guard` random columns (and end-value Ritz placeholders —
    the correction step's per-column shift only scales the column, so any
    finite value works; the first RR replaces them)."""
    if guard <= 0:
        return lam, X
    n = X.shape[0]
    G = jax.random.normal(key, (n, guard), X.dtype)
    G = G / jnp.linalg.norm(G, axis=0)
    end = lam[0] if which == "largest" else lam[-1]
    pad = jnp.full((guard,), end, lam.dtype)
    if which == "largest":
        return jnp.concatenate([pad, lam]), jnp.concatenate([G, X], axis=1)
    return jnp.concatenate([lam, pad]), jnp.concatenate([X, G], axis=1)


def refine_eigenpairs(
    A: jax.Array,
    B: jax.Array,
    lam: jax.Array,
    X: jax.Array,
    which: str = "smallest",
    *,
    tol: float = REFINE_TOL,
    max_steps: int = 60,
    guard: int | None = None,
    key: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array, Dict[str, Any]]:
    """Refine (lam, X) against the fp64 pencil until Table-3 tolerances.

    Returns ``(lam, X, info)`` with ``info`` recording the step count and
    the full residual / B-orthogonality trajectories (index 0 is the
    unrefined input) — this is what lands in ``result.info['refinement']``.
    """
    A = jnp.asarray(A, jnp.float64)
    B = jnp.asarray(B, jnp.float64)
    lam = jnp.asarray(lam, jnp.float64)
    X = jnp.asarray(X, jnp.float64)
    s = X.shape[1]
    if guard is None:
        guard = default_guard(s, A.shape[0])
    if key is None:
        key = jax.random.PRNGKey(1203)

    sigma = float(_sigma(lam, which))
    lu, piv = _factor_f32(A, B, sigma)

    resid, orth = _metrics(A, B, lam, X, s=s, which="smallest")
    resid_traj = [float(resid)]
    orth_traj = [float(orth)]
    lam_q, X_q = _with_guards(lam, X, guard, which, key)
    steps = 0
    stalled = 0
    refactors = 0
    sigmas = [sigma]
    finite = True
    while (resid_traj[-1] > tol or orth_traj[-1] > tol) and steps < max_steps:
        lam_new, X_new = _jit_refine_step(lu, piv, A, B, lam_q, X_q)
        resid, orth = _metrics(A, B, lam_new, X_new, s=s, which=which)
        r, o = float(resid), float(orth)
        if not (np.isfinite(r) and np.isfinite(o)):
            finite = False
            break                      # degenerate input; keep the last good
        lam_q, X_q = lam_new, X_new
        resid_traj.append(r)
        orth_traj.append(o)
        steps += 1
        if r <= tol and o <= tol:
            break
        lam_s, _ = _select(lam_q, X_q, s, which)
        end = float(lam_s[0] if which == "smallest" else lam_s[-1])
        sig2 = float(_sigma(lam_s, which))
        if (refactors < 3
                and abs(sig2 - sigma) > 0.25 * abs(end - sigma)):
            # the Ritz values moved enough that a fresh shift contracts
            # materially faster — refactor (another half-fp64-LU, cheap
            # next to the steps it saves)
            sigma = sig2
            lu, piv = _factor_f32(A, B, sigma)
            sigmas.append(sigma)
            refactors += 1
            stalled = 0
            continue
        # three consecutive non-improving steps means we are at the fp64
        # attainable floor (or the shift cannot contract further) — stop
        # rather than spin
        stalled = stalled + 1 if r >= 0.95 * resid_traj[-2] else 0
        if stalled >= 3:
            break

    if steps > 0:
        lam, X = _select(lam_q, X_q, s, which)
    info = {
        "steps": steps,
        "sigma": sigmas,
        "guard": int(guard),
        "tol": float(tol),
        "converged": bool(resid_traj[-1] <= tol and orth_traj[-1] <= tol),
        "relative_residual": resid_traj,
        "b_orthogonality": orth_traj,
        # the degradation ladder's inputs (resilience.recovery): a stall
        # above tolerance on a demoted pipeline escalates to fp64, a
        # non-finite trajectory is a diagnosed health failure
        "stalled": bool(stalled >= 3),
        "finite": bool(finite),
    }
    return lam, X, info


@partial(jax.jit, static_argnames=("which", "steps", "guard"))
def refine_eigenpairs_fixed(
    A: jax.Array,
    B: jax.Array,
    lam: jax.Array,
    X: jax.Array,
    which: str = "smallest",
    steps: int = 2,
    guard: int = 0,
    key: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Traceable fixed-step refinement for the vmapped batched pipelines.

    No convergence test (the step count is part of the pipeline cache
    key); otherwise identical arithmetic to ``refine_eigenpairs``.
    """
    A = A.astype(jnp.float64)
    B = B.astype(jnp.float64)
    lam = lam.astype(jnp.float64)
    X = X.astype(jnp.float64)
    if steps == 0:
        return lam, X
    s = X.shape[1]
    if key is None:
        key = jax.random.PRNGKey(1203)
    lam_q, X_q = _with_guards(lam, X, guard, which, key)

    # phases of two steps with a re-shift (and fp32 refactor) in between:
    # each pair of RR sweeps sharpens the (possibly demoted-pipeline)
    # eigenvalue estimates enough that the next factorization's shift sits
    # materially closer to the wanted end — the traceable analogue of the
    # host driver's adaptive refactor loop
    first = True
    remaining = steps
    while remaining > 0:
        phase_steps = min(2, remaining)
        remaining -= phase_steps
        anchor = lam if first else _select(lam_q, X_q, s, which)[0]
        first = False
        sigma = _sigma(anchor, which)
        lu, piv = lu_factor((A - sigma * B).astype(jnp.float32))

        def body(_, carry, lu=lu, piv=piv):
            lam_q, X_q = carry
            return _refine_step(lu, piv, A, B, lam_q, X_q)

        lam_q, X_q = jax.lax.fori_loop(0, phase_steps, body, (lam_q, X_q))
    return _select(lam_q, X_q, s, which)
