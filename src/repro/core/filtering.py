"""Chebyshev polynomial filtering of the Lanczos starting block.

The classic ChebFSI accelerator (Zhou, Saad, Tiago, Chelikowsky) adapted to
the KE/KI pipeline: clustered DFT-like spectra stall the plain restart loop
because the wanted cluster's Ritz separation is tiny, so before iterating we
damp the *unwanted* end of the spectrum with a degree-d Chebyshev polynomial
of the operator applied to the (n, p) starting block. Everything here is
traceable JAX (static degree / probe length), so the mesh path can fuse
probe + filter into ONE shard_map-ped program (see
``repro.dist.eigensolver.ke_prep_program``) and the batched path can inline
it into ``lanczos_solve_jit``.

Spectral bounds come from a k-step single-vector Lanczos probe: with Ritz
values theta_1 <= ... <= theta_k and last residual norm beta_k, the
safeguarded interval [theta_1 - beta_k, theta_k + beta_k] encloses the
spectrum up to the probe's accuracy (the standard safeguard — a Gershgorin
bound would need the assembled C, which the KI variant never forms). The
filter cutoff splits wanted from damped at the probe's s-th Ritz value.

Scaling uses the three-term *sigma* recurrence so iterates stay O(1) at the
wanted end instead of growing like cosh(d * acosh(t)) — degrees of 50+ stay
finite even on the inverse-pair spectra whose |lambda| spans 1e4.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def probe_steps(s: int, n: int) -> int:
    """Length of the bound-estimation Lanczos probe: enough Ritz values to
    place the cutoff above the s wanted ones, capped by the dimension."""
    return int(min(max(2 * s, 12), n - 1))


def estimate_bounds(matvec, v: jax.Array, k: int):
    """k-step single-vector Lanczos probe -> (theta (k,) ascending, beta_k).

    ``matvec`` takes (n, p) blocks (p=1 here). Safeguarded spectrum bounds
    are ``theta[0] - beta_k`` / ``theta[-1] + beta_k``; the interior Ritz
    values seed the filter cutoff. Traceable: one fused fori_loop.
    """
    from .lanczos import _segment_impl  # late import: lanczos imports us

    n = v.shape[0]
    V = jnp.zeros((n, k + 1), v.dtype)
    V = V.at[:, 0].set(v / jnp.linalg.norm(v))
    T = jnp.zeros((k + 1, k + 1), v.dtype)
    V, T, B_q = _segment_impl(matvec, V, T, jnp.asarray(0), p=1)
    theta = jnp.linalg.eigvalsh(0.5 * (T[:k, :k] + T[:k, :k].T))
    return theta, jnp.abs(B_q[0, 0])


def estimate_bounds_jit(matvec, v: jax.Array, k: int):
    """One-dispatch jitted probe for the host-loop driver (per-solve jit,
    like the callable-op segment path)."""
    return jax.jit(partial(estimate_bounds, matvec, k=k))(v)


def filter_interval(theta: jax.Array, beta_k: jax.Array, s: int, which: str):
    """(a, b, a0): damp [a, b], normalize at the wanted-end bound a0.

    which='SA': wanted low end -> damp [cutoff, hi]; 'LA' mirrors it. The
    cutoff is the probe's s-th Ritz value from the wanted end, clipped 5%
    inside the safeguarded interval so the damped window is never empty.
    """
    k = theta.shape[0]
    lo = theta[0] - beta_k
    hi = theta[-1] + beta_k
    margin = 0.05 * (hi - lo)
    if which == "SA":
        cut = jnp.clip(theta[min(s, k - 1)], lo + margin, hi - margin)
        return cut, hi, lo
    cut = jnp.clip(theta[k - 1 - min(s, k - 1)], lo + margin, hi - margin)
    return lo, cut, hi


def chebyshev_filter(matvec, X: jax.Array, degree: int, a, b, a0):
    """Degree-d scaled Chebyshev filter of the block X: damps [a, b],
    amplifies toward a0 (the wanted end). Zhou et al.'s sigma recurrence —
    each iterate is rescaled so its value at a0 stays 1, which keeps the
    amplified components O(1) instead of cosh-growing with the degree.
    ``degree`` is static; the recurrence is a fori_loop of fused matvecs.
    """
    if degree <= 0:
        return X
    e = (b - a) / 2.0
    c = (b + a) / 2.0
    d0 = a0 - c
    # keep the normalization point strictly outside the damped interval
    tiny = jnp.finfo(X.dtype).tiny
    d0 = jnp.where(jnp.abs(d0) < e * 1e-8,
                   jnp.where(d0 < 0, -e * 1e-8, e * 1e-8) + tiny, d0)
    sigma1 = e / d0
    Y = (matvec(X) - c * X) * (sigma1 / e)
    if degree == 1:
        return Y

    def body(_, carry):
        Xp, Yc, sig = carry
        sig_new = 1.0 / (2.0 / sigma1 - sig)
        Yn = (matvec(Yc) - c * Yc) * (2.0 * sig_new / e) - (sig * sig_new) * Xp
        return Yc, Yn, sig_new

    _, Y, _ = jax.lax.fori_loop(1, degree, body, (X, Y, sigma1))
    return Y


def chebyshev_filter_jit(matvec, X: jax.Array, degree: int, a, b, a0):
    """One-dispatch jitted filter application for the host-loop driver."""
    return jax.jit(partial(chebyshev_filter, matvec,
                           degree=degree))(X, a=a, b=b, a0=a0)
