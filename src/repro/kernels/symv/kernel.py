"""Pallas TPU kernel: symmetric mat-vec reading only the UPPER triangle.

The paper's KE1 (CUBLAS/MAGMA DSYMV) is the hot loop of the Krylov solver. On
TPU a symv is HBM-bandwidth-bound (2 flops per element read), so the win the
paper gets from exploiting symmetry in *flops* becomes a win in *bytes* here:
each upper-triangle tile A_ij is streamed through VMEM once and contributes

    y_up[i] += A_ij @ x[j]          (its own row block)
    y_lo[j] += A_ij^T @ x[i]        (the mirrored row block, j > i)

halving HBM traffic vs a dense gemv. The grid enumerates the nb(nb+1)/2
upper-triangle tiles via scalar-prefetched (ib, jb) index arrays
(PrefetchScalarGridSpec), row-major so y_up accumulates contiguously.

VMEM budget per step: bm*bn*4 bytes (tile) + bn*4 + 2*bm*4; with the default
bm = bn = 512 and f32 that is ~1 MiB << 16 MiB v5e VMEM, leaving room for
double buffering. Tile dims are multiples of (8, 128) as the VPU/MXU want.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _symv_kernel(ib, jb, a_ref, xj_ref, xi_ref, yu_ref, yl_ref):
    t = pl.program_id(0)
    i = ib[t]
    j = jb[t]

    a = a_ref[...]
    # the output refs double as cross-tile accumulators; for bf16 operands
    # the wrappers allocate them in fp32 (the MXU accumulator dtype) and
    # preferred_element_type pins every per-tile contraction to match
    acc_t = yu_ref.dtype

    def dot(m, v):
        return jnp.dot(m, v, preferred_element_type=acc_t)

    # --- diagonal tile: only its upper triangle is semantic. Mask in-register
    # and fold in its own mirror: y_up[i] = triu(A_ii) x_i + striu(A_ii)^T x_i.
    # i == j is the first step of each contiguous i-run => acts as the init.
    @pl.when(i == j)
    def _diag():
        rows = jax.lax.broadcasted_iota(jnp.int32, a.shape, 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
        a_up = jnp.where(rows <= cols, a, 0)
        a_strict = jnp.where(rows < cols, a, 0)
        yu_ref[...] = dot(a_up, xj_ref[...]) + dot(a_strict.T, xj_ref[...])

    # --- strictly-upper tile: y_up[i] += A_ij x_j
    @pl.when(j > i)
    def _off():
        yu_ref[...] += dot(a, xj_ref[...])

    # --- mirrored contribution: y_lo[j] += A_ij^T x_i (strictly upper only).
    # Every j-block's first visit is at i == 0 (row-major triangle order), so
    # initialization there covers all blocks, including j == 0 (no strictly-
    # upper tile) which must come out zero.
    @pl.when(i == 0)
    def _init_lo():
        yl_ref[...] = jnp.zeros_like(yl_ref)

    @pl.when(j > i)
    def _acc_lo():
        yl_ref[...] += dot(a.T, xi_ref[...])


def triangle_indices(nb: int):
    """Row-major upper-triangle (i, j >= i) block index arrays."""
    pairs = [(i, j) for i in range(nb) for j in range(i, nb)]
    ib = np.asarray([p[0] for p in pairs], np.int32)
    jb = np.asarray([p[1] for p in pairs], np.int32)
    return ib, jb


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def symv_pallas(A: jax.Array, x: jax.Array, block: int = 512,
                interpret: bool = True) -> jax.Array:
    """y = A x for symmetric A, reading only the upper triangle of A.

    Requires n % block == 0 (ops.py pads). Returns y (n,).
    """
    n = A.shape[0]
    assert n % block == 0, (n, block)
    nb = n // block
    ib, jb = triangle_indices(nb)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(len(ib),),
        in_specs=[
            pl.BlockSpec((block, block), lambda t, ib, jb: (ib[t], jb[t])),
            pl.BlockSpec((block,), lambda t, ib, jb: (jb[t],)),
            pl.BlockSpec((block,), lambda t, ib, jb: (ib[t],)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda t, ib, jb: (ib[t],)),
            pl.BlockSpec((block,), lambda t, ib, jb: (jb[t],)),
        ],
    )
    acc_t = jnp.float32 if A.dtype == jnp.bfloat16 else A.dtype
    y_up, y_lo = pl.pallas_call(
        _symv_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n,), acc_t)] * 2,
        interpret=interpret,
    )(jnp.asarray(ib), jnp.asarray(jb), A, x, x)
    return (y_up + y_lo).astype(A.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def symm_block_pallas(A: jax.Array, X: jax.Array, block: int = 512,
                      interpret: bool = True) -> jax.Array:
    """Y = A X for symmetric A and an (n, p) block of RHS vectors, reading
    only the upper triangle of A — the fused multi-RHS matvec of the block
    Lanczos core (KE1 over a whole s-step block in ONE kernel pass).

    The kernel body is exactly ``_symv_kernel``: every tile contribution is
    a (block, block) @ (block, p) matmul instead of a mat-vec, so the same
    one-triangle streaming halves HBM traffic while the MXU amortizes the
    tile read over p right-hand sides (arithmetic intensity grows p-fold —
    this is what makes the block method compute- rather than
    bandwidth-bound). Requires n % block == 0 (ops.py pads); p rides along
    unblocked (ops.py pads it to the lane granularity on a real TPU).
    """
    n = A.shape[0]
    p = X.shape[1]
    assert n % block == 0, (n, block)
    nb = n // block
    ib, jb = triangle_indices(nb)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(len(ib),),
        in_specs=[
            pl.BlockSpec((block, block), lambda t, ib, jb: (ib[t], jb[t])),
            pl.BlockSpec((block, p), lambda t, ib, jb: (jb[t], 0)),
            pl.BlockSpec((block, p), lambda t, ib, jb: (ib[t], 0)),
        ],
        out_specs=[
            pl.BlockSpec((block, p), lambda t, ib, jb: (ib[t], 0)),
            pl.BlockSpec((block, p), lambda t, ib, jb: (jb[t], 0)),
        ],
    )
    acc_t = jnp.float32 if A.dtype == jnp.bfloat16 else A.dtype
    y_up, y_lo = pl.pallas_call(
        _symv_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n, p), acc_t)] * 2,
        interpret=interpret,
    )(jnp.asarray(ib), jnp.asarray(jb), A, X, X)
    return (y_up + y_lo).astype(A.dtype)
