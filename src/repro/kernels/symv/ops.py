"""Jitted public wrapper for the symv kernel: padding + device dispatch.

On CPU (this container) the kernel body executes in interpret mode — the
Python-level oracle of the TPU lowering. On a real TPU backend set
``interpret=False`` (the default flips automatically).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import symm_block_pallas, symv_pallas
from .ref import symm_block_ref, symv_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block", "force_interpret"))
def symv(A: jax.Array, x: jax.Array, block: int = 256,
         force_interpret: bool | None = None) -> jax.Array:
    """y = A x for symmetric A via the one-triangle Pallas kernel.

    Pads n up to a multiple of `block` (zero padding is exact for symv).
    """
    n = A.shape[0]
    interpret = (not _on_tpu()) if force_interpret is None else force_interpret
    # clamp the pad target to (roughly) the granularity ceiling of n — NOT
    # the old next-power-of-two clamp, which padded e.g. n=300 to 512x512
    # (~70% wasted flops/bytes per matvec). The block must be a multiple of
    # the tile granularity g: 8 sublanes in interpret mode, 128 lanes on a
    # real TPU (kernel.py's (8, 128) MXU tiling). The two modes want
    # opposite objectives:
    #  * interpret: every tile is a Python-level kernel call, so keep the
    #    grid as coarse as the requested block allows (nb tiles) and round
    #    the per-tile size up to g — waste <= g*nb rows. n=300 -> 2 tiles
    #    of 152, 304 padded.
    #  * compiled: grid steps are cheap, padded bytes are the cost — pick
    #    the g-multiple block (<= requested) minimizing the padded size,
    #    ties to the larger block. n=300 -> 3 tiles of 128, 384 padded.
    # (The other wrappers pad to fixed 128-tiles (gemm, syr2k), a divisor
    # of n (band_mv), or min(block, n) (trsm).)
    block = _pick_block(n, block, interpret)
    pad = (-n) % block
    if pad:
        A = jnp.pad(A, ((0, pad), (0, pad)))
        x = jnp.pad(x, (0, pad))
    y = symv_pallas(A, x, block=block, interpret=interpret)
    return y[:n]


def _pick_block(n: int, block: int, interpret: bool) -> int:
    """The symv pad-target heuristic (see the comment above), factored so
    the multi-RHS wrapper shares it verbatim."""
    g = 8 if interpret else 128
    if interpret:
        nb = -(-n // max(g, block))
        per = -(-n // nb)
        return max(g, -(-per // g) * g)
    k_max = max(1, min(block, -(-n // g) * g) // g)
    best_block, best_padded = g, -(-n // g) * g
    for k in range(2, k_max + 1):
        b = g * k
        padded = -(-n // b) * b
        if padded <= best_padded:  # ties -> larger block
            best_block, best_padded = b, padded
    return best_block


@functools.partial(jax.jit, static_argnames=("block", "force_interpret"))
def symm_block(A: jax.Array, X: jax.Array, block: int = 256,
               force_interpret: bool | None = None) -> jax.Array:
    """Y = A X for symmetric A and an (n, p) RHS block via the one-triangle
    Pallas kernel — the block-Lanczos fused matvec (p SYMVs in one pass).

    Pads n up to a block multiple exactly like ``symv``; on a real TPU the
    RHS count p is additionally padded up to the 128-lane granularity
    (interpret mode runs p as-is). Zero padding is exact for the product.
    """
    n = A.shape[0]
    p = X.shape[1]
    interpret = (not _on_tpu()) if force_interpret is None else force_interpret
    blk = _pick_block(n, block, interpret)
    pad = (-n) % blk
    pad_p = 0 if interpret else (-p) % 128
    if pad or pad_p:
        A = jnp.pad(A, ((0, pad), (0, pad)))
        X = jnp.pad(X, ((0, pad), (0, pad_p)))
    Y = symm_block_pallas(A, X, block=blk, interpret=interpret)
    return Y[:n, :p]


__all__ = ["symv", "symm_block", "symv_ref", "symm_block_ref"]
