"""Jitted public wrapper for the symv kernel: padding + device dispatch.

On CPU (this container) the kernel body executes in interpret mode — the
Python-level oracle of the TPU lowering. On a real TPU backend set
``interpret=False`` (the default flips automatically).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import symv_pallas
from .ref import symv_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block", "force_interpret"))
def symv(A: jax.Array, x: jax.Array, block: int = 256,
         force_interpret: bool | None = None) -> jax.Array:
    """y = A x for symmetric A via the one-triangle Pallas kernel.

    Pads n up to a multiple of `block` (zero padding is exact for symv).
    """
    n = A.shape[0]
    interpret = (not _on_tpu()) if force_interpret is None else force_interpret
    block = min(block, max(8, 1 << (n - 1).bit_length()))
    pad = (-n) % block
    if pad:
        A = jnp.pad(A, ((0, pad), (0, pad)))
        x = jnp.pad(x, (0, pad))
    y = symv_pallas(A, x, block=block, interpret=interpret)
    return y[:n]


__all__ = ["symv", "symv_ref"]
