"""Pure-jnp oracle for the symmetric matrix-vector product (KE1 / KI2).

The contract: A is symmetric (only its upper triangle is *semantically*
needed — the kernel reads one triangle; the oracle may read all of it).
"""
import jax.numpy as jnp


def symv_ref(A, x):
    return A @ x


def symv_upper_ref(A, x):
    """Oracle that provably uses only the upper triangle (tests feed garbage
    into the strictly-lower part to verify the kernel's one-triangle claim)."""
    U = jnp.triu(A)
    strict = jnp.triu(A, 1)
    return U @ x + strict.T @ x


def symm_block_ref(A, X):
    """Multi-RHS oracle: Y = A X for an (n, p) block."""
    return A @ X


def symm_block_upper_ref(A, X):
    """One-triangle multi-RHS oracle (mirrors ``symv_upper_ref``)."""
    U = jnp.triu(A)
    strict = jnp.triu(A, 1)
    return U @ X + strict.T @ X
