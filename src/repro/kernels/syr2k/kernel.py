"""Pallas TPU kernel: fused symmetric rank-2k update C += alpha(V W^T + W V^T).

The trailing update of blocked Householder tridiagonalization (TD1) and the
SYR2K step of blocked DSYGST (GS2). Fusing the two outer products means each
C tile makes exactly one HBM round trip per update instead of two — on TPU
the update is bandwidth-bound (2k flops per element at small k), so this
halves its roofline time.

Grid (i, j) over C tiles; V/W panels are (bm, k) with k = panel width (<= 128
in practice — a single MXU face), staying resident per row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _syr2k_kernel(c_ref, vi_ref, wj_ref, wi_ref, vj_ref, o_ref, *, alpha,
                  acc_dtype):
    # sub-fp32 operands accumulate in fp32 on the MXU (acc_dtype pins the
    # accumulator); the store casts back to the storage dtype
    contrib = jnp.dot(vi_ref[...], wj_ref[...].T,
                      preferred_element_type=acc_dtype)
    contrib += jnp.dot(wi_ref[...], vj_ref[...].T,
                       preferred_element_type=acc_dtype)
    acc = c_ref[...].astype(acc_dtype) + alpha * contrib
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "alpha", "interpret"))
def syr2k_pallas(C: jax.Array, V: jax.Array, W: jax.Array,
                 alpha: float = -1.0, bm: int = 256,
                 interpret: bool = True) -> jax.Array:
    """C + alpha (V W^T + W V^T); n % bm == 0 (ops.py pads), k arbitrary.

    bf16 operands take the fp32-accumulating MXU path (result cast back to
    bf16 at the store); fp32/fp64 accumulate in kind.
    """
    n, k = V.shape
    assert C.shape == (n, n) and W.shape == (n, k) and n % bm == 0
    acc_dtype = jnp.float32 if C.dtype == jnp.bfloat16 else C.dtype
    nb = n // bm
    return pl.pallas_call(
        functools.partial(_syr2k_kernel, alpha=alpha, acc_dtype=acc_dtype),
        grid=(nb, nb),
        in_specs=[
            pl.BlockSpec((bm, bm), lambda i, j: (i, j)),
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, k), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), C.dtype),
        interpret=interpret,
    )(C, V, W, W, V)
