"""Jitted public wrapper for the fused SYR2K kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import syr2k_pallas
from .ref import syr2k_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(x: int, b: int) -> int:
    return -(-x // b) * b


@functools.partial(jax.jit, static_argnames=("alpha", "bm", "force_interpret"))
def syr2k(C: jax.Array, V: jax.Array, W: jax.Array, alpha: float = -1.0,
          bm: int = 256, force_interpret: bool | None = None) -> jax.Array:
    """C + alpha (V W^T + W V^T), padding n to the tile size."""
    n, k = V.shape
    interpret = (not _on_tpu()) if force_interpret is None else force_interpret
    bm_ = min(bm, _round_up(n, 8))
    np_ = _round_up(n, bm_)
    pad = np_ - n
    if pad:
        C = jnp.pad(C, ((0, pad), (0, pad)))
        V = jnp.pad(V, ((0, pad), (0, 0)))
        W = jnp.pad(W, ((0, pad), (0, 0)))
    out = syr2k_pallas(C, V, W, alpha=alpha, bm=bm_, interpret=interpret)
    return out[:n, :n]


__all__ = ["syr2k", "syr2k_ref"]
