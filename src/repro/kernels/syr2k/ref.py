"""Pure-jnp oracle for the symmetric rank-2k trailing update (TD1/GS2)."""


def syr2k_ref(C, V, W, alpha=-1.0):
    """C + alpha*(V W^T + W V^T) — the tridiagonalization trailing update."""
    return C + alpha * (V @ W.T + W @ V.T)
