"""Dispatch wrappers for the batched tridiagonal eigensolver (TT3/TD2).

Two execution paths behind one contract:

``tridiag_eig_batched`` — the XLA path every backend gets: bisection and
inverse iteration fused into ONE jitted program, with the Sturm scans
unrolled ``unroll`` rows per step. Unrolling is bitwise-neutral (plain
loop unrolling), so this path returns exactly the values of the legacy
two-program baseline while cutting the scan's per-step loop overhead —
the margin the ``BENCH_tridiag.json --quick`` gate pins at n=2048, s=64.
It is plain traceable jnp, so ``core.batched`` vmaps it into bucket
pipelines and ``dist.eigensolver`` calls it inside ``shard_map``.

``tridiag_eig_kernel`` — the Pallas path: one ``bisect_sturm_pallas``
launch for all indices' intervals and one ``invit_pallas`` launch for all
shifted solves + cluster MGS (interpret mode off-TPU). The ops wrappers
own the padding contract: rows to the sublane multiple (8) with
decoupling pads (Sturm pads sit above the spectrum; solve pads carry
``e = 0`` seams and zero start rows), lanes to 128 with out-of-band
cluster ids and zero start columns.

Like ``kernels/house_panel``: ``force_kernel=True`` exercises the Pallas
path off-TPU (interpret mode unless ``force_interpret=False``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.linalg_utils import gershgorin_bounds
from repro.core.tridiag_eig import (_cluster_ids, _pivmin, bisect_eigenvalues,
                                    inverse_iteration)
from .kernel import bisect_sturm_pallas, invit_pallas

#: Sturm-scan unroll of the fused XLA path — measured sweet spot on host
#: backends (per-step loop overhead amortized over 16 rows; larger factors
#: start losing to instruction-cache pressure).
SCAN_UNROLL = 16


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_up(k: int, mult: int) -> int:
    return k + (-k) % mult


# ------------------------------------------------------------ fused XLA --

@functools.partial(jax.jit, static_argnames=("max_iters", "iters", "unroll"))
def tridiag_eig_batched(d: jax.Array, e: jax.Array, ks: jax.Array,
                        key: jax.Array, max_iters: int = 80, iters: int = 3,
                        unroll: int = SCAN_UNROLL):
    """ONE fused program: unrolled Sturm bisection + inverse iteration.

    ``ks`` must be sorted ascending (``eigh_tridiag_selected`` owns the
    sort-and-restore). Bitwise-equal to the 'scan' baseline — ``unroll``
    only changes how many recurrence rows share a loop iteration.
    """
    lam = bisect_eigenvalues(d, e, ks, max_iters=max_iters, unroll=unroll)
    Z = inverse_iteration(d, e, lam, key, iters=iters)
    return lam, Z


# --------------------------------------------------------- Pallas launch --

def bisect_sturm(d: jax.Array, e: jax.Array, ks: jax.Array,
                 max_iters: int = 80, force_kernel: bool = False,
                 force_interpret: bool | None = None) -> jax.Array:
    """Eigenvalues at indices ``ks`` — Pallas kernel on TPU, unrolled XLA
    scan elsewhere. Both agree bitwise with ``bisect_sturm_ref``."""
    use_kernel = force_kernel or _on_tpu()
    if not use_kernel:
        return bisect_eigenvalues(d, e, ks, max_iters=max_iters,
                                  unroll=SCAN_UNROLL)
    interpret = (not _on_tpu()) if force_interpret is None else force_interpret
    n, s = d.shape[0], ks.shape[0]
    N, S = _pad_up(n, 8), _pad_up(s, 128)
    lo0, hi0 = gershgorin_bounds(d, e)
    piv = _pivmin(d, e)
    e2 = jnp.concatenate([jnp.zeros((1,), d.dtype), e * e])
    # pad rows sit strictly above every probed shift (x <= hi0), with a
    # zero e2 seam: their Sturm terms stay positive and count nothing
    d_pad = jnp.concatenate([d, jnp.full((N - n,), hi0 + 1.0, d.dtype)])
    e2_pad = jnp.concatenate([e2, jnp.zeros((N - n,), d.dtype)])
    ks_pad = jnp.concatenate([ks.astype(jnp.int32),
                              jnp.zeros((S - s,), jnp.int32)])
    lam = bisect_sturm_pallas(
        d_pad[:, None], e2_pad[:, None], ks_pad[None, :],
        jnp.full((1, S), lo0, d.dtype), jnp.full((1, S), hi0, d.dtype),
        jnp.full((1, S), piv, d.dtype), max_iters=max_iters,
        interpret=interpret)
    return lam[0, :s]


def invit_batched(d: jax.Array, e: jax.Array, lam: jax.Array,
                  key: jax.Array, iters: int = 3,
                  force_kernel: bool = False,
                  force_interpret: bool | None = None) -> jax.Array:
    """Eigenvectors for SORTED shifts ``lam`` — Pallas kernel on TPU,
    the vmapped-scan LU elsewhere."""
    use_kernel = force_kernel or _on_tpu()
    if not use_kernel:
        return inverse_iteration(d, e, lam, key, iters=iters)
    interpret = (not _on_tpu()) if force_interpret is None else force_interpret
    n, s = d.shape[0], lam.shape[0]
    N, S = _pad_up(n, 8), _pad_up(s, 128)
    scale = jnp.maximum(jnp.max(jnp.abs(d)),
                        jnp.max(jnp.abs(e)) if e.size else 0.0)
    cid = _cluster_ids(lam, scale)
    piv = _pivmin(d, e)
    X0 = jax.random.normal(key, (n, s), d.dtype)
    X0 = X0 / jnp.linalg.norm(X0, axis=0, keepdims=True)
    d_pad = jnp.concatenate([d, jnp.ones((N - n,), d.dtype)])
    # e_pad[i] couples rows i and i+1; zeros from row n-1 on decouple the
    # padding block entirely (its solve rows start and stay zero)
    e_pad = jnp.zeros((N,), d.dtype).at[:n - 1].set(e) if n > 1 \
        else jnp.zeros((N,), d.dtype)
    lam_pad = jnp.concatenate([lam, jnp.full((S - s,), lam[-1], d.dtype)])
    cid_pad = jnp.concatenate([cid, s + jnp.arange(S - s, dtype=jnp.int32)])
    X0_pad = jnp.zeros((N, S), d.dtype).at[:n, :s].set(X0)
    Z = invit_pallas(d_pad[:, None], e_pad[:, None], lam_pad[None, :],
                     cid_pad[None, :], jnp.full((1, S), piv, d.dtype),
                     X0_pad, iters=iters, interpret=interpret)
    return Z[:n, :s]


def tridiag_eig_kernel(d: jax.Array, e: jax.Array, ks: jax.Array,
                       key: jax.Array, max_iters: int = 80, iters: int = 3,
                       force_interpret: bool | None = None):
    """Full TT3 through the two Pallas launches (interpret off-TPU)."""
    lam = bisect_sturm(d, e, ks, max_iters=max_iters, force_kernel=True,
                       force_interpret=force_interpret)
    Z = invit_batched(d, e, lam, key, iters=iters, force_kernel=True,
                      force_interpret=force_interpret)
    return lam, Z


__all__ = ["tridiag_eig_batched", "tridiag_eig_kernel", "bisect_sturm",
           "invit_batched", "SCAN_UNROLL"]
