"""Pure-jnp oracles for the batched tridiagonal eigensolver kernels.

``bisect_sturm_ref`` IS ``core.tridiag_eig.bisect_eigenvalues`` — the
interpret-mode parity tests pin the Pallas bisection kernel against the
exact interval sequence of the production scan (same Gershgorin start, same
``mid = 0.5 (lo + hi)`` splits, same pivmin-clamped Sturm recurrence), so a
kernel that drifts by even one count fails bitwise. ``invit_ref`` likewise
delegates to ``core.tridiag_eig.inverse_iteration`` (pivoted tridiagonal LU
per shift + cluster-masked MGS); the kernel's reductions may reassociate,
so the inverse-iteration parity bars are tight allclose, not bitwise.

Both oracles are plain traceable jnp — they drop into ``vmap``/``jit``
(``core.batched`` buckets) and ``shard_map`` regions (the distributed TT3
of ``dist.eigensolver``) unchanged.
"""
from __future__ import annotations

import jax

from repro.core.tridiag_eig import bisect_eigenvalues, inverse_iteration


def bisect_sturm_ref(d: jax.Array, e: jax.Array, ks: jax.Array,
                     max_iters: int = 80) -> jax.Array:
    """Eigenvalues of tridiag(d, e) at indices ``ks`` by Sturm bisection.

    Bitwise-equal to ``core.tridiag_eig.bisect_eigenvalues`` by
    construction (it is the same function).
    """
    return bisect_eigenvalues(d, e, ks, max_iters=max_iters)


def invit_ref(d: jax.Array, e: jax.Array, lam: jax.Array, key: jax.Array,
              iters: int = 3) -> jax.Array:
    """Eigenvectors for sorted shifts ``lam``: shifted inverse iteration
    with DGTTRF-style pivoted LU and DSTEIN-style cluster-wise MGS."""
    return inverse_iteration(d, e, lam, key, iters=iters)


__all__ = ["bisect_sturm_ref", "invit_ref"]
