"""Pallas TPU kernels: batched Sturm bisection + shifted inverse iteration.

TT3's two halves, each as ONE kernel launch over VMEM-resident state:

``bisect_sturm_pallas`` advances ALL wanted indices' intervals together —
the (lo, hi) interval state is a pair of (1, S) lane vectors carried
through a ``fori_loop`` of bisection sweeps, and every sweep runs the
pivmin-clamped Sturm recurrence down the (N, 1) diagonal columns once,
vectorized across the index lane. The iteration count is static, the
splits are ``0.5 (lo + hi)``, and the recurrence is the same op sequence
as ``core.tridiag_eig.sturm_count`` — interpret mode reproduces the
``bisect_sturm_ref`` oracle bitwise.

``invit_pallas`` factors and solves all S shifted tridiagonal systems per
sweep in one launch: the DGTTRF partial-pivoting recurrence and the
forward substitution share a single row loop (carry = current pivot row,
lane-vectorized over shifts; D/DU/DU2 and the permuted RHS land in VMEM
scratch), a reversed row loop back-substitutes, and the DSTEIN-style
cluster-wise MGS runs over the column lanes with iota masks — the
``house_panel`` trick: no dynamic lane indexing anywhere, each column is
extracted by a masked reduction.

Padding contract (the ops wrapper enforces it): rows to the sublane
multiple with ``e = 0`` on the seam (padded rows decouple — their Sturm
terms are positive and their solve rows carry zeros), lanes to 128 with
out-of-band cluster ids and zero start vectors, so padded lanes never mix
into real columns.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _clamp(q, piv):
    """Pivmin clamp away from zero, sign-preserving (DSTEBZ / DGTTRF)."""
    return jnp.where(jnp.abs(q) < piv, jnp.where(q < 0, -piv, piv), q)


# ------------------------------------------------------------- bisection --

def _bisect_kernel(d_ref, e2_ref, ks_ref, lo_ref, hi_ref, piv_ref, lam_ref,
                   *, max_iters: int):
    N = d_ref.shape[0]
    ks = ks_ref[...]
    piv = piv_ref[...]

    def sweep(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)

        def row(i, qc):
            q, cnt = qc
            di = d_ref[pl.ds(i, 1), :]    # (1, 1)
            ei2 = e2_ref[pl.ds(i, 1), :]  # (1, 1)
            q_new = (di - mid) - ei2 / _clamp(q, piv)
            return q_new, cnt + (q_new < 0).astype(jnp.int32)

        q0 = jnp.ones(mid.shape, mid.dtype)
        c0 = jnp.zeros(mid.shape, jnp.int32)
        _, cnt = jax.lax.fori_loop(0, N, row, (q0, c0))
        go_right = cnt <= ks
        return jnp.where(go_right, mid, lo), jnp.where(go_right, hi, mid)

    lo, hi = jax.lax.fori_loop(0, max_iters, sweep, (lo_ref[...], hi_ref[...]))
    lam_ref[...] = 0.5 * (lo + hi)


@functools.partial(jax.jit, static_argnames=("max_iters", "interpret"))
def bisect_sturm_pallas(d2, e22, ks2, lo2, hi2, piv2,
                        max_iters: int = 80, interpret: bool = True):
    """All-indices Sturm bisection in ONE kernel launch.

    d2/e22: (N, 1) diagonal and squared off-diagonal (e2[0] = 0, padded
    rows decoupled); ks2: (1, S) int32 wanted indices; lo2/hi2: (1, S)
    initial Gershgorin intervals; piv2: (1, S) broadcast pivmin.
    Returns lam (1, S).
    """
    N, _ = d2.shape
    S = ks2.shape[1]
    return pl.pallas_call(
        functools.partial(_bisect_kernel, max_iters=max_iters),
        in_specs=[pl.BlockSpec((N, 1), lambda: (0, 0)),
                  pl.BlockSpec((N, 1), lambda: (0, 0)),
                  pl.BlockSpec((1, S), lambda: (0, 0)),
                  pl.BlockSpec((1, S), lambda: (0, 0)),
                  pl.BlockSpec((1, S), lambda: (0, 0)),
                  pl.BlockSpec((1, S), lambda: (0, 0))],
        out_specs=pl.BlockSpec((1, S), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, S), d2.dtype),
        interpret=interpret,
    )(d2, e22, ks2, lo2, hi2, piv2)


# ----------------------------------------------------- inverse iteration --

def _invit_kernel(d_ref, e_ref, lam_ref, cid_ref, piv_ref, x0_ref, z_ref,
                  dscr, duscr, du2scr, yscr, *, iters: int):
    N, S = x0_ref.shape
    dtype = x0_ref.dtype
    lam = lam_ref[...]
    cid = cid_ref[...]
    piv = piv_ref[...]
    tiny = jnp.finfo(dtype).tiny
    lanes1 = jax.lax.broadcasted_iota(jnp.int32, (1, S), 1)
    lanesN = jax.lax.broadcasted_iota(jnp.int32, (N, S), 1)

    z_ref[...] = x0_ref[...]

    def one_round(_, carry):
        # --- DGTTRF factorization fused with the forward substitution:
        # one row loop, carry = (current pivot, current superdiag, current
        # rhs), each lane its own shifted system T - lam_j I.
        def fact_fwd(i, state):
            dcur, ducur, bcur = state
            dl_i = e_ref[pl.ds(i, 1), :]                    # (1, 1)
            dnext = d_ref[pl.ds(i + 1, 1), :] - lam         # (1, S)
            dunext = e_ref[pl.ds(i + 1, 1), :]              # (1, 1)
            b_next = z_ref[pl.ds(i + 1, 1), :]              # (1, S)
            no_swap = jnp.abs(dcur) >= jnp.abs(dl_i)
            fact_ns = dl_i / _clamp(dcur, piv)
            fact_sw = dcur / _clamp(dl_i, piv)
            dscr[pl.ds(i, 1), :] = jnp.where(no_swap, dcur, dl_i)
            duscr[pl.ds(i, 1), :] = jnp.where(no_swap, ducur, dnext)
            du2scr[pl.ds(i, 1), :] = jnp.where(no_swap, 0.0, dunext)
            L_i = jnp.where(no_swap, fact_ns, fact_sw)
            dcur_new = jnp.where(no_swap, dnext - fact_ns * ducur,
                                 ducur - fact_sw * dnext)
            ducur_new = jnp.where(no_swap, dunext, -fact_sw * dunext)
            yscr[pl.ds(i, 1), :] = jnp.where(no_swap, bcur, b_next)
            bcur_new = jnp.where(no_swap, b_next - L_i * bcur,
                                 bcur - L_i * b_next)
            return dcur_new, ducur_new, bcur_new

        d0 = d_ref[pl.ds(0, 1), :] - lam
        du0 = jnp.broadcast_to(e_ref[pl.ds(0, 1), :], (1, S)).astype(dtype)
        b0 = z_ref[pl.ds(0, 1), :]
        d_last, _, b_last = jax.lax.fori_loop(0, N - 1, fact_fwd,
                                              (d0, du0, b0))
        dscr[pl.ds(N - 1, 1), :] = d_last
        duscr[pl.ds(N - 1, 1), :] = jnp.zeros((1, S), dtype)
        du2scr[pl.ds(N - 1, 1), :] = jnp.zeros((1, S), dtype)
        yscr[pl.ds(N - 1, 1), :] = b_last

        # --- back substitution, reversed row loop
        def back(j, x12):
            x1, x2 = x12
            i = N - 1 - j
            y_i = yscr[pl.ds(i, 1), :]
            du_i = duscr[pl.ds(i, 1), :]
            du2_i = du2scr[pl.ds(i, 1), :]
            ds_i = _clamp(dscr[pl.ds(i, 1), :], piv)
            x_i = (y_i - du_i * x1 - du2_i * x2) / ds_i
            z_ref[pl.ds(i, 1), :] = x_i
            return x_i, x1

        zero = jnp.zeros((1, S), dtype)
        jax.lax.fori_loop(0, N, back, (zero, zero))

        # --- column normalization + cluster-wise MGS over the lanes.
        # Norms are max-abs rescaled: a solve at a converged shift returns
        # columns at the 1/pivmin scale (~1e292 in f64), whose naive
        # sum-of-squares overflows — jnp.linalg.norm rescales too.
        X = z_ref[...]
        m = jnp.maximum(jnp.max(jnp.abs(X), axis=0, keepdims=True), tiny)
        Xs = X / m
        norms = m * jnp.sqrt(jnp.sum(Xs * Xs, axis=0, keepdims=True))
        X = X / jnp.maximum(norms, tiny)

        def mgs(i, X):
            ci = jnp.sum(jnp.where(lanes1 == i, cid, 0))
            mask = ((lanes1 < i) & (cid == ci)).astype(dtype)
            xi = jnp.sum(jnp.where(lanesN == i, X, 0.0), axis=1,
                         keepdims=True)                       # (N, 1)
            coeff = jnp.sum(X * xi, axis=0, keepdims=True) * mask
            xi = xi - jnp.sum(X * coeff, axis=1, keepdims=True)
            mi = jnp.maximum(jnp.max(jnp.abs(xi)), tiny)
            nrm = mi * jnp.sqrt(jnp.sum((xi / mi) * (xi / mi)))
            xi = xi / jnp.maximum(nrm, tiny)
            return jnp.where(lanesN == i, xi, X)

        X = jax.lax.fori_loop(1, S, mgs, X)
        z_ref[...] = X
        return carry

    jax.lax.fori_loop(0, iters, one_round, 0)


@functools.partial(jax.jit, static_argnames=("iters", "interpret"))
def invit_pallas(d2, e2, lam2, cid2, piv2, X0,
                 iters: int = 3, interpret: bool = True):
    """All-shifts inverse iteration in ONE kernel launch.

    d2: (N, 1) diagonal; e2: (N, 1) off-diagonal padded with zeros (e2[i]
    couples rows i and i+1); lam2: (1, S) SORTED shifts; cid2: (1, S)
    int32 cluster ids (padded lanes unique); piv2: (1, S) broadcast
    pivmin; X0: (N, S) column-normalized start block (padded rows/lanes
    zero). Returns Z (N, S).
    """
    N, S = X0.shape
    dtype = X0.dtype
    return pl.pallas_call(
        functools.partial(_invit_kernel, iters=iters),
        in_specs=[pl.BlockSpec((N, 1), lambda: (0, 0)),
                  pl.BlockSpec((N, 1), lambda: (0, 0)),
                  pl.BlockSpec((1, S), lambda: (0, 0)),
                  pl.BlockSpec((1, S), lambda: (0, 0)),
                  pl.BlockSpec((1, S), lambda: (0, 0)),
                  pl.BlockSpec((N, S), lambda: (0, 0))],
        out_specs=pl.BlockSpec((N, S), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((N, S), dtype),
        scratch_shapes=[pltpu.VMEM((N, S), dtype) for _ in range(4)],
        interpret=interpret,
    )(d2, e2, lam2, cid2, piv2, X0)
