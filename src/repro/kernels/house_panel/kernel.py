"""Pallas TPU kernel: fused compact-WY panel factorization (DGEQRT analogue).

One launch factors a whole (rows, b) band-reduction panel into (V, T): the
b Householder reflectors, the in-panel trailing updates, and the T-matrix
recurrence all run over a single VMEM-resident panel instead of issuing
b reflector-sized XLA ops per panel. This is the stage-1 companion of
``kernels/rot_apply``: the band reduction's panel QR becomes one kernel
launch, so the full sweep is O(1) dispatches end to end.

Layout: the panel rides in as one (P, b) block (P = rows padded to the
sublane multiple, b = bandwidth <= 128 — a single lane face, like the
(bm, k) panels of ``kernels/syr2k``). ``row_start`` is a scalar in SMEM:
reflector j pivots at global row ``row_start + j`` and the masks below are
how the kernel stays fixed-shape for every panel of the sweep (the pivot
is traced, the shapes never change). The reflector loop is unrolled at
trace time (b is static), every step a handful of (P, b)/(b, b) VPU/MXU
ops — no dynamic column indexing anywhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _house_panel_kernel(rs_ref, e_ref, v_ref, t_ref):
    P, b = e_ref.shape
    # reflector norms/taus are far too cancellation-sensitive for bf16:
    # a bf16 panel computes in fp32 (the MXU-accumulator dtype) and casts
    # V/T back at the store; fp32/fp64 panels compute in kind
    dtype = (jnp.float32 if e_ref.dtype == jnp.bfloat16 else e_ref.dtype)
    rs = rs_ref[0]
    R = e_ref[...].astype(dtype)
    V = jnp.zeros((P, b), dtype)
    T = jnp.zeros((b, b), dtype)
    rows = jax.lax.broadcasted_iota(jnp.int32, (P, 1), 0)
    colsP = jax.lax.broadcasted_iota(jnp.int32, (P, b), 1)
    rows_b = jax.lax.broadcasted_iota(jnp.int32, (b, b), 0)
    cols_b = jax.lax.broadcasted_iota(jnp.int32, (b, b), 1)
    one = jnp.ones((), dtype)

    for j in range(b):
        pivot = rs + j
        x = jnp.sum(jnp.where(colsP == j, R, 0.0), axis=1, keepdims=True)
        xm = jnp.where(rows >= pivot, x, 0.0)
        alpha = jnp.sum(jnp.where(rows == pivot, x, 0.0))
        sigma = jnp.maximum(jnp.sum(xm * xm) - alpha * alpha, 0.0)
        safe = sigma > 0.0
        norm_x = jnp.sqrt(alpha * alpha + sigma)
        sgn = jnp.where(alpha >= 0.0, one, -one)
        beta = jnp.where(safe, -sgn * norm_x, alpha)
        denom = jnp.where(safe, alpha - beta, one)
        tau = jnp.where(safe, (beta - alpha) / jnp.where(safe, beta, one),
                        0.0)
        # v: zeros above the pivot, 1 at it, xm/denom below (identity
        # reflector when the tail is numerically zero, tau = 0)
        v = jnp.where(rows > pivot, xm / denom, 0.0)
        v = jnp.where(rows == pivot, one, v)
        v = jnp.where(safe, v, jnp.where(rows == pivot, one, 0.0))
        # trailing update of the panel: R -= tau v (v^T R)
        proj = jnp.sum(v * R, axis=0, keepdims=True)          # (1, b)
        R = R - tau * (v * proj)
        # T recurrence: T[:j, j] = -tau T[:j, :j] (V^T v); T[j, j] = tau.
        # V/T only hold columns < j, so full-width masked products equal
        # the sliced ones.
        z = jnp.sum(V * v, axis=0)                            # (b,)
        tcol = -tau * jax.lax.dot(T, z[:, None],
                                  preferred_element_type=dtype)  # (b, 1)
        T = jnp.where(cols_b == j, tcol, T)
        T = jnp.where((rows_b == j) & (cols_b == j), tau, T)
        V = jnp.where(colsP == j, v, V)

    v_ref[...] = V.astype(v_ref.dtype)
    t_ref[...] = T.astype(t_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def house_panel_pallas(E: jax.Array, row_start: jax.Array,
                       interpret: bool = True):
    """Factor E[row_start:, :] into compact-WY (V, T) in ONE kernel launch.

    E is (P, b) with P a sublane multiple (the ops wrapper pads);
    ``row_start`` is a (1,) int32. Returns (V (P, b), T (b, b)).
    """
    P, b = E.shape
    return pl.pallas_call(
        _house_panel_kernel,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((P, b), lambda: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((P, b), lambda: (0, 0)),
            pl.BlockSpec((b, b), lambda: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((P, b), E.dtype),
            jax.ShapeDtypeStruct((b, b), E.dtype),
        ],
        interpret=interpret,
    )(row_start, E)
