"""Public wrapper for the fused compact-WY panel factorization.

``house_panel`` is the stage-1 panel unit of the band reduction: a whole
(rows, b) panel goes to compact-WY form (V, T) in ONE device operation. On
TPU it lowers to the Pallas kernel (panel resident in VMEM, reflector loop
unrolled); elsewhere it falls back to the identical pure-jnp expression, so
the panel sweep stays a single traceable program on every backend —
including inside ``lax.fori_loop`` bodies (``row_start`` may be traced),
under ``vmap`` in ``core.batched``, and inside the ``shard_map``-ped
distributed sweep of ``dist.sharded_la``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import house_panel_pallas
from .ref import house_panel_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def house_panel(E: jax.Array, row_start,
                force_kernel: bool = False,
                force_interpret: bool | None = None):
    """Compact-WY factorization of E[row_start:, :] — returns (V, T).

    E: (rows, b) full-height panel; reflector j pivots at row
    ``row_start + j`` (traced ok) and rows above pass through untouched.
    V is (rows, b) with zeros above each pivot, T is (b, b) upper
    triangular; Q = I - V T V^T. Pivots past the panel end (the rows < b
    tail panel) yield identity reflectors (tau = 0).

    Dispatches to the Pallas kernel on TPU (or when ``force_kernel=True``,
    using interpret mode off-TPU); otherwise the pure-jnp oracle. Rows are
    padded to the sublane multiple internally.
    """
    use_kernel = force_kernel or _on_tpu()
    if not use_kernel:
        if E.dtype == jnp.bfloat16:
            # mirror the kernel's fp32-accumulating bf16 path: reflector
            # norms/taus cancel too hard for bf16 arithmetic
            V, T = house_panel_ref(E.astype(jnp.float32), row_start)
            return V.astype(E.dtype), T.astype(E.dtype)
        return house_panel_ref(E, row_start)
    rows, b = E.shape
    pad = (-rows) % 8
    if pad:
        E = jnp.pad(E, ((0, pad), (0, 0)))
    interpret = (not _on_tpu()) if force_interpret is None else force_interpret
    rs = jnp.asarray(row_start, jnp.int32).reshape((1,))
    V, T = house_panel_pallas(E, rs, interpret=interpret)
    return V[:rows], T


__all__ = ["house_panel", "house_panel_ref"]
