"""Pure-jnp oracle for the fused compact-WY panel factorization.

``house_panel_ref(E, row_start)`` factors the sub-panel ``E[row_start:, :]``
of a full-height (rows, b) panel into compact-WY form: reflector ``j``
pivots at row ``row_start + j`` and only touches rows ``>= row_start``, so

    Q = I - V T V^T   is orthogonal and   (Q^T E)[row_start + j + 1:, j] = 0.

This is exactly ``linalg_utils.qr_wy_masked`` (the LAPACK DGEQRT panel op of
the band reduction) minus the R output the band sweep never consumes — the
two-sided trailing update regenerates the panel columns from (V, T) anyway.
``row_start`` may be traced, so the oracle drops straight into ``fori_loop``
panel sweeps; reflectors whose pivot falls past the panel (the rows < b
tail panel) come out as identity (tau = 0) and the shapes stay (rows, b) /
(b, b) regardless.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.linalg_utils import householder_masked


def house_panel_ref(E: jax.Array, row_start) -> tuple[jax.Array, jax.Array]:
    """Compact-WY factorization of E[row_start:, :]: returns (V, T).

    E is (rows, b); V is (rows, b) unit "masked lower trapezoidal" (zeros
    above each pivot row), T is (b, b) upper triangular, and
    I - V T V^T is the orthogonal panel factor.
    """
    rows, b = E.shape
    V = jnp.zeros((rows, b), E.dtype)
    T = jnp.zeros((b, b), E.dtype)
    R = E
    for j in range(b):
        v, tau, _ = householder_masked(R[:, j], row_start + j)
        R = R - tau * jnp.outer(v, v @ R)
        V = V.at[:, j].set(v)
        if j > 0:
            z = V[:, :j].T @ v
            T = T.at[:j, j].set(-tau * (T[:j, :j] @ z))
        T = T.at[j, j].set(tau)
    return V, T
