"""Pure-jnp oracle for the tiled GEMM kernel."""
import jax.numpy as jnp


def gemm_ref(A, B):
    return A @ B


def gemm_accum_ref(C, A, B, alpha=1.0):
    """C + alpha * A @ B (the Q1-accumulation / trailing-update form)."""
    return C + alpha * (A @ B)
