"""Jitted public wrapper for the GEMM kernel: padding + dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import gemm_pallas
from .ref import gemm_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(x: int, b: int) -> int:
    return -(-x // b) * b


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk",
                                             "force_interpret"))
def gemm(A: jax.Array, B: jax.Array, bm: int = 128, bn: int = 128,
         bk: int = 128, force_interpret: bool | None = None) -> jax.Array:
    """C = A @ B via the tiled Pallas kernel (zero-pads to tile multiples)."""
    m, k = A.shape
    _, n = B.shape
    interpret = (not _on_tpu()) if force_interpret is None else force_interpret
    bm_, bn_, bk_ = min(bm, _round_up(m, 8)), min(bn, _round_up(n, 8)), \
        min(bk, _round_up(k, 8))
    mp, np_, kp = _round_up(m, bm_), _round_up(n, bn_), _round_up(k, bk_)
    Ap = jnp.pad(A, ((0, mp - m), (0, kp - k)))
    Bp = jnp.pad(B, ((0, kp - k), (0, np_ - n)))
    C = gemm_pallas(Ap, Bp, bm=bm_, bn=bn_, bk=bk_, interpret=interpret)
    return C[:m, :n]


__all__ = ["gemm", "gemm_ref"]
