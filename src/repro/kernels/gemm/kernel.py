"""Pallas TPU kernel: MXU-tiled GEMM with K-reduction in the grid.

This is the BLAS-3 workhorse of the paper's pipelines — the Q1 accumulation
of variant TT (two GEMMs per panel), the SYRK trailing updates of blocked
Cholesky, and TT4/TD3 back-transforms all reduce to it.

Grid (mi, ni, ki) with ki innermost: the (bm, bn) accumulator tile lives in a
VMEM scratch across the whole K loop (no HBM round-trips), initialized at
ki == 0 and emitted at ki == nk-1. Accumulation runs in float32 for
bf16/f16/f32 inputs (MXU-native mixed precision), f64 stays f64 (interpret /
CPU path for the double-precision solvers).

Default tiles (256, 256, 512) in f32: A-tile 512 KiB + B-tile 512 KiB +
acc 256 KiB ~ 1.3 MiB — double-bufferable in 16 MiB VMEM, all dims multiples
of the (128, 128) MXU face.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, out_dtype):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=acc_ref.dtype)

    @pl.when(ki == nk - 1)
    def _emit():
        o_ref[...] = acc_ref[...].astype(out_dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def gemm_pallas(A: jax.Array, B: jax.Array, bm: int = 256, bn: int = 256,
                bk: int = 512, interpret: bool = True) -> jax.Array:
    """C = A @ B; shapes must be multiples of the tiles (ops.py pads)."""
    m, k = A.shape
    k2, n = B.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0
    acc_dtype = jnp.float64 if A.dtype == jnp.float64 else jnp.float32
    return pl.pallas_call(
        functools.partial(_gemm_kernel, out_dtype=A.dtype),
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), A.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        interpret=interpret,
    )(A, B)
