"""Pallas TPU kernel: symmetric BAND matrix-vector product in band storage.

This is the storage format variant TT's intermediate lives in (bandwidth w
after stage 1), and the building block for a TPU-native TT2: operating on
the (n, w+1) band instead of the (n, n) dense matrix cuts both HBM traffic
and the working set by n/w (= 500x at the paper's n=17k, w=32).

Layout: band[i, d] = A[i, i+d], d = 0..w (upper diagonals). For the matvec,
  y_i = sum_d band[i, d] x_{i+d} + sum_{d>=1} band[i-d, d] x_{i-d}.

Grid tiles rows (bm per step, w <= bm). The mirrored term needs a w-row
lookback; Pallas blocks cannot overlap, so the kernel receives the SAME band
array twice — the current tile and the previous tile (block index i-1,
clamped at 0; out-of-range rows are masked) — and gathers lookback rows from
their concatenation. x stays fully VMEM-resident (n <= ~1M f32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _band_mv_kernel(cur_ref, prev_ref, x_ref, o_ref, *, w: int, bm: int,
                    n: int):
    i = pl.program_id(0)
    row0 = i * bm
    cur = cur_ref[...]            # (bm, w+1) rows [row0, row0+bm)
    prev = prev_ref[...]          # (bm, w+1) rows [row0-bm, row0) (i>0)
    both = jnp.concatenate([prev, cur], axis=0)   # local row r -> r - row0 + bm
    x = x_ref[...]                # (n,)
    rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (bm,), 0)

    acc = jnp.zeros((bm,), cur.dtype)
    for d in range(w + 1):
        # upper-diagonal term: band[i, d] * x[i+d]
        up_idx = jnp.clip(rows + d, 0, n - 1)
        up_ok = (rows + d) < n
        acc += jnp.where(up_ok, cur[:, d] * x[up_idx], 0.0)
        if d > 0:
            # mirrored term: band[i-d, d] * x[i-d]
            src = rows - d
            lo_ok = src >= 0
            local = jnp.clip(src - row0 + bm, 0, 2 * bm - 1)
            acc += jnp.where(lo_ok, both[local, d] * x[jnp.clip(src, 0,
                                                                n - 1)], 0.0)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("w", "bm", "interpret"))
def band_mv_pallas(band: jax.Array, x: jax.Array, w: int, bm: int = 256,
                   interpret: bool = True) -> jax.Array:
    """y = A x for symmetric band A ((n, w+1) storage); n % bm == 0, w <= bm."""
    n, wp1 = band.shape
    assert n % bm == 0 and w < bm and wp1 == w + 1

    return pl.pallas_call(
        functools.partial(_band_mv_kernel, w=w, bm=bm, n=n),
        grid=(n // bm,),
        in_specs=[
            pl.BlockSpec((bm, wp1), lambda i: (i, 0)),
            # previous tile (clamped at the first step; masked in-kernel)
            pl.BlockSpec((bm, wp1), lambda i: (jnp.maximum(i - 1, 0), 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), band.dtype),
        interpret=interpret,
    )(band, band, x)
