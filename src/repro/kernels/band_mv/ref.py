"""Pure-jnp oracle for the symmetric band matrix-vector product.

Band storage: ``band`` is (n, w+1); band[i, d] = A[i, i+d] for d = 0..w
(upper diagonals; symmetric A implied). Entries past the matrix edge are 0.
"""
import jax.numpy as jnp


def band_to_dense(band):
    n, wp1 = band.shape
    A = jnp.zeros((n, n), band.dtype)
    for d in range(wp1):
        diag = band[: n - d, d]
        A = A + jnp.diag(diag, d)
        if d > 0:
            A = A + jnp.diag(diag, -d)
    return A


def dense_to_band(A, w):
    n = A.shape[0]
    cols = []
    for d in range(w + 1):
        diag = jnp.diagonal(A, offset=d)
        cols.append(jnp.pad(diag, (0, d)))
    return jnp.stack(cols, axis=1)


def band_mv_ref(band, x):
    return band_to_dense(band) @ x
