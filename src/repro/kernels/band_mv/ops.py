"""Jitted public wrapper for the band matvec kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import band_mv_pallas
from .ref import band_mv_ref, band_to_dense, dense_to_band


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("w", "bm", "force_interpret"))
def band_mv(band: jax.Array, x: jax.Array, w: int, bm: int = 128,
            force_interpret: bool | None = None) -> jax.Array:
    """y = A x for symmetric band A in (n, w+1) storage (zero-pads rows)."""
    n = band.shape[0]
    interpret = (not _on_tpu()) if force_interpret is None else force_interpret
    bm_ = min(bm, n)
    while n % bm_:
        bm_ -= 1
    if w >= bm_:
        bm_ = n  # single tile fallback for tiny n
    pad = (-n) % bm_
    if pad:
        band = jnp.pad(band, ((0, pad), (0, 0)))
        x = jnp.pad(x, (0, pad))
    y = band_mv_pallas(band, x, w=w, bm=bm_, interpret=interpret)
    return y[:n]


__all__ = ["band_mv", "band_mv_ref", "band_to_dense", "dense_to_band"]
