"""Pallas TPU kernel: batched Givens rotation over row-pair tiles.

The TT2 bulge chase applies wavefronts of G independent Givens rotations:
each rotation mixes one row pair with its (c, s) coefficients. Dense-storage
code dispatches one masked full-row update per rotation; this kernel streams
a whole block of (c, s) pairs over row-pair tiles held in VMEM, so one
launch applies the entire wavefront (to the packed band windows and to the
transposed-Q row pairs alike).

Layout: the pair axis is split into two (G, L) operands (x0 = first rows,
x1 = second rows) so tiles are plain (bg, bl) VPU blocks — a (G, 2, L)
block would put the size-2 pair axis in the sublane dimension and waste
7/8 of each tile. (c, s) ride along as (G, 1) columns broadcast per tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rot_apply_kernel(x0_ref, x1_ref, c_ref, s_ref, y0_ref, y1_ref):
    # bf16 tiles rotate in fp32 (VPU fma in the accumulator dtype) and
    # cast at the store; fp32/fp64 compute in kind
    wt = jnp.float32 if x0_ref.dtype == jnp.bfloat16 else x0_ref.dtype
    x0 = x0_ref[...].astype(wt)   # (bg, bl)
    x1 = x1_ref[...].astype(wt)
    c = c_ref[...].astype(wt)     # (bg, 1) -> broadcasts over the lane dim
    s = s_ref[...].astype(wt)
    y0_ref[...] = (c * x0 + s * x1).astype(y0_ref.dtype)
    y1_ref[...] = (-s * x0 + c * x1).astype(y1_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bg", "bl", "interpret"))
def rot_apply_pallas(x0: jax.Array, x1: jax.Array, c: jax.Array,
                     s: jax.Array, bg: int = 8, bl: int = 128,
                     interpret: bool = True):
    """Rotate G row pairs: x0, x1 are (G, L); c, s are (G, 1).

    Requires G % bg == 0 and L % bl == 0 (the ops wrapper pads).
    Returns (y0, y1), both (G, L).
    """
    G, L = x0.shape
    assert G % bg == 0 and L % bl == 0, (G, L, bg, bl)
    grid = (G // bg, L // bl)
    return pl.pallas_call(
        _rot_apply_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bg, bl), lambda i, j: (i, j)),
            pl.BlockSpec((bg, bl), lambda i, j: (i, j)),
            pl.BlockSpec((bg, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bg, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bg, bl), lambda i, j: (i, j)),
            pl.BlockSpec((bg, bl), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, L), x0.dtype),
            jax.ShapeDtypeStruct((G, L), x0.dtype),
        ],
        interpret=interpret,
    )(x0, x1, c, s)
