"""Public wrapper for the batched Givens rotation kernel.

``rot_apply`` is the wavefront unit of the TT2 bulge chase: G independent
rotations applied to G row pairs as ONE fused update. On TPU it lowers to
the Pallas kernel (row-pair tiles streamed through VMEM); elsewhere it
falls back to the identical vectorized XLA expression, so the bulge chase
stays a single traceable program on every backend (including under vmap in
``core.batched``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import rot_apply_pallas
from .ref import rot_apply_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def rot_apply(pairs: jax.Array, cs: jax.Array,
              force_kernel: bool = False,
              force_interpret: bool | None = None) -> jax.Array:
    """Apply G independent Givens rotations to G row pairs.

    pairs: (G, 2, L) — G disjoint row pairs.
    cs:    (G, 2)    — (c, s) per pair, out0 = c*x0 + s*x1, out1 = -s*x0 + c*x1.

    Dispatches to the Pallas kernel on TPU (or when ``force_kernel=True``,
    using interpret mode off-TPU); otherwise the vectorized jnp fallback.
    Shapes are padded to tile multiples internally.
    """
    use_kernel = force_kernel or _on_tpu()
    if not use_kernel:
        if pairs.dtype == jnp.bfloat16:
            # fp32-accumulate the rotation (the kernel's bf16 path does
            # the same); the store casts back to bf16
            out = rot_apply_ref(pairs.astype(jnp.float32),
                                cs.astype(jnp.float32))
            return out.astype(pairs.dtype)
        return rot_apply_ref(pairs, cs)
    G, _, L = pairs.shape
    bg = 8 if G >= 8 else max(G, 1)
    bl = 128 if L >= 128 else L
    gpad = (-G) % bg
    lpad = (-L) % bl
    x0 = pairs[:, 0, :]
    x1 = pairs[:, 1, :]
    c = cs[:, 0:1]
    s = cs[:, 1:2]
    if gpad or lpad:
        x0 = jnp.pad(x0, ((0, gpad), (0, lpad)))
        x1 = jnp.pad(x1, ((0, gpad), (0, lpad)))
        c = jnp.pad(c, ((0, gpad), (0, 0)), constant_values=1.0)
        s = jnp.pad(s, ((0, gpad), (0, 0)))
    interpret = (not _on_tpu()) if force_interpret is None else force_interpret
    y0, y1 = rot_apply_pallas(x0, x1, c, s, bg=bg, bl=bl, interpret=interpret)
    return jnp.stack([y0[:G, :L], y1[:G, :L]], axis=1)


__all__ = ["rot_apply", "rot_apply_ref"]
