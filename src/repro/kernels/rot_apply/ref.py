"""Pure-jnp oracle for the batched Givens row-pair rotation.

``pairs`` is (G, 2, L): G independent row pairs. ``cs`` is (G, 2) holding
(c, s) per pair. Each pair is rotated

    out[g, 0] =  c[g] * pairs[g, 0] + s[g] * pairs[g, 1]
    out[g, 1] = -s[g] * pairs[g, 0] + c[g] * pairs[g, 1]

— exactly ``linalg_utils.rotate_rows`` applied to G disjoint row pairs at
once (the wavefront unit of the TT2 bulge chase).
"""
import jax.numpy as jnp


def rot_apply_ref(pairs, cs):
    c = cs[:, 0][:, None]
    s = cs[:, 1][:, None]
    x0 = pairs[:, 0, :]
    x1 = pairs[:, 1, :]
    return jnp.stack([c * x0 + s * x1, -s * x0 + c * x1], axis=1)
