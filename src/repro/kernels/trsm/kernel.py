"""Pallas TPU kernels for the blocked triangular solve with multiple RHS.

The paper's GS2 (two DTRSMs, its chosen path over DSYGST), BT1, and the KI
per-iteration solves all hinge on TRSM. A TPU-native TRSM splits into

  (a) a *diagonal-tile* solve — inherently sequential over the b rows of the
      tile; done in-kernel with a VPU forward/back-substitution fori_loop
      over a (b, b) tile held entirely in VMEM, and
  (b) MXU GEMM updates B_i := B_i - U_ik^T X_k — which dominate the flops
      (BLAS-3) and are the gemm kernel's job at the ops.py layer.

Both tile solves (U X = B and U^T X = B) are provided. b defaults to 128:
the substitution loop is latency-bound so small tiles keep it short while
the (128, s)-tile updates still feed the MXU full faces.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _trsm_tile_upper_kernel(u_ref, b_ref, x_ref):
    """Solve U X = B for one (b, b) upper-triangular tile, RHS (b, s).

    Backward substitution: x_i = (b_i - sum_{j>i} U_ij x_j) / U_ii.
    """
    U = u_ref[...]
    B = b_ref[...]
    b = U.shape[0]

    def body(k, X):
        i = b - 1 - k
        # contributions of already-solved rows (> i)
        row = U[i, :]  # (b,)
        mask = (jnp.arange(b) > i).astype(U.dtype)
        acc = (mask * row) @ X  # (s,)
        xi = (B[i, :] - acc) / U[i, i]
        return X.at[i, :].set(xi)

    X = jax.lax.fori_loop(0, b, body, jnp.zeros_like(B))
    x_ref[...] = X


def _trsm_tile_upper_t_kernel(u_ref, b_ref, x_ref):
    """Solve U^T X = B for one (b, b) upper-triangular tile (forward subst)."""
    U = u_ref[...]
    B = b_ref[...]
    b = U.shape[0]

    def body(i, X):
        col = U[:, i]  # U^T row i = U column i
        mask = (jnp.arange(b) < i).astype(U.dtype)
        acc = (mask * col) @ X
        xi = (B[i, :] - acc) / U[i, i]
        return X.at[i, :].set(xi)

    X = jax.lax.fori_loop(0, b, body, jnp.zeros_like(B))
    x_ref[...] = X


@functools.partial(jax.jit, static_argnames=("trans", "interpret"))
def trsm_tile(U: jax.Array, B: jax.Array, trans: bool = False,
              interpret: bool = True) -> jax.Array:
    """Single-tile triangular solve as a Pallas call (whole tile in VMEM)."""
    b, s = B.shape
    kern = _trsm_tile_upper_t_kernel if trans else _trsm_tile_upper_kernel
    return pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((b, b), lambda i: (0, 0)),
            pl.BlockSpec((b, s), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((b, s), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s), B.dtype),
        interpret=interpret,
    )(U, B)
