"""Pure-jnp oracle for the blocked triangular solve (GS2 / BT1 / KI1 / KI3)."""
import jax


def trsm_ref(U, B, trans: bool = False):
    """Solve U^T X = B (trans=True) or U X = B (trans=False), U upper tri."""
    return jax.scipy.linalg.solve_triangular(U, B, trans=1 if trans else 0,
                                             lower=False)
