"""Blocked TRSM driver: Pallas diagonal-tile solves + Pallas GEMM updates.

Solves U X = B (``trans=False``) or U^T X = B (``trans=True``) for upper
triangular U — the exact operations behind the paper's GS2/BT1/KI stages.
The block loop runs at trace time (static shapes per step); the O(n^2 s)
GEMM updates dominate and run on the MXU path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..gemm.ops import gemm
from .kernel import trsm_tile
from .ref import trsm_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("trans", "block",
                                             "force_interpret"))
def trsm(U: jax.Array, B: jax.Array, trans: bool = False, block: int = 128,
         force_interpret: bool | None = None) -> jax.Array:
    """Blocked triangular solve; B may be (n,) or (n, s)."""
    squeeze = B.ndim == 1
    if squeeze:
        B = B[:, None]
    n, s = B.shape
    interpret = (not _on_tpu()) if force_interpret is None else force_interpret
    block = min(block, n)
    X = jnp.zeros_like(B)
    blocks = [(k0, min(k0 + block, n)) for k0 in range(0, n, block)]
    if trans:
        # forward over block rows: U^T lower triangular
        for (k0, k1) in blocks:
            rhs = B[k0:k1, :]
            if k0 > 0:
                # rhs -= U[0:k0, k0:k1]^T X[0:k0]
                rhs = rhs - gemm(U[:k0, k0:k1].T, X[:k0, :],
                                 force_interpret=force_interpret)
            Xk = trsm_tile(U[k0:k1, k0:k1], rhs, trans=True,
                           interpret=interpret)
            X = X.at[k0:k1, :].set(Xk)
    else:
        # backward over block rows
        for (k0, k1) in reversed(blocks):
            rhs = B[k0:k1, :]
            if k1 < n:
                rhs = rhs - gemm(U[k0:k1, k1:], X[k1:, :],
                                 force_interpret=force_interpret)
            Xk = trsm_tile(U[k0:k1, k0:k1], rhs, trans=False,
                           interpret=interpret)
            X = X.at[k0:k1, :].set(Xk)
    return X[:, 0] if squeeze else X


__all__ = ["trsm", "trsm_ref"]
