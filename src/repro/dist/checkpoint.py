"""Atomic, manifest-based checkpointing for arbitrary pytrees.

Layout: one directory per step, ``<dir>/step_<8-digit>/`` containing
``leaf_00000.npy ...`` (flattened-pytree order) plus ``manifest.json``
(leaf paths, step, user ``extra``). Writes go to ``step_*.tmp`` and are
renamed into place only after the manifest lands, so a crash mid-write can
never produce a directory that ``load_latest`` would trust: directories
without a manifest (or still carrying the ``.tmp`` suffix) are skipped.

Also provides ``lanczos_callback`` — a hook for ``core.lanczos.lanczos_solve``
that persists the thick-restart factorization (V, T) every ``every``
restarts, so a preempted eigensolve can resume from the latest basis
instead of from scratch.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{8})$")
_MANIFEST = "manifest.json"


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:08d}")


def save(directory: str, step: int, tree: Any, extra: Optional[dict] = None,
         keep: Optional[int] = None) -> str:
    """Atomically persist ``tree`` (any pytree of arrays) at ``step``.

    ``extra`` is a small JSON-serializable dict stored in the manifest
    (data cursors, solver kind, ...). ``keep`` bounds retention: after a
    successful save only the newest ``keep`` step directories survive.
    Returns the finalized step directory.
    """
    os.makedirs(directory, exist_ok=True)
    final = _step_dir(directory, step)
    tmp = final + ".tmp"
    shutil.rmtree(tmp, ignore_errors=True)
    os.makedirs(tmp)

    leaves, _ = jax.tree_util.tree_flatten(tree)
    paths = [jax.tree_util.keystr(p)
             for p, _ in jax.tree_util.tree_leaves_with_path(tree)]
    for i, leaf in enumerate(leaves):
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), np.asarray(leaf),
                allow_pickle=False)
    manifest = {"step": int(step), "n_leaves": len(leaves),
                "leaf_paths": paths, "extra": extra or {}}
    # manifest last: its presence is the commit marker
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    shutil.rmtree(final, ignore_errors=True)
    os.rename(tmp, final)

    if keep is not None:
        steps = _valid_steps(directory)
        for old in steps[:-keep]:
            shutil.rmtree(_step_dir(directory, old), ignore_errors=True)
    return final


def _valid_steps(directory: str) -> list[int]:
    """Ascending step numbers of committed (manifest-bearing) directories."""
    out = []
    try:
        entries = os.listdir(directory)
    except FileNotFoundError:
        return out
    for name in entries:
        m = _STEP_RE.match(name)
        if not m:
            continue  # .tmp leftovers and foreign files
        if os.path.exists(os.path.join(directory, name, _MANIFEST)):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    """Newest committed step, or None when nothing valid exists."""
    steps = _valid_steps(directory)
    return steps[-1] if steps else None


def load(directory: str, step: int,
         like: Any) -> Tuple[int, Any, dict]:
    """Restore the pytree saved at ``step`` into the structure of ``like``."""
    d = _step_dir(directory, step)
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)
    like_leaves, treedef = jax.tree_util.tree_flatten(like)
    n = manifest["n_leaves"]
    if n != len(like_leaves):
        raise ValueError(
            f"checkpoint at step {step} has {n} leaves; template has "
            f"{len(like_leaves)}")
    leaves = []
    for i, ref in enumerate(like_leaves):
        arr = np.load(os.path.join(d, f"leaf_{i:05d}.npy"),
                      allow_pickle=False)
        dtype = getattr(ref, "dtype", None)
        leaves.append(jnp.asarray(arr, dtype=dtype))
    return manifest["step"], jax.tree_util.tree_unflatten(treedef, leaves), \
        manifest["extra"]


def load_latest(directory: str,
                like: Any) -> Optional[Tuple[int, Any, dict]]:
    """(step, tree, extra) for the newest committed checkpoint, else None."""
    step = latest_step(directory)
    if step is None:
        return None
    return load(directory, step, like)


def lanczos_callback(directory: str, every: int = 1, keep: int = 2):
    """Checkpoint hook for ``lanczos_solve(..., callback=...)``.

    Persists the thick-restart factorization ``{"V": V, "T": T}`` every
    ``every`` restarts (step number = restart index) with
    ``extra={"kind": "lanczos", "j": j}``; resume by loading the latest
    basis and handing it back as ``v0`` / warm restart state.
    """

    def callback(k_restart: int, V, T, j) -> None:
        if k_restart % every:
            return
        save(directory, k_restart, {"V": V, "T": T},
             extra={"kind": "lanczos", "j": int(j)}, keep=keep)

    return callback
