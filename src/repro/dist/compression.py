"""Error-feedback int8 gradient compression (EF-SGD / 1-bit-Adam family).

Per leaf: carry ``c = g + e`` (gradient plus accumulated quantization
error), quantize to int8 with a per-leaf absmax scale, and fold the
residual back into the error state. The telescoping identity

    sum_t decompress(q_t) = sum_t g_t - e_final

means signals far below one quantization step still get transmitted
eventually — the property ``tests/test_dist.py`` checks. Scales are scalar
per leaf, so the wire format is ``int8 tree + one f32 per leaf``
(~4x smaller than f32 gradients before entropy coding).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

_QMAX = 127.0


def init_ef_state(grads: Any) -> Any:
    """Zero error-feedback accumulator shaped like the gradient pytree."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_with_feedback(grads: Any, ef: Any) -> Tuple[Any, Any, Any]:
    """(int8 tree, per-leaf scale tree, new error state).

    Quantization error per element is at most ``scale / 2``; everything
    the wire loses lands in the returned error state and rides along on
    the next call.
    """

    def one(g, e):
        c = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(c)) / _QMAX,
                            jnp.finfo(jnp.float32).tiny)
        q = jnp.clip(jnp.round(c / scale), -_QMAX, _QMAX).astype(jnp.int8)
        new_e = c - q.astype(jnp.float32) * scale
        return q, scale, new_e

    out = jax.tree.map(one, grads, ef)
    q = jax.tree.map(lambda t: t[0], out,
                     is_leaf=lambda t: isinstance(t, tuple))
    s = jax.tree.map(lambda t: t[1], out,
                     is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return q, s, new_ef


def decompress(q: Any, scales: Any) -> Any:
    """Dequantize an int8 tree back to f32."""
    return jax.tree.map(lambda qq, ss: qq.astype(jnp.float32) * ss, q, scales)
