"""Distributed BLAS-2/3 building blocks over a 2-D (data..., model) mesh.

The decomposition follows the multi-GPU ELPA2 / Solca-Schulthess playbook:

  * ``dist_symv`` / ``dist_gemm``  — explicit ``shard_map`` kernels: the
    operand matrix lives 2-D-sharded (row blocks over the data axes, column
    blocks over 'model'), each device multiplies its local tile, and one
    ``psum`` over 'model' finishes the row. ``*_rs`` variants replace the
    psum with ``psum_scatter`` so the output stays fully sharded (the
    collective is half the bytes — the right choice when the consumer is
    itself distributed).
  * ``dist_cholesky`` / ``dist_trsm_left_t`` — blocked panel algorithms
    (right-looking Cholesky, block forward/backward substitution) written
    against row-block-sharded operands; XLA's SPMD partitioner turns the
    panel broadcast into one collective per panel, matching the paper's
    "factor panel, broadcast, update trailing matrix" structure.

All entry points accept plain (even single-device) arrays and place them
onto the mesh themselves, so the same call sites work eagerly in tests and
traced inside jitted solvers.
"""
from __future__ import annotations

import functools
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

_solve_tri = jax.scipy.linalg.solve_triangular


def _row_spec(mesh):
    """The merged non-'model' axes: 'data', or ('pod', 'data') multi-pod."""
    rows = tuple(a for a in mesh.axis_names if a != "model")
    if not rows:
        return None
    return rows if len(rows) > 1 else rows[0]


def _row_model_spec(mesh):
    """Dim-0 spec splitting over every axis (rows then 'model')."""
    rows = tuple(a for a in mesh.axis_names if a != "model")
    axes = rows + (("model",) if "model" in mesh.axis_names else ())
    return axes if len(axes) > 1 else axes[0]


# ------------------------------------------------------------- matvec -----

def dist_symv(mesh, A, x):
    """y = A x with A 2-D-sharded (rows x 'model'), one psum per call.

    The KE1 hot loop: every Lanczos matvec in the distributed solver is
    exactly this kernel (2 n^2 flops spread over the whole mesh, n/R·n/C
    local tiles)."""
    rs = _row_spec(mesh)

    def local(a_blk, x_blk):
        return jax.lax.psum(a_blk @ x_blk, "model")

    return shard_map(local, mesh=mesh,
                     in_specs=(P(rs, "model"), P("model")),
                     out_specs=P(rs))(A, x)


def dist_symv_rs(mesh, A, x):
    """Reduce-scatter symv: output stays sharded over (rows, 'model') —
    half the collective bytes of ``dist_symv`` when the consumer is itself
    a distributed kernel."""
    rs = _row_spec(mesh)

    def local(a_blk, x_blk):
        return jax.lax.psum_scatter(a_blk @ x_blk, "model", tiled=True)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(rs, "model"), P("model")),
                     out_specs=P(_row_model_spec(mesh)))(A, x)


# --------------------------------------------------------------- gemm -----

def dist_gemm(mesh, A, B):
    """C = A B with A (rows x 'model')-sharded and B row-sharded over
    'model' (the contraction axis): local tile matmul + one psum."""
    rs = _row_spec(mesh)

    def local(a_blk, b_blk):
        return jax.lax.psum(a_blk @ b_blk, "model")

    return shard_map(local, mesh=mesh,
                     in_specs=(P(rs, "model"), P("model", None)),
                     out_specs=P(rs, None))(A, B)


def dist_gemm_rs(mesh, A, B):
    """``dist_gemm`` with the psum replaced by a row-wise psum_scatter:
    the result stays fully sharded over (rows, 'model')."""
    rs = _row_spec(mesh)

    def local(a_blk, b_blk):
        return jax.lax.psum_scatter(a_blk @ b_blk, "model",
                                    scatter_dimension=0, tiled=True)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(rs, "model"), P("model", None)),
                     out_specs=P(_row_model_spec(mesh), None))(A, B)


# -------------------------------------------------------------- syr2k -----

def dist_syr2k(mesh, C, V, W):
    """Rank-2w update C - V W^T - W V^T (DSYR2K, the band-reduction trailing
    update) with C row-block-sharded and V, W (n, w) panels.

    Each device updates its row block from its slice of V/W plus the full
    (replicated) panels — no collective at all: the panels are O(n w) and
    ride in replicated, so the O(n^2 w) flops are embarrassingly row-parallel.
    """
    rs = _row_spec(mesh)

    def local(c_blk, v_blk, w_blk, v_full, w_full):
        return c_blk - v_blk @ w_full.T - w_blk @ v_full.T

    return shard_map(local, mesh=mesh,
                     in_specs=(P(rs, None), P(rs, None), P(rs, None),
                               P(None, None), P(None, None)),
                     out_specs=P(rs, None))(C, V, W, V, W)


def dist_panel_matmul(mesh, C, V):
    """X = C V with C row-block-sharded and V an (n, w) replicated panel:
    local tile matmul, output row-sharded, no collective."""
    rs = _row_spec(mesh)

    def local(c_blk, v_full):
        return c_blk @ v_full

    return shard_map(local, mesh=mesh,
                     in_specs=(P(rs, None), P(None, None)),
                     out_specs=P(rs, None))(C, V)


def dist_apply_wy_two_sided(mesh, C, V, T):
    """Q^T C Q for symmetric row-sharded C, Q = I - V T V^T (compact WY).

    The two-sided update is refactored into SYR2K form (LAPACK DSYRDB):
    with X = C V and S = T^T (V^T X) T,

        Q^T C Q = C - Z V^T - V Z^T,   Z = X T - (1/2) V S,

    (S is symmetric because C is) so the distributed work is one
    panel matmul (X, row-parallel) plus one ``dist_syr2k``; the w x w
    couplings S, T stay replicated.
    """
    X = dist_panel_matmul(mesh, C, V)
    # panel couplings are O(n w) / O(w^2): compute replicated
    S = T.T @ (V.T @ X) @ T
    Z = X @ T - 0.5 * (V @ S)
    return dist_syr2k(mesh, C, V, Z)


def dist_apply_wy_right(mesh, M, V, T):
    """M Q = M - ((M V) T) V^T for row-sharded M — the explicit Q1
    accumulation of the band reduction (two GEMMs per panel, both local to
    each row block since V rides in replicated)."""
    rs = _row_spec(mesh)

    def local(m_blk, v_full, t):
        return m_blk - ((m_blk @ v_full) @ t) @ v_full.T

    return shard_map(local, mesh=mesh,
                     in_specs=(P(rs, None), P(None, None), P(None, None)),
                     out_specs=P(rs, None))(M, V, T)


# ------------------------------------------------- fused band-reduction ---

def _row_axes(mesh):
    return tuple(a for a in mesh.axis_names if a != "model")


@functools.lru_cache(maxsize=None)
def band_sweep_program(mesh, n: int, w: int, dtype_name: str):
    """ONE ``shard_map``-ped jitted program for the ENTIRE stage-1 sweep.

    The dispatch-light TT1: every panel iteration lives inside a
    ``lax.fori_loop`` in a single ``shard_map`` region, so a full reduction
    is one host dispatch instead of O(n/w) per-panel host round trips.
    Per panel, on each device's (n/R, n) row block:

      * the (n, w) panel columns are assembled by ONE ``all_gather`` over
        the row axes and factored to compact-WY (Y, T) via
        ``kernels/house_panel`` — replicated compute, O(n w^2), which makes
        the gather double as the panel broadcast (every shard ends up
        holding the same (Y, T) with zero extra collectives);
      * the trailing update runs in SYR2K form: X_blk = C_blk Y is local,
        the (w, w) coupling V^T X is one ``psum``, and the rank-2w update
        plus the explicit Q1 accumulation are local GEMMs (one more
        ``all_gather`` ships the O(n w) Z panel).

    Requires n divisible by the row-shard count (``dist_reduce_to_band``
    pads C to the shard multiple with an identity block otherwise, so the
    fused program serves every n). Returns a jitted
    ``(M, Q1) -> (W, Q1)`` callable on row-block-sharded storage; W comes
    back band-masked (|i-j| > w zeroed) but un-symmetrized — the packer
    averages the triangles when the band is replicated for TT2.
    """
    from repro.core.sbr import _n_panels
    from repro.kernels.house_panel.ops import house_panel

    rs = _row_spec(mesh)
    row_axes = _row_axes(mesh)
    ax = row_axes if len(row_axes) > 1 else row_axes[0]
    R = max(_n_row_shards(mesh), 1)
    assert n % R == 0, (n, R)
    nloc = n // R
    n_panels = _n_panels(n, w)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dtype = jnp.dtype(dtype_name)

    def local(m_blk, q_blk):
        # global row offset of this shard (row axes merge in mesh order)
        shard = jnp.zeros((), jnp.int32)
        for a in row_axes:
            shard = shard * sizes[a] + jax.lax.axis_index(a)
        r0 = shard * nloc

        def body(k, carry):
            m_blk, q_blk = carry
            c0 = k * w
            e_blk = jax.lax.dynamic_slice(m_blk, (0, c0), (nloc, w))
            E = jax.lax.all_gather(e_blk, ax, axis=0, tiled=True)
            V, T = house_panel(E, c0 + w)
            X_blk = m_blk @ V                                   # (nloc, w)
            V_blk = jax.lax.dynamic_slice(
                V, (r0, jnp.zeros((), r0.dtype)), (nloc, w))
            W_c = jax.lax.psum(V_blk.T @ X_blk, ax)             # (w, w)
            S = T.T @ W_c @ T
            Z_blk = X_blk @ T - 0.5 * (V_blk @ S)
            Z = jax.lax.all_gather(Z_blk, ax, axis=0, tiled=True)
            m_blk = m_blk - Z_blk @ V.T - V_blk @ Z.T
            # explicit Q1 accumulation (two local GEMMs per panel)
            q_blk = q_blk - ((q_blk @ V) @ T) @ V.T
            return m_blk, q_blk

        if n_panels:
            m_blk, q_blk = jax.lax.fori_loop(0, n_panels, body,
                                             (m_blk, q_blk))
        gi = r0 + jnp.arange(nloc, dtype=jnp.int32)[:, None]
        dist_band = jnp.abs(gi - jnp.arange(n, dtype=jnp.int32)[None, :])
        m_blk = jnp.where(dist_band <= w, m_blk, jnp.zeros((), dtype))
        return m_blk, q_blk

    sweep = shard_map(local, mesh=mesh,
                      in_specs=(P(rs, None), P(rs, None)),
                      out_specs=(P(rs, None), P(rs, None)),
                      check_rep=False)
    return jax.jit(sweep)


# ----------------------------------------------------- panel factorizations

def _n_row_shards(mesh) -> int:
    rows = tuple(a for a in mesh.axis_names if a != "model")
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = 1
    for a in rows:
        out *= sizes[a]
    return out


def _panel(mesh, n: int, block) -> int:
    if block is not None:
        return int(block)
    # one panel per row shard, clamped so tiny problems stay multi-panel
    # and huge dry-run problems don't unroll into enormous HLO
    return max(min(n // max(_n_row_shards(mesh), 1), 1024), 16)


def _chol_blocked(B, block: int):
    """Right-looking blocked Cholesky, B = U^T U (upper factor)."""
    n = B.shape[0]
    M = B
    U = jnp.zeros_like(B)
    for k0 in range(0, n, block):
        k1 = min(k0 + block, n)
        Ukk = jnp.linalg.cholesky(M[k0:k1, k0:k1]).T
        U = U.at[k0:k1, k0:k1].set(Ukk)
        if k1 < n:
            row = _solve_tri(Ukk, M[k0:k1, k1:], trans=1, lower=False)
            U = U.at[k0:k1, k1:].set(row)
            M = M.at[k1:, k1:].add(-(row.T @ row))
    return jnp.triu(U)


def _trsm_lt_blocked(U, B, block: int):
    """Solve U^T W = B (U upper): block forward substitution."""
    n = U.shape[0]
    W = jnp.zeros_like(B)
    for k0 in range(0, n, block):
        k1 = min(k0 + block, n)
        rhs = B[k0:k1] - U[:k0, k0:k1].T @ W[:k0]
        W = W.at[k0:k1].set(_solve_tri(U[k0:k1, k0:k1], rhs, trans=1,
                                       lower=False))
    return W


def _trsm_l_blocked(U, B, block: int):
    """Solve U W = B (U upper): block backward substitution."""
    n = U.shape[0]
    W = jnp.zeros_like(B)
    starts = list(range(0, n, block))
    for k0 in reversed(starts):
        k1 = min(k0 + block, n)
        rhs = B[k0:k1] - U[k0:k1, k1:] @ W[k1:]
        W = W.at[k0:k1].set(_solve_tri(U[k0:k1, k0:k1], rhs, lower=False))
    return W


def _row_sharded(mesh, M):
    nd = getattr(M, "ndim", len(M.shape))
    spec = [None] * nd
    spec[0] = _row_spec(mesh)
    return NamedSharding(mesh, P(*spec))


@functools.lru_cache(maxsize=None)
def _jit_blocked(fn, block: int, out_sharding):
    """One jitted executable per (kernel, panel size, output layout):
    a fresh jax.jit per call would retrace/recompile every invocation."""
    return jax.jit(partial(fn, block=block), out_shardings=out_sharding)


def dist_cholesky(mesh, B, block=None):
    """GS1: distributed B = U^T U on row-block-sharded storage.

    One panel per row shard by default; the SPMD partitioner lowers each
    ``U_k,: = U_kk^{-T} B_k,:`` panel solve into a broadcast of the
    factored diagonal block plus local trailing (SYRK) updates."""
    sh = _row_sharded(mesh, B)
    Bm = jax.device_put(B, sh)
    blk = _panel(mesh, B.shape[0], block)
    return _jit_blocked(_chol_blocked, blk, sh)(Bm)


def dist_trsm_left_t(mesh, U, B, block=None):
    """GS2/BT: distributed solve of U^T W = B (U upper, left, transposed)."""
    sh = _row_sharded(mesh, B)
    Um = jax.device_put(U, _row_sharded(mesh, U))
    Bm = jax.device_put(B, sh)
    blk = _panel(mesh, U.shape[0], block)
    return _jit_blocked(_trsm_lt_blocked, blk, sh)(Um, Bm)


def dist_trsm_left(mesh, U, B, block=None):
    """BT1: distributed solve of U W = B (U upper, left) — the
    back-transform X = U^{-1} Y."""
    sh = _row_sharded(mesh, B)
    Um = jax.device_put(U, _row_sharded(mesh, U))
    Bm = jax.device_put(B, sh)
    blk = _panel(mesh, U.shape[0], block)
    return _jit_blocked(_trsm_l_blocked, blk, sh)(Um, Bm)
