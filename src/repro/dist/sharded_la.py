"""Distributed BLAS-2/3 building blocks over a 2-D (data..., model) mesh.

The decomposition follows the multi-GPU ELPA2 / Solca-Schulthess playbook:

  * ``dist_symv`` / ``dist_gemm``  — explicit ``shard_map`` kernels: the
    operand matrix lives 2-D-sharded (row blocks over the data axes, column
    blocks over 'model'), each device multiplies its local tile, and one
    ``psum`` over 'model' finishes the row. ``*_rs`` variants replace the
    psum with ``psum_scatter`` so the output stays fully sharded (the
    collective is half the bytes — the right choice when the consumer is
    itself distributed).
  * ``dist_cholesky`` / ``dist_trsm_left_t`` — blocked panel algorithms
    (right-looking Cholesky, block forward/backward substitution) written
    against row-block-sharded operands; XLA's SPMD partitioner turns the
    panel broadcast into one collective per panel, matching the paper's
    "factor panel, broadcast, update trailing matrix" structure.

All entry points accept plain (even single-device) arrays and place them
onto the mesh themselves, so the same call sites work eagerly in tests and
traced inside jitted solvers.
"""
from __future__ import annotations

import functools
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

_solve_tri = jax.scipy.linalg.solve_triangular


def _row_spec(mesh):
    """The merged non-'model' axes: 'data', or ('pod', 'data') multi-pod."""
    rows = tuple(a for a in mesh.axis_names if a != "model")
    if not rows:
        return None
    return rows if len(rows) > 1 else rows[0]


def _row_model_spec(mesh):
    """Dim-0 spec splitting over every axis (rows then 'model')."""
    rows = tuple(a for a in mesh.axis_names if a != "model")
    axes = rows + (("model",) if "model" in mesh.axis_names else ())
    return axes if len(axes) > 1 else axes[0]


# ------------------------------------------------------------- matvec -----

def dist_symv(mesh, A, x):
    """y = A x with A 2-D-sharded (rows x 'model'), one psum per call.

    The KE1 hot loop: every Lanczos matvec in the distributed solver is
    exactly this kernel (2 n^2 flops spread over the whole mesh, n/R·n/C
    local tiles)."""
    rs = _row_spec(mesh)

    def local(a_blk, x_blk):
        return jax.lax.psum(a_blk @ x_blk, "model")

    return shard_map(local, mesh=mesh,
                     in_specs=(P(rs, "model"), P("model")),
                     out_specs=P(rs))(A, x)


def dist_symv_rs(mesh, A, x):
    """Reduce-scatter symv: output stays sharded over (rows, 'model') —
    half the collective bytes of ``dist_symv`` when the consumer is itself
    a distributed kernel."""
    rs = _row_spec(mesh)

    def local(a_blk, x_blk):
        return jax.lax.psum_scatter(a_blk @ x_blk, "model", tiled=True)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(rs, "model"), P("model")),
                     out_specs=P(_row_model_spec(mesh)))(A, x)


# --------------------------------------------------------------- gemm -----

def dist_gemm(mesh, A, B):
    """C = A B with A (rows x 'model')-sharded and B row-sharded over
    'model' (the contraction axis): local tile matmul + one psum."""
    rs = _row_spec(mesh)

    def local(a_blk, b_blk):
        return jax.lax.psum(a_blk @ b_blk, "model")

    return shard_map(local, mesh=mesh,
                     in_specs=(P(rs, "model"), P("model", None)),
                     out_specs=P(rs, None))(A, B)


def dist_gemm_rs(mesh, A, B):
    """``dist_gemm`` with the psum replaced by a row-wise psum_scatter:
    the result stays fully sharded over (rows, 'model')."""
    rs = _row_spec(mesh)

    def local(a_blk, b_blk):
        return jax.lax.psum_scatter(a_blk @ b_blk, "model",
                                    scatter_dimension=0, tiled=True)

    return shard_map(local, mesh=mesh,
                     in_specs=(P(rs, "model"), P("model", None)),
                     out_specs=P(_row_model_spec(mesh), None))(A, B)


# ----------------------------------------------------- panel factorizations

def _n_row_shards(mesh) -> int:
    rows = tuple(a for a in mesh.axis_names if a != "model")
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = 1
    for a in rows:
        out *= sizes[a]
    return out


def _panel(mesh, n: int, block) -> int:
    if block is not None:
        return int(block)
    # one panel per row shard, clamped so tiny problems stay multi-panel
    # and huge dry-run problems don't unroll into enormous HLO
    return max(min(n // max(_n_row_shards(mesh), 1), 1024), 16)


def _chol_blocked(B, block: int):
    """Right-looking blocked Cholesky, B = U^T U (upper factor)."""
    n = B.shape[0]
    M = B
    U = jnp.zeros_like(B)
    for k0 in range(0, n, block):
        k1 = min(k0 + block, n)
        Ukk = jnp.linalg.cholesky(M[k0:k1, k0:k1]).T
        U = U.at[k0:k1, k0:k1].set(Ukk)
        if k1 < n:
            row = _solve_tri(Ukk, M[k0:k1, k1:], trans=1, lower=False)
            U = U.at[k0:k1, k1:].set(row)
            M = M.at[k1:, k1:].add(-(row.T @ row))
    return jnp.triu(U)


def _trsm_lt_blocked(U, B, block: int):
    """Solve U^T W = B (U upper): block forward substitution."""
    n = U.shape[0]
    W = jnp.zeros_like(B)
    for k0 in range(0, n, block):
        k1 = min(k0 + block, n)
        rhs = B[k0:k1] - U[:k0, k0:k1].T @ W[:k0]
        W = W.at[k0:k1].set(_solve_tri(U[k0:k1, k0:k1], rhs, trans=1,
                                       lower=False))
    return W


def _trsm_l_blocked(U, B, block: int):
    """Solve U W = B (U upper): block backward substitution."""
    n = U.shape[0]
    W = jnp.zeros_like(B)
    starts = list(range(0, n, block))
    for k0 in reversed(starts):
        k1 = min(k0 + block, n)
        rhs = B[k0:k1] - U[k0:k1, k1:] @ W[k1:]
        W = W.at[k0:k1].set(_solve_tri(U[k0:k1, k0:k1], rhs, lower=False))
    return W


def _row_sharded(mesh, M):
    nd = getattr(M, "ndim", len(M.shape))
    spec = [None] * nd
    spec[0] = _row_spec(mesh)
    return NamedSharding(mesh, P(*spec))


@functools.lru_cache(maxsize=None)
def _jit_blocked(fn, block: int, out_sharding):
    """One jitted executable per (kernel, panel size, output layout):
    a fresh jax.jit per call would retrace/recompile every invocation."""
    return jax.jit(partial(fn, block=block), out_shardings=out_sharding)


def dist_cholesky(mesh, B, block=None):
    """GS1: distributed B = U^T U on row-block-sharded storage.

    One panel per row shard by default; the SPMD partitioner lowers each
    ``U_k,: = U_kk^{-T} B_k,:`` panel solve into a broadcast of the
    factored diagonal block plus local trailing (SYRK) updates."""
    sh = _row_sharded(mesh, B)
    Bm = jax.device_put(B, sh)
    blk = _panel(mesh, B.shape[0], block)
    return _jit_blocked(_chol_blocked, blk, sh)(Bm)


def dist_trsm_left_t(mesh, U, B, block=None):
    """GS2/BT: distributed solve of U^T W = B (U upper, left, transposed)."""
    sh = _row_sharded(mesh, B)
    Um = jax.device_put(U, _row_sharded(mesh, U))
    Bm = jax.device_put(B, sh)
    blk = _panel(mesh, U.shape[0], block)
    return _jit_blocked(_trsm_lt_blocked, blk, sh)(Um, Bm)


def dist_trsm_left(mesh, U, B, block=None):
    """BT1: distributed solve of U W = B (U upper, left) — the
    back-transform X = U^{-1} Y."""
    sh = _row_sharded(mesh, B)
    Um = jax.device_put(U, _row_sharded(mesh, U))
    Bm = jax.device_put(B, sh)
    blk = _panel(mesh, U.shape[0], block)
    return _jit_blocked(_trsm_l_blocked, blk, sh)(Um, Bm)
