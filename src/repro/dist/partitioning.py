"""PartitionSpec rules: pytree of shapes -> pytree of NamedShardings.

Rules are shape- and path-aware, and every rule is guarded by divisibility —
a dimension is only sharded when the mesh axis divides it evenly, so the
same functions serve the 8-device CI meshes and the 512-chip production
meshes without special-casing.

  * params     — stacked expert weights (``w_gate``/``w_up``/``w_down``,
                 leading (R,) scan dim then E) shard their expert dim over
                 'model' (expert parallelism); dense 2-D+ weights take
                 tensor parallelism on a trailing dim over 'model' and —
                 with ``fsdp=True`` — ZeRO-style sharding of one remaining
                 dim over the data axes. Scalars/vectors replicate.
  * opt state  — mirrors the param rules leaf-for-leaf (AdamW mu/nu inherit
                 the param layout; the step counter replicates).
  * decode     — KV/recurrent caches shard their batch dim over the data
                 axes; position scalars replicate.
  * batches    — dim 0 over the data axes, with a no-shard guard: a batch
                 whose leading dim is 1 (or not divisible) replicates —
                 B=1 decode must never be scattered across hosts.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_EXPERT_KEYS = ("w_gate", "w_up", "w_down")


def _mesh_axes(mesh):
    """(data_spec, data_size, model_size). data_spec merges every non-model
    axis (('pod','data') on multi-pod meshes)."""
    names = tuple(mesh.axis_names)
    data_axes = tuple(a for a in names if a != "model")
    sizes = dict(zip(names, mesh.devices.shape))
    dsize = 1
    for a in data_axes:
        dsize *= sizes[a]
    msize = sizes.get("model", 1)
    if not data_axes:
        data_spec = None
    elif len(data_axes) == 1:
        data_spec = data_axes[0]
    else:
        data_spec = data_axes
    return data_spec, dsize, msize


def _divisible(dim: int, by: int) -> bool:
    return by > 1 and dim >= by and dim % by == 0


def replicated(mesh, tree: Any) -> Any:
    """Fully-replicated shardings shaped like ``tree``."""
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def param_shardings(mesh, params: Any, fsdp: bool = True) -> Any:
    """NamedShardings for a parameter pytree (shapes or concrete arrays).

    ``fsdp=False`` is the serving layout: weights replicated over the data
    axes, tensor/expert-parallel over 'model' only — decode then reads
    weights from local HBM with no per-token parameter all-gathers.
    """
    data_spec, dsize, msize = _mesh_axes(mesh)

    def spec_for(path, leaf) -> P:
        shape = tuple(leaf.shape)
        nd = len(shape)
        if nd <= 1:
            return P()  # norms / biases / scalars: replicate
        spec = [None] * nd
        name = jax.tree_util.keystr(path)
        if any(k in name for k in _EXPERT_KEYS) and nd >= 3:
            # stacked experts (..., E, d_in, d_out): EP over 'model' on E
            e_ax = nd - 3
            if _divisible(shape[e_ax], msize):
                spec[e_ax] = "model"
        else:
            # tensor parallelism: trailing dim first (output features)
            for i in (nd - 1, nd - 2):
                if i >= 0 and _divisible(shape[i], msize):
                    spec[i] = "model"
                    break
        if fsdp and data_spec is not None:
            for i in range(nd):
                if spec[i] is None and _divisible(shape[i], dsize):
                    spec[i] = data_spec
                    break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, spec_for(p, l)), params)


def opt_state_shardings(mesh, opt: Any) -> Any:
    """Optimizer state inherits the param layout (ZeRO-style): mu/nu carry
    the same path suffixes as params, so the param rules apply verbatim;
    the scalar count replicates via the nd<=1 rule."""
    return param_shardings(mesh, opt)


def _batch_dim_sharding(mesh, leaf, batch_axis: int) -> NamedSharding:
    data_spec, dsize, _ = _mesh_axes(mesh)
    shape = tuple(leaf.shape)
    spec = [None] * len(shape)
    if (data_spec is not None and len(shape) > batch_axis
            and shape[batch_axis] > 1 and _divisible(shape[batch_axis],
                                                     dsize)):
        spec[batch_axis] = data_spec
    return NamedSharding(mesh, P(*spec))


def decode_state_shardings(mesh, state: Any) -> Any:
    """Shardings for a ``DecodeState``: scanned block caches carry a leading
    (R,) dim so their batch axis is 1; tail caches and enc-dec memory lead
    with batch. The (B,) per-slot position vector replicates (it is tiny
    and every collective over it would cost more than the copy)."""
    block = jax.tree.map(lambda l: _batch_dim_sharding(mesh, l, 1),
                         state.block_caches)
    tails = jax.tree.map(lambda l: _batch_dim_sharding(mesh, l, 0),
                         state.tail_caches)
    pos = NamedSharding(mesh, P())
    memory = (jax.tree.map(lambda l: _batch_dim_sharding(mesh, l, 0),
                           state.memory)
              if state.memory is not None else None)
    return type(state)(block_caches=block, tail_caches=tails, pos=pos,
                       memory=memory)


def batch_shardings(mesh, batch: Any) -> Any:
    """Data-parallel input sharding with the B=1 no-shard guard."""
    return jax.tree.map(lambda l: _batch_dim_sharding(mesh, l, 0), batch)
