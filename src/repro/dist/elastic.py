"""Elastic remeshing: recompute the device mesh after churn.

When hosts join or leave mid-run, the model-parallel degree must be held
fixed (weights are laid out for it); only the data axis — and optionally a
leading pod axis — flexes. ``plan_remesh`` keeps ``model_parallel`` intact,
divides the surviving devices into ``pods x data x model`` (or
``data x model`` for one pod), and drops a ragged remainder rather than
failing the job. Raises ``ValueError`` when not even one data slice fits.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple


class RemeshPlan(NamedTuple):
    new_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    n_used: int
    n_dropped: int
    note: str


def plan_remesh(n_devices: int, model_parallel: int,
                pods: int = 1) -> RemeshPlan:
    """Mesh plan for ``n_devices`` survivors at fixed ``model_parallel``.

    Returns shape ``(pods, data, model_parallel)`` when ``pods > 1``, else
    ``(data, model_parallel)``. A remainder that fills no whole data row is
    dropped (the plan's ``note`` says how many devices idle).
    """
    if model_parallel < 1 or pods < 1:
        raise ValueError(f"bad plan inputs: mp={model_parallel} pods={pods}")
    per_pod = n_devices // pods
    data = per_pod // model_parallel
    if data < 1:
        raise ValueError(
            f"{n_devices} devices across {pods} pod(s) cannot sustain "
            f"model_parallel={model_parallel}")
    n_used = pods * data * model_parallel
    n_dropped = n_devices - n_used
    note = (f"dropping {n_dropped} ragged device(s) to keep "
            f"model_parallel={model_parallel}" if n_dropped else
            f"exact fit at model_parallel={model_parallel}")
    if pods > 1:
        return RemeshPlan((pods, data, model_parallel),
                          ("pod", "data", "model"), n_used, n_dropped, note)
    return RemeshPlan((data, model_parallel), ("data", "model"), n_used,
                      n_dropped, note)
