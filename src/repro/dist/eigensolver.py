"""Distributed KE and TT pipelines over a 2-D device mesh.

Stage-for-stage the paper's variants, with each dense stage routed
through ``sharded_la``:

KE (``solve_ke_distributed``):
  GS1  U = dist_cholesky(B)                  (row-block panels)
  GS2  C = U^{-T} A U^{-1}                   (two dist_trsm_left_t solves)
  KE1  communication-avoiding block Lanczos  (ONE shard_map-ped jitted
       program per thick restart — the whole s-step segment loop plus the
       restart math — with TWO collectives per (n, p) block step: the
       matvec psum over 'model' and the row all_gather that doubles as
       the broadcast; see ``ke_restart_program``. An optional Chebyshev
       prep program filters the starting block so clustered spectra
       converge inside the restart budget.)
  BT1  X = U^{-1} Y                          (dist_trsm_left)

TT (``solve_tt_distributed``, the ELPA2-style two-stage path):
  GS1/GS2 as above, then
  TT1  dense -> band of width w              (ONE shard_map-ped program
       for the whole sweep: all_gather'd panel -> fused compact-WY QR ->
       sharded SYR2K trailing update + Q1 accumulation, all BLAS-3 and
       O(1) host dispatches — see ``dist_reduce_to_band``)
  TT2  band -> tridiagonal                   (replicated wavefront bulge
       chase on packed O(n w) band storage; the rotation stream is
       recorded, not accumulated — Q1 never leaves the mesh and no
       (n, n) Q2 is formed)
  TT3  bisection + inverse iteration         (spectrum-partitioned: each
       device owns a contiguous slice of the wanted indices — EleMRRR-
       style — bisects and inverse-iterates it locally, and two kinds of
       all_gather reassemble lam and Z; see ``dist_tridiag_eig``)
  TT4  Y = Q1 (Q2 Z)                         (rotation replay on the thin
       slab + collective-free panel matmul against the mesh-resident Q1)
  BT1  X = U^{-1} Y                          (dist_trsm_left)

The Lanczos driver itself is ``core.lanczos.lanczos_solve`` — the
distributed path supplies a matvec closure instead of duplicating the
restart logic. ``core.gsyeig.solve(..., mesh=...)`` dispatches here.
"""
from __future__ import annotations

import functools
import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.filtering import (chebyshev_filter, estimate_bounds,
                                  filter_interval, probe_steps)
from repro.core.instrument import DispatchCounter
from repro.core.lanczos import (_qr_posdiag, _restart_math, _segment_impl,
                                default_subspace, lanczos_solve,
                                restart_schedule)
from repro.core.linalg_utils import symmetrize
from repro.core.operators import ExplicitC
from repro.core.precision import compute_dtype, validate_precision
from repro.core.sbr import (_jit_house_panel, _jit_pack, _jit_slice_cols,
                            _n_panels, apply_q2, band_chase)
from repro.core.tridiag_eig import (TridiagEigResult, _cluster_ids,
                                    _gttrf_gtts2, _mgs_clustered,
                                    bisect_eigenvalues,
                                    eigh_tridiag_selected)
from repro.kernels.tridiag_eig.ops import SCAN_UNROLL
from .sharded_la import (_n_row_shards, _row_axes, _row_spec, _row_sharded,
                         band_sweep_program, dist_apply_wy_right,
                         dist_apply_wy_two_sided, dist_cholesky,
                         dist_panel_matmul, dist_trsm_left,
                         dist_trsm_left_t)


def _make_timer(times: dict):
    """Per-stage wall-clock accumulator shared by both pipelines."""
    def timed(name, fn, *args):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times[name] = times.get(name, 0.0) + (time.perf_counter() - t0)
        return out
    return timed


def _standard_form(mesh, A, B, timed):
    """GS1 + GS2 (shared by KE and TT): B = U^T U, C = U^{-T} A U^{-1}
    via two transposed panel solves, resymmetrized."""
    U = timed("GS1", lambda b: dist_cholesky(mesh, b), B)
    T1 = timed("GS2", lambda a: dist_trsm_left_t(mesh, U, a), A)
    C = timed("GS2", lambda t: dist_trsm_left_t(mesh, U, t.T).T, T1)
    return U, 0.5 * (C + C.T)


def _mesh_tiling(mesh, n: int):
    """(row_spec, gather_axes, n_row_shards, model_size) plus whether n
    tiles evenly over both mesh dimensions (the fused programs' layout)."""
    rs = _row_spec(mesh)
    row_axes = _row_axes(mesh)
    ax = row_axes if len(row_axes) > 1 else (row_axes[0] if row_axes else None)
    R = max(_n_row_shards(mesh), 1)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    cm = sizes.get("model", 1)
    return rs, ax, R, cm, (n % R == 0 and n % cm == 0)


def _fused_block_matvec(c_blk, ncm: int, ax):
    """The communication-avoiding W = C X on an (n, p) replicated block,
    from inside a shard_map region with C 2-D-sharded (rows x 'model').

    Exactly TWO collectives: each device multiplies its (nloc, ncm) tile
    against its 'model' slice of X, ONE psum over 'model' completes the
    row block, and ONE all_gather over the row axes rebuilds the
    replicated (n, p) result — which doubles as the broadcast for the
    redundantly-computed orthogonalization/restart math (the
    ``band_sweep_program`` trick), so the O(n m p) small-matrix work costs
    zero extra collectives. Compare one psum per matvec (2 p collectives
    per block step) in the old per-``dist_symv`` path.
    """
    def matvec(X):
        mi = jax.lax.axis_index("model")
        Xs = jax.lax.dynamic_slice_in_dim(X, mi * ncm, ncm, axis=0)
        Wp = jax.lax.psum(c_blk @ Xs, "model")
        if ax is not None:
            Wp = jax.lax.all_gather(Wp, ax, axis=0, tiled=True)
        return Wp
    return matvec


@functools.lru_cache(maxsize=None)
def ke_restart_program(mesh, n: int, p: int, m: int, s: int, keep: int,
                       which: str, dtype_name: str):
    """ONE ``shard_map``-ped jitted program per thick restart (KE1).

    The whole block-Lanczos segment — every (n, p) block step with its
    two-collective fused matvec, the two-pass re-orthogonalization, and
    the residual-block QR — runs as a ``lax.fori_loop`` inside a single
    shard_map region, followed by the replicated restart math (eigh of
    T_m, Ritz residual bounds, thick-restart state) and the Ritz-vector
    assembly. The host issues one dispatch per restart and fetches a
    single convergence scalar: the same dispatch discipline
    ``band_sweep_program`` gives TT1, applied to the Krylov side.

    Returns a jitted ``(C, V, T, j0, tol_eff) ->
    (theta (s,), resid (s,), V', T', converged, healthy, evecs (n, s))``
    callable; V/T are donated. Requires n divisible by both mesh tilings
    (``solve_ke_distributed`` falls back to a replicated operator else).
    """
    rs, ax, R, cm, ok = _mesh_tiling(mesh, n)
    assert ok, (n, R, cm)
    ncm = n // cm

    def local(c_blk, V, T, j0, tol_eff):
        matvec = _fused_block_matvec(c_blk, ncm, ax)
        V, T, B_q = _segment_impl(matvec, V, T, j0, p)
        # the restart math carries the fused health sentinel — the
        # finite-state verdict rides out of the SAME program as the
        # convergence scalar, zero extra dispatches
        theta, S, resid, V_r, T_new, conv, healthy = _restart_math(
            V, T, B_q, tol_eff, s=s, keep=keep, m=m, p=p, which=which)
        evecs, _ = jnp.linalg.qr(V[:, :m] @ S[:, :s])
        return theta[:s], resid[:s], V_r, T_new, conv, healthy, evecs

    prog = shard_map(local, mesh=mesh,
                     in_specs=(P(rs, "model"), P(None, None), P(None, None),
                               P(), P()),
                     out_specs=(P(None), P(None), P(None, None),
                                P(None, None), P(), P(), P(None, None)),
                     check_rep=False)
    return jax.jit(prog, donate_argnums=(1, 2))


@functools.lru_cache(maxsize=None)
def ke_prep_program(mesh, n: int, p: int, kb: int, degree: int, s: int,
                    which: str, dtype_name: str):
    """ONE fused program for the Chebyshev prep: the kb-step bound probe,
    the interval selection, the degree-d filter recurrence on the (n, p)
    starting block, and its orthonormalization — every matvec the fused
    two-collective kind, every small step replicated. One host dispatch
    total, so filtering never reintroduces a per-matvec round trip."""
    rs, ax, R, cm, ok = _mesh_tiling(mesh, n)
    assert ok, (n, R, cm)
    ncm = n // cm

    def local(c_blk, X0):
        matvec = _fused_block_matvec(c_blk, ncm, ax)
        theta, beta_k = estimate_bounds(matvec, X0[:, 0], kb)
        a, b, a0 = filter_interval(theta, beta_k, s, which)
        Xf = chebyshev_filter(matvec, X0, degree, a, b, a0)
        Q0, _ = _qr_posdiag(Xf)
        return Q0

    prog = shard_map(local, mesh=mesh,
                     in_specs=(P(rs, "model"), P(None, None)),
                     out_specs=P(None, None),
                     check_rep=False)
    return jax.jit(prog)


def solve_ke_distributed(
    mesh,
    A: jax.Array,
    B: jax.Array,
    s: int,
    m: Optional[int] = None,
    which: str = "smallest",
    tol: float = 0.0,
    max_restarts: int = 500,
    key: Optional[jax.Array] = None,
    return_info: bool = False,
    p: int = 4,
    filter_degree: int = 0,
    invert: bool = False,
    precision: str = "fp64",
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 1,
    checkpoint_keep: int = 2,
    resume: bool = False,
    preempt_after: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array]:
    """s extremal eigenpairs of A X = B X Lambda on a 2-D device mesh.

    The Krylov stage is the communication-avoiding block Lanczos: one
    fused ``shard_map`` program per thick restart (``ke_restart_program``)
    with two collectives per (n, ``p``) block step. ``filter_degree > 0``
    Chebyshev-filters the starting block (one extra fused program);
    ``invert=True`` applies the paper's MD trick in-place — solve the
    inverse pair (B, A) for its LARGEST eigenpairs and map back — which is
    what makes the log-spaced MD spectrum converge fast at its tiny end.

    ``precision`` demotes the Krylov stage only (GS1/GS2/BT1 stay fp64):
    ``mixed`` runs the whole fused restart program — operand, basis and
    restart math — in fp32; ``fast`` keeps the basis fp32 but ships the
    sharded operand in bf16 (the matvec accumulates in fp32 via dtype
    promotion). The convergence test is floored at the demoted operand's
    attainable residual; callers recover fp64 accuracy by refinement
    (``core.refinement`` via ``gsyeig.solve(..., precision=...)``).

    Failure containment: ``checkpoint_dir`` persists the thick-restart
    state (V, T) through ``dist/checkpoint`` every ``checkpoint_every``
    restarts (atomic, ``checkpoint_keep`` newest retained);
    ``resume=True`` warm-starts from the newest committed checkpoint —
    the restart boundary is a pure function of (V, T), so a resumed
    solve on a DIFFERENT mesh (e.g. an ``elastic.plan_remesh``-shrunken
    one after host loss) reproduces the uninterrupted eigenvalues to
    collective-roundoff (the preemption-drill parity test pins 1e-12).
    ``preempt_after=k`` is the drill hook: raise
    ``resilience.faults.SimulatedPreemption`` after the k-th restart's
    checkpoint lands. ``info['healthy']`` carries the fused finite-state
    sentinel of the restart program.

    Returns ``(evals (s,) ascending, X (n, s) B-orthonormal)``; with
    ``return_info=True`` a third dict carries per-stage wall-clock times
    and Lanczos counters (n_matvec, n_restart, converged, healthy).
    """
    validate_precision(precision)
    demoted = precision != "fp64"
    cdtype = compute_dtype(precision)
    B_orig = B
    if invert:
        A, B = B, A
        which = "largest" if which == "smallest" else "smallest"
    n = A.shape[0]
    if m is None:
        m = default_subspace(s, n, p)
    assert m % p == 0, (m, p)
    if key is None:
        key = jax.random.PRNGKey(20120520)
    times = {}
    timed = _make_timer(times)

    U, C = _standard_form(mesh, A, B, timed)
    arp_which = "SA" if which == "smallest" else "LA"
    # work dtype of the basis/restart math; the operand may sit lower
    wdtype = jnp.float32 if demoted else C.dtype
    keep, _ = restart_schedule(s, m, p)
    rs, ax, R, cm, divisible = _mesh_tiling(mesh, n)

    t0 = time.perf_counter()
    healthy = True
    resumed_from = None
    if not divisible:
        # uneven tilings cannot shard_map; keep GS1/GS2/BT1 distributed and
        # run the (block) Lanczos stage on the replicated operator — still
        # the shared core, just without the mesh collectives. Checkpointing
        # rides the host loop's callback hook (resume is fused-path only).
        callback = None
        if checkpoint_dir is not None:
            from . import checkpoint as _ckpt
            callback = _ckpt.lanczos_callback(checkpoint_dir,
                                              every=checkpoint_every,
                                              keep=checkpoint_keep)
        C_rep = jax.device_put(C, NamedSharding(mesh, P(None, None)))
        res = lanczos_solve(ExplicitC(C_rep), s, which=arp_which, m=m,
                            tol=tol, max_restarts=max_restarts, key=key,
                            p=p, filter_degree=filter_degree,
                            callback=callback,
                            compute_dtype=cdtype if demoted else None)
        lam, Y = res.evals, res.evecs
        n_matvec, n_restart = res.n_matvec, res.n_restart
        converged = res.converged
        healthy = bool(res.healthy)
    else:
        # the Krylov operand lives 2-D-sharded: rows over data axes, cols
        # over 'model' — the layout the fused block matvec consumes
        if demoted:
            C = C.astype(cdtype)
        dtype = C.dtype
        C = jax.device_put(C, NamedSharding(mesh, P(rs, "model")))
        rep = NamedSharding(mesh, P(None, None))
        dname = jnp.dtype(dtype).name
        X0 = jax.device_put(
            jax.random.normal(key, (n, p), wdtype), rep)
        n_matvec = 0
        if filter_degree > 0:
            kb = probe_steps(s, n)
            prep = ke_prep_program(mesh, n, p, kb, filter_degree, s,
                                   arp_which, dname)
            Q0 = _dispatch(prep, C, X0)
            n_matvec += kb + filter_degree * p
        else:
            Q0, _ = _qr_posdiag(X0)
        V = jax.device_put(
            jnp.zeros((n, m + p), wdtype).at[:, :p].set(Q0), rep)
        T = jax.device_put(jnp.zeros((m + p, m + p), wdtype), rep)
        # the demoted operand floors the attainable residual at
        # ~eps(cdtype) * ||C||; ask for no more (core.lanczos uses the
        # same 8x floor on its local demoted path)
        eps = float(jnp.finfo(dtype).eps)
        eps_eff = 8.0 * eps if demoted else eps
        tol_eff = jnp.asarray(tol if tol > 0.0 else eps_eff, wdtype)
        prog = ke_restart_program(mesh, n, p, m, s, keep, arp_which, dname)
        j0 = 0
        k0 = 0
        converged = False
        if checkpoint_dir is not None and resume:
            from . import checkpoint as _ckpt
            # dict keys flatten sorted, so the template's {T, V} order
            # matches what save() wrote
            got = _ckpt.load_latest(
                checkpoint_dir, {"T": jnp.zeros((m + p, m + p), wdtype),
                                 "V": jnp.zeros((n, m + p), wdtype)})
            if got is not None:
                step, tree, extra = got
                V = jax.device_put(tree["V"], rep)
                T = jax.device_put(tree["T"], rep)
                j0 = int(extra.get("j", keep // p))
                k0 = int(step) + 1
                n_matvec = int(extra.get("n_matvec", n_matvec))
                resumed_from = int(step)
        n_restart = max_restarts
        for k_restart in range(k0, max_restarts):
            lam, resid, V, T, conv, healthy_dev, Y = _dispatch(
                prog, C, V, T, jnp.asarray(j0), tol_eff)
            n_matvec += m - j0 * p
            j0 = keep // p
            # one fetch for both fused verdicts
            conv_ok, health_ok = (bool(x) for x in
                                  jax.device_get((conv, healthy_dev)))
            if (checkpoint_dir is not None
                    and k_restart % checkpoint_every == 0):
                # the POST-restart (V, T) — the state the next segment
                # consumes — so a resumed solve replays the identical
                # restart arithmetic
                from . import checkpoint as _ckpt
                _ckpt.save(checkpoint_dir, k_restart, {"V": V, "T": T},
                           extra={"kind": "ke_dist", "j": int(j0),
                                  "n_matvec": int(n_matvec)},
                           keep=checkpoint_keep)
            if preempt_after is not None \
                    and k_restart - k0 + 1 >= preempt_after:
                from repro.resilience.faults import SimulatedPreemption
                raise SimulatedPreemption(k_restart)
            if not health_ok:
                healthy = False
                n_restart = k_restart + 1
                break
            if conv_ok:
                converged = True
                n_restart = k_restart + 1
                break
    jax.block_until_ready(Y)
    times["KE_iter"] = time.perf_counter() - t0

    if demoted:
        lam, Y = lam.astype(A.dtype), Y.astype(A.dtype)
    order = jnp.argsort(lam)
    lam, Y = lam[order], Y[:, order]

    # BT1: X = U^{-1} Y
    X = timed("BT1", lambda y: dist_trsm_left(mesh, U, y), Y)

    if invert:
        lam = 1.0 / lam
        order = jnp.argsort(lam)
        lam, X = lam[order], X[:, order]
        from repro.core.residuals import b_normalize
        X = b_normalize(X, jax.device_put(
            B_orig, NamedSharding(mesh, P(None, None))))

    if return_info:
        info = {"stage_times": times, "n_matvec": int(n_matvec),
                "n_restart": int(n_restart),
                "converged": bool(converged), "healthy": bool(healthy),
                "p": int(p), "filter_degree": int(filter_degree),
                "precision": precision, "fused": bool(divisible)}
        if resumed_from is not None:
            info["resumed_from"] = int(resumed_from)
        return lam, X, info
    return lam, X


# -------------------------------------------------------- TT pipeline -----

# the per-panel jitted pieces of the STEPWISE baseline (column slice, fused
# panel QR, band pack) come from core.sbr — one set of helpers serves both
# stepwise baselines. ``_jit_pack`` also packs the replicated band into
# compact (w+1, n) storage for the TT2 wavefront chase.
_jit_band_clean = jax.jit(
    lambda M, w: symmetrize(jnp.where(
        jnp.abs(jnp.arange(M.shape[0])[:, None]
                - jnp.arange(M.shape[0])[None, :]) <= w, M, 0.0)),
    static_argnames=("w",))


# dispatch accounting for the TT1 sweep, mirroring ``core.lanczos`` /
# ``core.sbr``: each jitted-program invocation counts 1, so the regression
# tests can pin "fused sweep = O(1), per-panel loop = O(n/w)"
_dispatch = DispatchCounter()

#: host->device dispatches issued by ``dist_reduce_to_band`` (and the
#: stepwise baseline) since the last ``reset_dispatch_count()``
dispatch_count = _dispatch.count
reset_dispatch_count = _dispatch.reset


def dist_reduce_to_band(mesh, C, w: int = 8):
    """TT1: distributed Q1^T C Q1 = W (bandwidth w) on row-sharded storage.

    The ENTIRE sweep is ONE ``shard_map``-ped jitted program
    (``sharded_la.band_sweep_program``): panel assembly by ``all_gather``,
    replicated compact-WY factorization (``kernels/house_panel``), the
    SYR2K-form sharded trailing update, and the in-place Q1 accumulation
    all run inside a single ``lax.fori_loop`` — O(1) host dispatches per
    reduction where the old per-panel host loop
    (:func:`dist_reduce_to_band_stepwise`) paid a Python round trip plus a
    fresh ``shard_map`` dispatch per panel, which ``BENCH_variant_race``
    measured as 13.4s of a 14.3s solve at n=128 on 8 host devices.

    Returns ``(W, Q1)`` both row-block-sharded on the mesh; W is
    band-masked (off-band entries exactly zero). Storage note: W stays in
    full dense (n, n) form while mesh-resident (row-block sharding needs
    the rectangular layout); ``solve_tt_distributed`` packs it into compact
    (w+1, n) band storage — averaging the triangles — right before the
    replicated TT2 wavefront chase (see ``core.band_storage``). When n is
    not divisible by the row-shard count R, C is embedded in a
    block-diagonal ``[[C, 0], [0, I]]`` of the next multiple of R — the
    padding rows carry identity reflectors (their panel tails are zero)
    and identity Q1/W blocks, so the sliced-back result is exactly the
    reduction of C and the sweep STAYS one fused program for every n
    (matching the 2-dispatch TT1 the cost model charges; ``shard_map``
    could not run a per-panel fallback on uneven shards anyway).
    """
    n = C.shape[0]
    R = max(_n_row_shards(mesh), 1)
    n_pad = -(-n // R) * R
    if n_pad != n:
        idx = jnp.arange(n, n_pad)
        C = jnp.zeros((n_pad, n_pad), C.dtype).at[:n, :n].set(C) \
            .at[idx, idx].set(1.0)
    row_sh = _row_sharded(mesh, C)
    M = jax.device_put(C, row_sh)
    Q1 = jax.device_put(jnp.eye(n_pad, dtype=C.dtype), row_sh)
    sweep = band_sweep_program(mesh, n_pad, w, jnp.dtype(C.dtype).name)
    W, Q1 = _dispatch(sweep, M, Q1)
    if n_pad != n:
        W, Q1 = W[:n, :n], Q1[:n, :n]
    return W, Q1


def dist_reduce_to_band_stepwise(mesh, C, w: int = 8):
    """The old per-panel HOST loop: gather panel -> replicated QR ->
    ``dist_apply_wy_two_sided`` / ``dist_apply_wy_right``, one fresh set of
    dispatches (and two host device_put round trips) per panel.

    Kept ONLY as the dispatch-overhead baseline for the regression tests —
    do not use it on the hot path (``dist_reduce_to_band`` handles every n,
    padding to the shard multiple when needed).
    """
    n = C.shape[0]
    row_sh = _row_sharded(mesh, C)
    rep = NamedSharding(mesh, P(None, None))
    M = jax.device_put(C, row_sh)
    Q1 = jax.device_put(jnp.eye(n, dtype=C.dtype), row_sh)
    for k in range(_n_panels(n, w)):
        c0 = k * w
        E = jax.device_put(_dispatch(_jit_slice_cols, M,
                             jnp.asarray(c0), w), rep)
        V, T = _dispatch(_jit_house_panel, E, jnp.asarray(c0 + w))
        V = jax.device_put(V, rep)
        M = _dispatch(dist_apply_wy_two_sided, mesh, M, V, T)
        Q1 = _dispatch(dist_apply_wy_right, mesh, Q1, V, T)
    W = jax.device_put(_dispatch(_jit_band_clean, M, w), row_sh)
    return W, Q1


@functools.lru_cache(maxsize=None)
def tt3_program(mesh, n: int, s_pad: int, max_iters: int, iters: int,
                unroll: int, dtype_name: str):
    """ONE ``shard_map``-ped jitted program for the spectrum-partitioned
    TT3 (EleMRRR-style, arXiv:1205.2107).

    The wanted-index axis is sharded over EVERY mesh axis: each device
    bisects its contiguous slice of ``ks`` with the unrolled Sturm scans
    (lanes are independent, so the partition is embarrassingly parallel),
    ONE all_gather reassembles the full sorted ``lam`` — which doubles as
    the broadcast for the replicated gap-based clustering (the
    ``band_sweep_program`` trick: redundant O(s) work, zero extra
    collectives) — and each inverse-iteration round factors/solves only
    the local shifted systems before an all_gather over the column axis
    rebuilds the block for the replicated cluster-wise MGS. That per-round
    gather is what keeps cross-shard clusters correct: a degenerate pair
    split across the slice boundary still reorthogonalizes every round,
    exactly like the replicated path — ``lam`` is BITWISE equal to
    ``eigh_tridiag_selected(..., method='batched')`` (each lane's Sturm
    arithmetic is independent of its neighbors), and ``Z`` agrees to the
    last bits: the only width-sensitive op is the column-norm reduction,
    whose vectorization may reassociate on narrow local slices (ulp-level,
    pinned <= 1e-12 by the parity tests and the bench gate).

    Collectives: 1 (lam) + ``iters`` (Z rounds). Requires ``s_pad``
    divisible by the device count (``dist_tridiag_eig`` owns the padding).

    Returns a jitted ``(d, e, ks_pad, X0) -> (lam (s_pad,), Z (n, s_pad))``
    callable; ``ks_pad`` sorted ascending, ``X0`` column-normalized with
    padding columns exactly zero (they solve to zero and drop out of every
    MGS sum, so real columns never see them).
    """
    axes = tuple(mesh.axis_names)
    part = axes if len(axes) > 1 else axes[0]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_dev = 1
    for a in axes:
        n_dev *= sizes[a]
    assert s_pad % n_dev == 0, (s_pad, n_dev)
    s_loc = s_pad // n_dev

    def local(d, e, ks_loc, X0):
        lam_loc = bisect_eigenvalues(d, e, ks_loc, max_iters=max_iters,
                                     unroll=unroll)
        lam = jax.lax.all_gather(lam_loc, part, axis=0, tiled=True)
        scale = jnp.maximum(jnp.max(jnp.abs(d)),
                            jnp.max(jnp.abs(e)) if e.size else 0.0)
        cid = _cluster_ids(lam, scale)
        # flat shard index in sharding order -> this device's column offset
        idx = jnp.zeros((), jnp.int32)
        for a in axes:
            idx = idx * sizes[a] + jax.lax.axis_index(a)
        col0 = idx * s_loc
        solve_batch = jax.vmap(_gttrf_gtts2, in_axes=(None, None, 0, 1),
                               out_axes=1)
        tiny = jnp.finfo(X0.dtype).tiny

        def one_round(_, X):
            X_loc = jax.lax.dynamic_slice_in_dim(X, col0, s_loc, axis=1)
            X_loc = solve_batch(d, e, lam_loc, X_loc)
            X_loc = X_loc / jnp.maximum(
                jnp.linalg.norm(X_loc, axis=0, keepdims=True), tiny)
            X = jax.lax.all_gather(X_loc, part, axis=1, tiled=True)
            return _mgs_clustered(X, cid)

        Z = jax.lax.fori_loop(0, iters, one_round, X0)
        return lam, Z

    prog = shard_map(local, mesh=mesh,
                     in_specs=(P(None), P(None), P(part), P(None, None)),
                     out_specs=(P(None), P(None, None)),
                     check_rep=False)
    return jax.jit(prog)


def dist_tridiag_eig(mesh, d: jax.Array, e: jax.Array, ks: jax.Array,
                     key: Optional[jax.Array] = None, max_iters: int = 80,
                     iters: int = 3) -> TridiagEigResult:
    """Selected eigenpairs of tridiag(d, e) with the spectrum partitioned
    over the mesh (``tt3_program``); the distributed ``eigh_tridiag_selected``.

    Same contract: ``ks`` in any order, sorted internally and the result
    unpermuted. ``s`` is padded up to the device-count multiple with
    duplicates of the top index and zero start columns — both inert, both
    sliced off — so the index slices always tile the mesh. Eigenvalues
    are bitwise those of the replicated ``method='batched'`` path and
    eigenvectors match to the last bits (see ``tt3_program``).
    """
    if key is None:
        key = jax.random.PRNGKey(12021)
    d, e, ks = jnp.asarray(d), jnp.asarray(e), jnp.asarray(ks)
    n, s = d.shape[0], ks.shape[0]
    n_dev = int(mesh.devices.size)
    s_pad = -(-s // n_dev) * n_dev
    order = jnp.argsort(ks)
    inv = jnp.argsort(order)
    ks_sorted = ks[order]
    ks_pad = jnp.concatenate(
        [ks_sorted, jnp.full((s_pad - s,), ks_sorted[-1], ks_sorted.dtype)])
    X0 = jax.random.normal(key, (n, s), d.dtype)
    X0 = X0 / jnp.linalg.norm(X0, axis=0, keepdims=True)
    X0 = jnp.zeros((n, s_pad), d.dtype).at[:, :s].set(X0)
    prog = tt3_program(mesh, n, s_pad, max_iters, iters, SCAN_UNROLL,
                       jnp.dtype(d.dtype).name)
    lam, Z = _dispatch(prog, d, e, ks_pad, X0)
    return TridiagEigResult(lam=lam[:s][inv], Z=Z[:, :s][:, inv])


def solve_tt_distributed(
    mesh,
    A: jax.Array,
    B: jax.Array,
    s: int,
    which: str = "smallest",
    band_width: int = 8,
    key: Optional[jax.Array] = None,
    return_info: bool = False,
    shard_tt3: bool = True,
    precision: str = "fp64",
) -> Tuple[jax.Array, jax.Array]:
    """s extremal eigenpairs of A X = B X Lambda via the distributed
    two-stage reduction (the paper's TT variant, ELPA2-style).

    The band reduction (TT1) and every O(n^3)/O(n^2 s) GEMM/TRSM stay on
    the mesh, and the tridiagonal eigensolver (TT3) is spectrum-partitioned
    over it (``dist_tridiag_eig``: per-device index slices, EleMRRR-style;
    ``shard_tt3=False`` falls back to the replicated fused path — same
    values bitwise). Only the bulge chase (TT2) runs replicated — the
    O(n^2 w) stage the paper measures as negligible.

    ``precision`` demotes the reduction stages (TT1/TT2/TT4) to the
    compute dtype of ``core.precision``; GS1/GS2, the tridiagonal
    eigensolve and BT1 stay fp64, and callers recover fp64 eigenpair
    accuracy via ``core.refinement`` (``gsyeig.solve(..., mesh=...,
    precision=...)`` does so automatically).

    Returns ``(evals (s,) ascending, X (n, s))``; with
    ``return_info=True`` a third dict carries per-stage wall-clock times.
    """
    validate_precision(precision)
    demoted = precision != "fp64"
    cdtype = compute_dtype(precision)
    n = A.shape[0]
    if key is None:
        key = jax.random.PRNGKey(20120520)
    times = {}
    timed = _make_timer(times)

    U, C = _standard_form(mesh, A, B, timed)
    if demoted:
        C = C.astype(cdtype)

    # TT1: dense -> band, Q1 stays mesh-resident
    W, Q1 = timed("TT1", lambda c: dist_reduce_to_band(mesh, c, band_width),
                  C)

    # TT2: band -> tridiagonal, replicated (O(n^2 w) wavefront Givens work
    # over packed (w+1, n) band storage). No Q2 is materialized — the
    # rotation stream is recorded and replayed onto the thin Ritz slab in
    # TT4, so Q1 — the O(n^2) object — never gathers and Q2 never exists.
    rep = NamedSharding(mesh, P(None, None))
    W_rep = jax.device_put(W, rep)
    chase = timed("TT2", lambda wr: band_chase(
        _jit_pack(wr, band_width), band_width), W_rep)

    # TT3: selected eigenpairs of the tridiagonal — each device bisects +
    # inverse-iterates its contiguous slice of the wanted indices (O(n s / P)
    # local work, 1 + iters collectives); replicated fallback is bitwise
    ks = jnp.arange(s) if which == "smallest" else jnp.arange(n - s, n)
    d64 = chase.d.astype(A.dtype)
    e64 = chase.e.astype(A.dtype)
    if shard_tt3:
        lam, Z = timed("TT3", lambda d, e: dist_tridiag_eig(
            mesh, d, e, ks, key), d64, e64)
    else:
        lam, Z = timed("TT3", lambda d, e: eigh_tridiag_selected(
            d, e, ks, key), d64, e64)

    # TT4: Y = Q1 (Q2 Z) — Q2 Z replays the recorded rotations over the
    # replicated (n, s) slab; the product against the row-sharded Q1 is a
    # collective-free panel matmul
    Zc = Z.astype(cdtype) if demoted else Z
    Y = timed("TT4", lambda z: dist_panel_matmul(
        mesh, Q1, apply_q2(chase, z, band_width)), Zc)
    if demoted:
        Y = Y.astype(A.dtype)

    # BT1: X = U^{-1} Y
    X = timed("BT1", lambda y: dist_trsm_left(mesh, U, y), Y)

    if return_info:
        info = {"stage_times": times, "band_width": int(band_width),
                "precision": precision, "tt3_sharded": bool(shard_tt3)}
        return lam, X, info
    return lam, X
