"""Distributed KE pipeline: Cholesky -> standard form -> thick-restart
Lanczos where every matvec is a ``dist_symv`` -> back-transform.

Stage-for-stage the paper's KE variant, with each dense stage routed
through ``sharded_la``:

  GS1  U = dist_cholesky(B)                  (row-block panels)
  GS2  C = U^{-T} A U^{-1}                   (two dist_trsm_left_t solves)
  KE1  thick-restart Lanczos on C            (matvec = dist_symv; the
       projected (m x m) problem stays replicated — it is tiny)
  BT1  X = U^{-1} Y                          (dist_trsm_left)

The Lanczos driver itself is ``core.lanczos.lanczos_solve`` — the
distributed path supplies a matvec closure instead of duplicating the
restart logic. ``core.gsyeig.solve(..., mesh=...)`` dispatches here.
"""
from __future__ import annotations

import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.lanczos import default_subspace, lanczos_solve
from .sharded_la import (_row_spec, dist_cholesky, dist_symv,
                         dist_trsm_left, dist_trsm_left_t)


def solve_ke_distributed(
    mesh,
    A: jax.Array,
    B: jax.Array,
    s: int,
    m: Optional[int] = None,
    which: str = "smallest",
    tol: float = 0.0,
    max_restarts: int = 500,
    key: Optional[jax.Array] = None,
    return_info: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """s extremal eigenpairs of A X = B X Lambda on a 2-D device mesh.

    Returns ``(evals (s,) ascending, X (n, s) B-orthonormal)``; with
    ``return_info=True`` a third dict carries per-stage wall-clock times
    and Lanczos counters (n_matvec, n_restart, converged).
    """
    n = A.shape[0]
    if m is None:
        m = default_subspace(s, n)
    if key is None:
        key = jax.random.PRNGKey(20120520)
    times = {}

    def timed(name, fn, *args):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times[name] = times.get(name, 0.0) + (time.perf_counter() - t0)
        return out

    # GS1: B = U^T U
    U = timed("GS1", lambda b: dist_cholesky(mesh, b), B)
    # GS2: C = U^{-T} A U^{-1} via two transposed panel solves
    T1 = timed("GS2", lambda a: dist_trsm_left_t(mesh, U, a), A)
    C = timed("GS2", lambda t: dist_trsm_left_t(mesh, U, t.T).T, T1)
    C = 0.5 * (C + C.T)
    # the Krylov operand lives 2-D-sharded: rows over data axes, cols over
    # 'model' — the layout dist_symv consumes
    C = jax.device_put(C, NamedSharding(mesh, P(_row_spec(mesh), "model")))

    arp_which = "SA" if which == "smallest" else "LA"
    v0 = jax.random.normal(key, (n,), C.dtype)
    t0 = time.perf_counter()
    res = lanczos_solve(lambda w: dist_symv(mesh, C, w), s, which=arp_which,
                        m=m, tol=tol, max_restarts=max_restarts, v0=v0)
    jax.block_until_ready(res.evecs)
    times["KE_iter"] = time.perf_counter() - t0

    lam, Y = res.evals, res.evecs
    order = jnp.argsort(lam)
    lam, Y = lam[order], Y[:, order]

    # BT1: X = U^{-1} Y
    X = timed("BT1", lambda y: dist_trsm_left(mesh, U, y), Y)

    if return_info:
        info = {"stage_times": times, "n_matvec": int(res.n_matvec),
                "n_restart": int(res.n_restart),
                "converged": bool(res.converged)}
        return lam, X, info
    return lam, X
