"""Distributed KE and TT pipelines over a 2-D device mesh.

Stage-for-stage the paper's variants, with each dense stage routed
through ``sharded_la``:

KE (``solve_ke_distributed``):
  GS1  U = dist_cholesky(B)                  (row-block panels)
  GS2  C = U^{-T} A U^{-1}                   (two dist_trsm_left_t solves)
  KE1  thick-restart Lanczos on C            (matvec = dist_symv; the
       projected (m x m) problem stays replicated — it is tiny)
  BT1  X = U^{-1} Y                          (dist_trsm_left)

TT (``solve_tt_distributed``, the ELPA2-style two-stage path):
  GS1/GS2 as above, then
  TT1  dense -> band of width w              (ONE shard_map-ped program
       for the whole sweep: all_gather'd panel -> fused compact-WY QR ->
       sharded SYR2K trailing update + Q1 accumulation, all BLAS-3 and
       O(1) host dispatches — see ``dist_reduce_to_band``)
  TT2  band -> tridiagonal                   (replicated wavefront bulge
       chase on packed O(n w) band storage; the rotation stream is
       recorded, not accumulated — Q1 never leaves the mesh and no
       (n, n) Q2 is formed)
  TT3  bisection + inverse iteration         (replicated, O(n s))
  TT4  Y = Q1 (Q2 Z)                         (rotation replay on the thin
       slab + collective-free panel matmul against the mesh-resident Q1)
  BT1  X = U^{-1} Y                          (dist_trsm_left)

The Lanczos driver itself is ``core.lanczos.lanczos_solve`` — the
distributed path supplies a matvec closure instead of duplicating the
restart logic. ``core.gsyeig.solve(..., mesh=...)`` dispatches here.
"""
from __future__ import annotations

import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.instrument import DispatchCounter
from repro.core.lanczos import default_subspace, lanczos_solve
from repro.core.linalg_utils import symmetrize
from repro.core.sbr import (_jit_house_panel, _jit_pack, _jit_slice_cols,
                            _n_panels, apply_q2, band_chase)
from repro.core.tridiag_eig import eigh_tridiag_selected
from .sharded_la import (_n_row_shards, _row_spec, _row_sharded,
                         band_sweep_program, dist_apply_wy_right,
                         dist_apply_wy_two_sided, dist_cholesky,
                         dist_panel_matmul, dist_symv, dist_trsm_left,
                         dist_trsm_left_t)


def _make_timer(times: dict):
    """Per-stage wall-clock accumulator shared by both pipelines."""
    def timed(name, fn, *args):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times[name] = times.get(name, 0.0) + (time.perf_counter() - t0)
        return out
    return timed


def _standard_form(mesh, A, B, timed):
    """GS1 + GS2 (shared by KE and TT): B = U^T U, C = U^{-T} A U^{-1}
    via two transposed panel solves, resymmetrized."""
    U = timed("GS1", lambda b: dist_cholesky(mesh, b), B)
    T1 = timed("GS2", lambda a: dist_trsm_left_t(mesh, U, a), A)
    C = timed("GS2", lambda t: dist_trsm_left_t(mesh, U, t.T).T, T1)
    return U, 0.5 * (C + C.T)


def solve_ke_distributed(
    mesh,
    A: jax.Array,
    B: jax.Array,
    s: int,
    m: Optional[int] = None,
    which: str = "smallest",
    tol: float = 0.0,
    max_restarts: int = 500,
    key: Optional[jax.Array] = None,
    return_info: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """s extremal eigenpairs of A X = B X Lambda on a 2-D device mesh.

    Returns ``(evals (s,) ascending, X (n, s) B-orthonormal)``; with
    ``return_info=True`` a third dict carries per-stage wall-clock times
    and Lanczos counters (n_matvec, n_restart, converged).
    """
    n = A.shape[0]
    if m is None:
        m = default_subspace(s, n)
    if key is None:
        key = jax.random.PRNGKey(20120520)
    times = {}
    timed = _make_timer(times)

    U, C = _standard_form(mesh, A, B, timed)
    # the Krylov operand lives 2-D-sharded: rows over data axes, cols over
    # 'model' — the layout dist_symv consumes
    C = jax.device_put(C, NamedSharding(mesh, P(_row_spec(mesh), "model")))

    arp_which = "SA" if which == "smallest" else "LA"
    v0 = jax.random.normal(key, (n,), C.dtype)
    t0 = time.perf_counter()
    res = lanczos_solve(lambda w: dist_symv(mesh, C, w), s, which=arp_which,
                        m=m, tol=tol, max_restarts=max_restarts, v0=v0)
    jax.block_until_ready(res.evecs)
    times["KE_iter"] = time.perf_counter() - t0

    lam, Y = res.evals, res.evecs
    order = jnp.argsort(lam)
    lam, Y = lam[order], Y[:, order]

    # BT1: X = U^{-1} Y
    X = timed("BT1", lambda y: dist_trsm_left(mesh, U, y), Y)

    if return_info:
        info = {"stage_times": times, "n_matvec": int(res.n_matvec),
                "n_restart": int(res.n_restart),
                "converged": bool(res.converged)}
        return lam, X, info
    return lam, X


# -------------------------------------------------------- TT pipeline -----

# the per-panel jitted pieces of the STEPWISE baseline (column slice, fused
# panel QR, band pack) come from core.sbr — one set of helpers serves both
# stepwise baselines. ``_jit_pack`` also packs the replicated band into
# compact (w+1, n) storage for the TT2 wavefront chase.
_jit_band_clean = jax.jit(
    lambda M, w: symmetrize(jnp.where(
        jnp.abs(jnp.arange(M.shape[0])[:, None]
                - jnp.arange(M.shape[0])[None, :]) <= w, M, 0.0)),
    static_argnames=("w",))


# dispatch accounting for the TT1 sweep, mirroring ``core.lanczos`` /
# ``core.sbr``: each jitted-program invocation counts 1, so the regression
# tests can pin "fused sweep = O(1), per-panel loop = O(n/w)"
_dispatch = DispatchCounter()

#: host->device dispatches issued by ``dist_reduce_to_band`` (and the
#: stepwise baseline) since the last ``reset_dispatch_count()``
dispatch_count = _dispatch.count
reset_dispatch_count = _dispatch.reset


def dist_reduce_to_band(mesh, C, w: int = 8):
    """TT1: distributed Q1^T C Q1 = W (bandwidth w) on row-sharded storage.

    The ENTIRE sweep is ONE ``shard_map``-ped jitted program
    (``sharded_la.band_sweep_program``): panel assembly by ``all_gather``,
    replicated compact-WY factorization (``kernels/house_panel``), the
    SYR2K-form sharded trailing update, and the in-place Q1 accumulation
    all run inside a single ``lax.fori_loop`` — O(1) host dispatches per
    reduction where the old per-panel host loop
    (:func:`dist_reduce_to_band_stepwise`) paid a Python round trip plus a
    fresh ``shard_map`` dispatch per panel, which ``BENCH_variant_race``
    measured as 13.4s of a 14.3s solve at n=128 on 8 host devices.

    Returns ``(W, Q1)`` both row-block-sharded on the mesh; W is
    band-masked (off-band entries exactly zero). Storage note: W stays in
    full dense (n, n) form while mesh-resident (row-block sharding needs
    the rectangular layout); ``solve_tt_distributed`` packs it into compact
    (w+1, n) band storage — averaging the triangles — right before the
    replicated TT2 wavefront chase (see ``core.band_storage``). When n is
    not divisible by the row-shard count R, C is embedded in a
    block-diagonal ``[[C, 0], [0, I]]`` of the next multiple of R — the
    padding rows carry identity reflectors (their panel tails are zero)
    and identity Q1/W blocks, so the sliced-back result is exactly the
    reduction of C and the sweep STAYS one fused program for every n
    (matching the 2-dispatch TT1 the cost model charges; ``shard_map``
    could not run a per-panel fallback on uneven shards anyway).
    """
    n = C.shape[0]
    R = max(_n_row_shards(mesh), 1)
    n_pad = -(-n // R) * R
    if n_pad != n:
        idx = jnp.arange(n, n_pad)
        C = jnp.zeros((n_pad, n_pad), C.dtype).at[:n, :n].set(C) \
            .at[idx, idx].set(1.0)
    row_sh = _row_sharded(mesh, C)
    M = jax.device_put(C, row_sh)
    Q1 = jax.device_put(jnp.eye(n_pad, dtype=C.dtype), row_sh)
    sweep = band_sweep_program(mesh, n_pad, w, jnp.dtype(C.dtype).name)
    W, Q1 = _dispatch(sweep, M, Q1)
    if n_pad != n:
        W, Q1 = W[:n, :n], Q1[:n, :n]
    return W, Q1


def dist_reduce_to_band_stepwise(mesh, C, w: int = 8):
    """The old per-panel HOST loop: gather panel -> replicated QR ->
    ``dist_apply_wy_two_sided`` / ``dist_apply_wy_right``, one fresh set of
    dispatches (and two host device_put round trips) per panel.

    Kept ONLY as the dispatch-overhead baseline for the regression tests —
    do not use it on the hot path (``dist_reduce_to_band`` handles every n,
    padding to the shard multiple when needed).
    """
    n = C.shape[0]
    row_sh = _row_sharded(mesh, C)
    rep = NamedSharding(mesh, P(None, None))
    M = jax.device_put(C, row_sh)
    Q1 = jax.device_put(jnp.eye(n, dtype=C.dtype), row_sh)
    for k in range(_n_panels(n, w)):
        c0 = k * w
        E = jax.device_put(_dispatch(_jit_slice_cols, M,
                             jnp.asarray(c0), w), rep)
        V, T = _dispatch(_jit_house_panel, E, jnp.asarray(c0 + w))
        V = jax.device_put(V, rep)
        M = _dispatch(dist_apply_wy_two_sided, mesh, M, V, T)
        Q1 = _dispatch(dist_apply_wy_right, mesh, Q1, V, T)
    W = jax.device_put(_dispatch(_jit_band_clean, M, w), row_sh)
    return W, Q1


def solve_tt_distributed(
    mesh,
    A: jax.Array,
    B: jax.Array,
    s: int,
    which: str = "smallest",
    band_width: int = 8,
    key: Optional[jax.Array] = None,
    return_info: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """s extremal eigenpairs of A X = B X Lambda via the distributed
    two-stage reduction (the paper's TT variant, ELPA2-style).

    The band reduction (TT1) and every O(n^3)/O(n^2 s) GEMM/TRSM stay on
    the mesh; the bulge chase (TT2) and the tridiagonal eigensolver (TT3)
    run replicated — they are the O(n^2 w)/O(n s) stages the paper measures
    as negligible. Returns ``(evals (s,) ascending, X (n, s))``; with
    ``return_info=True`` a third dict carries per-stage wall-clock times.
    """
    n = A.shape[0]
    if key is None:
        key = jax.random.PRNGKey(20120520)
    times = {}
    timed = _make_timer(times)

    U, C = _standard_form(mesh, A, B, timed)

    # TT1: dense -> band, Q1 stays mesh-resident
    W, Q1 = timed("TT1", lambda c: dist_reduce_to_band(mesh, c, band_width),
                  C)

    # TT2: band -> tridiagonal, replicated (O(n^2 w) wavefront Givens work
    # over packed (w+1, n) band storage). No Q2 is materialized — the
    # rotation stream is recorded and replayed onto the thin Ritz slab in
    # TT4, so Q1 — the O(n^2) object — never gathers and Q2 never exists.
    rep = NamedSharding(mesh, P(None, None))
    W_rep = jax.device_put(W, rep)
    chase = timed("TT2", lambda wr: band_chase(
        _jit_pack(wr, band_width), band_width), W_rep)

    # TT3: selected eigenpairs of the tridiagonal (replicated, O(n s))
    ks = jnp.arange(s) if which == "smallest" else jnp.arange(n - s, n)
    lam, Z = timed("TT3", lambda d, e: eigh_tridiag_selected(d, e, ks, key),
                   chase.d, chase.e)

    # TT4: Y = Q1 (Q2 Z) — Q2 Z replays the recorded rotations over the
    # replicated (n, s) slab; the product against the row-sharded Q1 is a
    # collective-free panel matmul
    Y = timed("TT4", lambda z: dist_panel_matmul(
        mesh, Q1, apply_q2(chase, z, band_width)), Z)

    # BT1: X = U^{-1} Y
    X = timed("BT1", lambda y: dist_trsm_left(mesh, U, y), Y)

    if return_info:
        info = {"stage_times": times, "band_width": int(band_width)}
        return lam, X, info
    return lam, X
