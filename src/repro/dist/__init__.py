"""repro.dist — the distribution layer.

Scales the paper's GSYEIG pipeline (and the LM substrate around it) from one
device to a 2-D ``(data..., model)`` mesh, following the multi-device
decomposition of the ELPA2 GPU eigensolver (Yu et al. 2020) and the hybrid
Hermitian solver of Solca & Schulthess (2012): distribute the BLAS-2/3
building blocks, keep the small projected problem replicated.

Modules
-------
checkpoint    atomic manifest-based save / load_latest / retention, plus a
              Lanczos-factorization callback for preemptible eigensolves
compression   error-feedback int8 gradient compression (1-bit-Adam family)
straggler     per-step timing monitor + microbatch rebalance plans
elastic       ``plan_remesh`` — recompute the mesh after device churn
partitioning  PartitionSpec rules for params / optimizer / decode state /
              batches (expert-parallel MoE, B=1 no-shard guard)
sharded_la    ``dist_symv`` / ``dist_gemm`` / ``dist_syr2k`` /
              ``dist_cholesky`` / ``dist_trsm_left_t`` and the compact-WY
              panel updates — the paper's stage kernels over a 2-D
              ``shard_map`` mesh
eigensolver   ``solve_ke_distributed`` — the full KE pipeline where every
              matvec is a ``dist_symv``; ``solve_tt_distributed`` — the
              ELPA2-style distributed two-stage reduction (TT)
"""
from . import (checkpoint, compression, elastic, partitioning, sharded_la,
               straggler)
from .eigensolver import solve_ke_distributed, solve_tt_distributed

__all__ = [
    "checkpoint", "compression", "elastic", "partitioning", "sharded_la",
    "straggler", "solve_ke_distributed", "solve_tt_distributed",
]
