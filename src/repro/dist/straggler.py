"""Per-host step-time monitoring and microbatch rebalancing.

``StragglerMonitor`` keeps a sliding window of per-host step durations.
A host is a straggler when its windowed mean exceeds ``threshold`` times
the across-host median (robust to one slow host skewing the baseline).
``rebalance_plan`` converts observed speeds (1 / mean step time) into an
integer microbatch allocation with the same total work, via
largest-remainder rounding — slow hosts shed load, fast hosts absorb it.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List


class StragglerMonitor:
    def __init__(self, n_hosts: int, window: int = 64,
                 threshold: float = 1.5):
        assert n_hosts >= 1
        self.n_hosts = n_hosts
        self.threshold = threshold
        self._times = [deque(maxlen=window) for _ in range(n_hosts)]

    def record(self, host: int, seconds: float) -> None:
        self._times[host].append(float(seconds))

    def _means(self) -> List[float]:
        """Per-host windowed mean; hosts with no samples inherit the median
        of observed hosts (they cannot be classified either way)."""
        raw = [sum(t) / len(t) if t else None for t in self._times]
        seen = sorted(m for m in raw if m is not None)
        fallback = seen[len(seen) // 2] if seen else 1.0
        return [fallback if m is None else m for m in raw]

    def _median_mean(self) -> float:
        means = sorted(self._means())
        return means[len(means) // 2]

    def stragglers(self) -> List[int]:
        """Hosts whose mean step time exceeds threshold x median."""
        med = self._median_mean()
        return [h for h, m in enumerate(self._means())
                if m > self.threshold * med]

    def rebalance_plan(self, microbatches_per_host: int) -> Dict[int, int]:
        """host -> microbatch count, preserving the global total.

        Shares are proportional to measured speed (1 / mean step time);
        largest-remainder rounding keeps the plan integral and exact.
        """
        total = self.n_hosts * microbatches_per_host
        means = self._means()
        speeds = [1.0 / max(m, 1e-9) for m in means]
        ssum = sum(speeds)
        raw = [total * sp / ssum for sp in speeds]
        plan = {h: int(r) for h, r in enumerate(raw)}
        short = total - sum(plan.values())
        # deterministic: biggest fractional remainder first, host id breaks ties
        order = sorted(range(self.n_hosts),
                       key=lambda h: (-(raw[h] - plan[h]), h))
        for h in order[:short]:
            plan[h] += 1
        return plan
