"""Analytic per-cell cost model: FLOPs / HBM bytes / collective bytes.

Why analytic: XLA's cost_analysis counts while-loop (scan) bodies ONCE, so
for a scan-over-layers program it under-reports by ~R x (and the sequence
scans inside mamba/xlstm by another S/chunk x). We control every module, so
exact flop formulas are available; the dry-run HLO remains the ground truth
for *structure* (which collectives, memory fit) and is cross-checked against
this model in tests/test_roofline.py on small unrolled configs.

Conventions:
  * train: fwd + bwd = 3x fwd matmul flops; remat adds ~1x fwd -> 4x.
  * attention score flops use the true causal/window footprint.
  * bytes: per-chip HBM traffic model (params + optimizer + activations +
    KV cache), documented inline per term.
  * collectives: per-chip bytes crossing the mesh, from the sharding rules
    (FSDP all-gathers, grad reduce-scatter, TP activation reductions,
    MoE all-to-all).
All numbers are GLOBAL totals; divide by chips for per-chip (the roofline
terms divide by chips x peak as the assignment specifies).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.models.config import ModelConfig, ShapeConfig


@dataclasses.dataclass
class CellCost:
    flops: float            # global
    hbm_bytes: float        # global
    coll_bytes: float       # global
    detail: Dict[str, float]


def _attn_flops(cfg: ModelConfig, B: int, S: int, T: int, causal: bool,
                window: int | None) -> float:
    d, hd = cfg.d_model, cfg.head_dim
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    proj = 2.0 * B * S * d * (H * hd) + 2.0 * 2.0 * B * S * d * (Hkv * hd) \
        + 2.0 * B * S * (H * hd) * d
    if window is not None:
        t_eff = min(window, T)
        scores = 2.0 * 2.0 * B * H * S * t_eff * hd
    elif causal and S == T:
        scores = 2.0 * 2.0 * B * H * (S * (S + 1) / 2) * hd
    else:
        scores = 2.0 * 2.0 * B * H * S * T * hd
    return proj + scores


def _ffn_flops(cfg: ModelConfig, B: int, S: int, fkind: str) -> float:
    d = cfg.d_model
    if fkind == "none":
        return 0.0
    if fkind == "dense":
        return 2.0 * 3.0 * B * S * d * cfg.d_ff
    # moe
    ffe = cfg.expert_ff
    k = cfg.experts_per_token
    f = 2.0 * 3.0 * B * S * k * d * ffe               # routed experts
    f += 2.0 * B * S * d * cfg.n_experts               # router
    if cfg.n_shared_experts:
        f += 2.0 * 3.0 * B * S * d * (ffe * cfg.n_shared_experts)
    if cfg.moe_dense_residual:
        f += 2.0 * 3.0 * B * S * d * cfg.d_ff
    return f


def _mamba_flops(cfg: ModelConfig, B: int, S: int) -> float:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state_dim
    f = 2.0 * B * S * d * 2 * di                       # in_proj
    f += 2.0 * B * S * di * cfg.ssm_conv_dim           # causal conv
    f += 2.0 * B * S * di * 2 * n                      # bc_proj
    f += 2.0 * B * S * di * di                         # dt_proj
    f += 9.0 * B * S * di * n                          # recurrence + read
    f += 2.0 * B * S * di * d                          # out_proj
    return f


def _xlstm_flops(cfg: ModelConfig, B: int, S: int, kind: str) -> float:
    d = cfg.d_model
    di = int(cfg.xlstm_proj_factor * d)
    H = cfg.n_heads
    hd = di // H
    f = 2.0 * B * S * d * 2 * di                       # up
    f += 2.0 * B * S * di * d                          # down
    if kind == "mlstm":
        f += 3.0 * 2.0 * B * S * di * di               # q,k,v
        f += 2.0 * B * S * di * 3 * H                  # gates
        f += 8.0 * B * S * H * hd * hd                 # C update + read
    else:  # slstm
        f += 2.0 * B * S * di * 4 * di                 # wx
        f += 2.0 * B * S * H * hd * 4 * hd             # recurrent wr
        f += 12.0 * B * S * di                         # gates/cell ops
    return f


def _embed_flops(cfg: ModelConfig, B: int, S: int, train: bool) -> float:
    # unembed matmul dominates (embedding lookup is a gather)
    f = 2.0 * B * S * cfg.d_model * cfg.vocab_size
    return f


def fwd_flops(cfg: ModelConfig, B: int, S: int, T: int | None = None,
              decode: bool = False) -> float:
    """Forward flops for S query tokens against history T (= S if None)."""
    T = T if T is not None else S
    kinds = cfg.layer_kinds()
    fkinds = cfg.ffn_kinds()
    total = 0.0
    for kind, fk in zip(kinds, fkinds):
        if kind in ("attn", "global"):
            total += _attn_flops(cfg, B, S, T, causal=not decode, window=None)
        elif kind == "local":
            total += _attn_flops(cfg, B, S, T, causal=not decode,
                                 window=cfg.sliding_window)
        elif kind == "mamba":
            total += _mamba_flops(cfg, B, S)
        elif kind in ("slstm", "mlstm"):
            total += _xlstm_flops(cfg, B, S, kind)
        total += _ffn_flops(cfg, B, S, fk)
    if cfg.encoder_decoder:
        Senc = T
        for _ in range(cfg.n_encoder_layers):
            total += _attn_flops(cfg, B, Senc, Senc, causal=False,
                                 window=None)
            total += _ffn_flops(cfg, B, Senc, "dense")
        # decoder cross-attention
        for kind in kinds:
            if kind in ("attn", "local", "global"):
                total += _attn_flops(cfg, B, S, Senc, causal=False,
                                     window=None)
    total += _embed_flops(cfg, B, S, train=not decode)
    return total


def train_flops(cfg: ModelConfig, shape: ShapeConfig,
                remat: bool = True) -> float:
    B, S = shape.global_batch, shape.seq_len
    f = fwd_flops(cfg, B, S)
    mult = 4.0 if remat else 3.0      # fwd + 2x bwd (+1x remat recompute)
    opt = 10.0 * cfg.param_count()    # AdamW elementwise
    return mult * f + opt


def decode_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    B, S = shape.global_batch, shape.seq_len
    return fwd_flops(cfg, B, 1, T=S, decode=True)


def prefill_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    return fwd_flops(cfg, shape.global_batch, shape.seq_len)


# -------------------------------------------------------------- bytes -----

def _act_bytes_per_layer(cfg: ModelConfig, B: int, S: int) -> float:
    # ~16 d-wide tensors r/w per layer in compute dtype (empirical for our
    # blocks; dominated by the residual stream + projections)
    return 16.0 * B * S * cfg.d_model * 2.0


def train_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    B, S = shape.global_batch, shape.seq_len
    n_params = cfg.param_count()
    # params: fwd read (bf16 cast) + bwd read + grad write + AdamW state r/w
    pbytes = n_params * (2.0 + 2.0 + 4.0 + 24.0)
    act = cfg.n_layers * _act_bytes_per_layer(cfg, B, S) * 2.0  # fwd+bwd
    # attention score traffic (chunked: logits written/read once per chunk)
    kinds = cfg.layer_kinds()
    score = 0.0
    for kind in kinds:
        if kind in ("attn", "global"):
            score += 4.0 * B * cfg.n_heads * S * S / 2
        elif kind == "local":
            w = cfg.sliding_window or S
            score += 4.0 * B * cfg.n_heads * S * min(w, S)
    return pbytes + act + score


def decode_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    B, S = shape.global_batch, shape.seq_len
    n_active = cfg.param_count(active_only=True)
    pbytes = n_active * 2.0                      # read active params once
    # KV cache read per token (THE decode bottleneck); int8 mode halves it
    kv_b = 1.0 if cfg.kv_cache_dtype == "int8" else 2.0
    cache = 0.0
    for kind in cfg.layer_kinds():
        if kind in ("attn", "global"):
            cache += B * S * cfg.n_kv_heads * cfg.head_dim * 2 * kv_b
        elif kind == "local":
            w = min(cfg.sliding_window or S, S)
            cache += B * w * cfg.n_kv_heads * cfg.head_dim * 2 * kv_b
        elif kind == "mamba":
            cache += B * cfg.ssm_expand * cfg.d_model * cfg.ssm_state_dim * 4
        elif kind in ("slstm", "mlstm"):
            di = int(cfg.xlstm_proj_factor * cfg.d_model)
            hd = di // cfg.n_heads
            cache += B * cfg.n_heads * hd * hd * 4.0
    act = cfg.n_layers * 16.0 * B * cfg.d_model * 2.0
    return pbytes + cache + act


def prefill_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    B, S = shape.global_batch, shape.seq_len
    n_active = cfg.param_count(active_only=True)
    pbytes = n_active * 2.0
    act = cfg.n_layers * _act_bytes_per_layer(cfg, B, S)
    score = 0.0
    for kind in cfg.layer_kinds():
        if kind in ("attn", "global"):
            score += 4.0 * B * cfg.n_heads * S * S / 2
        elif kind == "local":
            score += 4.0 * B * cfg.n_heads * S * min(cfg.sliding_window or S,
                                                     S)
    return pbytes + act + score


# --------------------------------------------------------- collectives ----

def train_coll_bytes(cfg: ModelConfig, shape: ShapeConfig, chips: int,
                     tp: int = 16) -> float:
    """Global bytes crossing links per step under our sharding rules."""
    B, S = shape.global_batch, shape.seq_len
    n_params = cfg.param_count()
    # FSDP: all-gather params (bf16) fwd + bwd, reduce-scatter grads (f32).
    # ring cost ~ payload x (D-1)/D ~ payload, counted once per chip set.
    fsdp = n_params * 2.0 * 2.0 + n_params * 4.0
    # TP: 2 all-reduces of the (B, S, d) activations per attn/ffn layer pair
    act = B * S * cfg.d_model * 2.0
    tp_coll = cfg.n_layers * 2.0 * 2.0 * act
    # MoE all-to-all: tokens out + back, k copies
    moe = 0.0
    if cfg.is_moe:
        n_moe_layers = sum(1 for f in cfg.ffn_kinds() if f == "moe")
        moe = n_moe_layers * 2.0 * cfg.experts_per_token * act
    return fsdp + tp_coll + moe


def decode_coll_bytes(cfg: ModelConfig, shape: ShapeConfig, chips: int,
                      serving_replicated: bool = False) -> float:
    """serving_replicated=True is §Perf iteration 1: weights replicated over
    the DP axes (TP-only sharding) — no per-token parameter all-gathers."""
    B = shape.global_batch
    act = B * 1 * cfg.d_model * 2.0
    # per layer: TP all-reduce of the single-token activations x2
    coll = cfg.n_layers * 2.0 * 2.0 * act
    if cfg.is_moe:
        n_moe = sum(1 for f in cfg.ffn_kinds() if f == "moe")
        coll += n_moe * 2.0 * cfg.experts_per_token * act
    if not serving_replicated:
        # FSDP-sharded weights: gather the active parameters every token
        coll += cfg.param_count(active_only=True) * 2.0
    return coll


def cell_cost(cfg: ModelConfig, shape: ShapeConfig, chips: int,
              serving_replicated: bool = False) -> CellCost:
    if shape.kind == "train":
        return CellCost(train_flops(cfg, shape), train_bytes(cfg, shape),
                        train_coll_bytes(cfg, shape, chips),
                        {"fwd_flops": fwd_flops(cfg, shape.global_batch,
                                                shape.seq_len)})
    if shape.kind == "prefill":
        return CellCost(prefill_flops(cfg, shape), prefill_bytes(cfg, shape),
                        train_coll_bytes(cfg, shape, chips) / 3.0,
                        {})
    return CellCost(decode_flops(cfg, shape), decode_bytes(cfg, shape),
                    decode_coll_bytes(cfg, shape, chips,
                                      serving_replicated=serving_replicated),
                    {})
