"""Declarative budget registry: one contract per solver entry point.

Each entry names the jitted program(s) a solver path dispatches, with the
host multiplicity of each (a thick-restart driver dispatches its restart
program ``n_restart`` times), and the :class:`BudgetContract` those
programs must satisfy *statically*. ``check_entry`` lowers every program
(never runs it), profiles it, and returns an :class:`EntryReport` whose
``violations`` list is empty iff the contract holds.

This is the single source of truth the scattered PR-5/6/7 test assertions
collapse into: tests now import the entry names / budget constants from
``contracts`` and call :func:`check_entry` (or the ``assert_program_budget``
pytest fixture) instead of re-deriving dispatch counts and grepping HLO.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .profile import ProgramProfile, profile_fn

#: dtypes every fp64 solver program may mention (loop counters, Sturm
#: index lanes, RNG keys and branch predicates ride along with the f64 data)
DEFAULT_ALLOWED_DTYPES: Tuple[str, ...] = (
    "float64", "int64", "int32", "uint32", "uint64", "bool", "key<fry>",
)


@dataclasses.dataclass(frozen=True)
class ProgramSpec:
    """One jitted program of an entry: how to lower it, never run it."""
    name: str
    fn: Callable
    args: Tuple[Any, ...] = ()
    kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    #: host dispatches this program contributes per solve/sweep (an int,
    #: already multiplied out — e.g. the KE restart program at n_restart=3
    #: contributes 3)
    host_multiplicity: int = 1
    with_hlo: bool = True


@dataclasses.dataclass(frozen=True)
class BudgetContract:
    """The static shape an entry's lowered programs must have."""
    #: total host->device dispatches per solve (sum of host multiplicities)
    max_dispatches: Optional[int] = None
    #: collectives a single trip of the busiest loop may execute
    #: ("per panel" / "per block step")
    max_collectives_per_step: Optional[int] = None
    #: exact static collective total across all programs (loop-multiplied)
    exact_collectives: Optional[int] = None
    #: upper bound when an exact count is not pinned
    max_collectives: Optional[int] = None
    #: dynamic (traced-bound) while loops allowed across all programs
    max_dynamic_whiles: Optional[int] = None
    allowed_dtypes: Tuple[str, ...] = DEFAULT_ALLOWED_DTYPES
    #: forbid float64 -> float32/bf16/fp16 convert_element_type sites
    forbid_f64_downcasts: bool = True
    #: downcast edges ("float64->float32", ...) this entry DECLARES as
    #: policy — the mixed/fast pipelines demote their GEMM stages on
    #: purpose, so the lint flags only *undeclared* demotions. Empty for
    #: fp64 contracts: every downcast stays a leak.
    declared_downcasts: Tuple[str, ...] = ()
    forbid_callbacks: bool = True
    #: require at least this many pallas_call launches (kernel entries)
    min_pallas_calls: int = 0
    #: require at least this many ``is_finite`` sites across the lowered
    #: programs — proof that the resilience health sentinels are FUSED
    #: into the program (a sentinel that fell out of the trace would
    #: silently stop guarding)
    min_isfinite_sites: int = 0
    #: extra host dispatches the sentinels are permitted to add on top of
    #: ``max_dispatches``. Pinned to 0 repo-wide: the health verdicts ride
    #: inside the existing fused programs, never as separate launches.
    sentinel_extra_dispatches: int = 0
    notes: str = ""

    def as_json_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["allowed_dtypes"] = list(self.allowed_dtypes)
        d["declared_downcasts"] = list(self.declared_downcasts)
        return d


@dataclasses.dataclass(frozen=True)
class AuditEntry:
    name: str
    #: lazily builds the ProgramSpecs (tracing imports jax-heavy modules)
    build: Callable[[], Sequence[ProgramSpec]]
    contract: BudgetContract
    needs_mesh: bool = False
    tags: Tuple[str, ...] = ()


@dataclasses.dataclass
class EntryReport:
    name: str
    contract: BudgetContract
    profiles: List[ProgramProfile]
    dispatches: int
    total_collectives: int
    max_collectives_per_step: int
    violations: List[str]
    skipped: bool = False
    isfinite_sites: int = 0

    @property
    def ok(self) -> bool:
        return not self.violations

    def as_json_dict(self) -> dict:
        return {"name": self.name, "ok": self.ok, "skipped": self.skipped,
                "violations": self.violations,
                "dispatches": self.dispatches,
                "isfinite_sites": self.isfinite_sites,
                "total_collectives": self.total_collectives,
                "max_collectives_per_step": self.max_collectives_per_step,
                "contract": self.contract.as_json_dict(),
                "programs": [p.as_json_dict() for p in self.profiles]}


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

_REGISTRY: Dict[str, AuditEntry] = {}


def register(entry: AuditEntry) -> AuditEntry:
    _REGISTRY[entry.name] = entry
    return entry


def get_entry(name: str) -> AuditEntry:
    return _REGISTRY[name]


def entries(tags: Optional[Sequence[str]] = None) -> List[AuditEntry]:
    out = list(_REGISTRY.values())
    if tags:
        want = set(tags)
        out = [e for e in out if want & set(e.tags)]
    return out


def clear_registry() -> None:
    _REGISTRY.clear()


# --------------------------------------------------------------------------
# contract checking
# --------------------------------------------------------------------------

def _check_contract(c: BudgetContract, profiles: List[ProgramProfile],
                    specs: Sequence[ProgramSpec]) -> Tuple[int, int, int,
                                                           int, List[str]]:
    viol: List[str] = []
    dispatches = sum(s.host_multiplicity for s in specs)
    total_coll = sum(p.total_collectives() * s.host_multiplicity
                     for p, s in zip(profiles, specs))
    per_step = max((p.max_collectives_per_loop_trip() for p in profiles),
                   default=0)
    isfinite_sites = sum(p.primitive_counts.get("is_finite", 0)
                         for p in profiles)
    if c.max_dispatches is not None:
        # the dispatch ceiling INCLUDES the sentinel allowance (pinned to
        # 0 repo-wide): health verdicts must not buy extra launches
        budget = c.max_dispatches + c.sentinel_extra_dispatches
        if dispatches > budget:
            viol.append(f"dispatches {dispatches} > budget {budget} "
                        f"(base {c.max_dispatches} + sentinel allowance "
                        f"{c.sentinel_extra_dispatches})")
    if isfinite_sites < c.min_isfinite_sites:
        viol.append(f"{isfinite_sites} fused is_finite site(s) < required "
                    f"{c.min_isfinite_sites} (health sentinel missing "
                    "from the lowered program)")
    if (c.max_collectives_per_step is not None
            and per_step > c.max_collectives_per_step):
        viol.append(f"collectives per loop step {per_step} > budget "
                    f"{c.max_collectives_per_step}")
    if (c.exact_collectives is not None
            and total_coll != c.exact_collectives):
        viol.append(f"static collective total {total_coll} != pinned "
                    f"{c.exact_collectives}")
    if c.max_collectives is not None and total_coll > c.max_collectives:
        viol.append(f"static collective total {total_coll} > budget "
                    f"{c.max_collectives}")
    whiles = sum(p.dynamic_whiles for p in profiles)
    if c.max_dynamic_whiles is not None and whiles > c.max_dynamic_whiles:
        viol.append(f"dynamic while loops {whiles} > budget "
                    f"{c.max_dynamic_whiles}")
    if c.forbid_callbacks:
        cbs = sum(p.callbacks for p in profiles)
        if cbs:
            viol.append(f"{cbs} host callback(s) in a no-callback program")
    if c.forbid_f64_downcasts:
        declared = set(c.declared_downcasts)
        for p in profiles:
            leaks = {k: v for k, v in p.f64_downcasts().items()
                     if k not in declared}
            if leaks:
                viol.append(f"{p.name}: precision leak(s) {leaks}")
    if c.allowed_dtypes:
        allowed = set(c.allowed_dtypes)
        for p in profiles:
            bad = [d for d in p.dtypes_seen() if d not in allowed]
            if bad:
                viol.append(f"{p.name}: dtypes {bad} outside allowed set")
    n_pallas = sum(len(p.pallas_calls) for p in profiles)
    if n_pallas < c.min_pallas_calls:
        viol.append(f"{n_pallas} pallas_call(s) < required "
                    f"{c.min_pallas_calls}")
    return dispatches, total_coll, per_step, isfinite_sites, viol


def check_entry(entry: AuditEntry) -> EntryReport:
    """Lower + profile every program of ``entry`` and enforce its contract."""
    specs = list(entry.build())
    profiles = [profile_fn(s.fn, *s.args, name=s.name,
                           with_hlo=s.with_hlo, **s.kwargs) for s in specs]
    dispatches, total, per_step, isf, viol = _check_contract(
        entry.contract, profiles, specs)
    return EntryReport(name=entry.name, contract=entry.contract,
                       profiles=profiles, dispatches=dispatches,
                       total_collectives=total,
                       max_collectives_per_step=per_step, violations=viol,
                       isfinite_sites=isf)


def check_all(tags: Optional[Sequence[str]] = None,
              have_mesh: bool = True) -> List[EntryReport]:
    reports = []
    for e in entries(tags):
        if e.needs_mesh and not have_mesh:
            reports.append(EntryReport(
                name=e.name, contract=e.contract, profiles=[],
                dispatches=0, total_collectives=0,
                max_collectives_per_step=0, violations=[], skipped=True))
            continue
        reports.append(check_entry(e))
    return reports


__all__ = ["ProgramSpec", "BudgetContract", "AuditEntry", "EntryReport",
           "register", "get_entry", "entries", "clear_registry",
           "check_entry", "check_all", "DEFAULT_ALLOWED_DTYPES"]
