"""The repo's budget contracts: every solver entry point, registered.

This module is where the scattered PR-5/6/7 invariants live now — the
named constants below are imported by the tests that used to hard-code
them, and :func:`register_all` builds the :mod:`registry` entries the
``launch/audit.py`` CLI (and the ``assert_program_budget`` pytest fixture)
enforce. Everything is lowered on a small canonical spec
(:class:`AuditSpec`); the contracts are structural (collectives per step,
dispatch counts, loop shapes), so the small spec proves the same
invariants the production shapes rely on.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .registry import (AuditEntry, BudgetContract, DEFAULT_ALLOWED_DTYPES,
                       ProgramSpec, register)

# ------------------------------------------------------------------------
# published budget constants — the single source of truth the tests import
# ------------------------------------------------------------------------

#: fused stage-1 sweep (local or distributed): whole reduction in <= 3 host
#: dispatches (sweep program + band repack + slack for the pad path)
TT1_FUSED_MAX_DISPATCHES = 3
#: collectives ONE panel iteration of ``band_sweep_program`` executes:
#: all_gather(panel) + psum(coupling) + all_gather(Z)
TT1_COLLECTIVES_PER_PANEL = 3
#: the stepwise per-panel TT1 baseline pays at least this many dispatches
#: per panel (house_panel + coupling + update + Q1 accumulation)
TT1_STEPWISE_DISPATCHES_PER_PANEL = 4
#: communication-avoiding block Lanczos: collectives per p-column block
#: step of the fused matvec (one psum + one all_gather)
KE_COLLECTIVES_PER_BLOCK_STEP = 2
#: collectives appearing in the lowered ke_restart_program *text* (the
#: loop body is written once in StableHLO)
KE_HLO_ALL_REDUCE_MAX = 1
KE_HLO_ALL_GATHER_MAX = 1
#: all_gathers in the lowered tt3_program text: the lam gather + the
#: per-round Z gather (fori body appears once)
TT3_HLO_ALL_GATHER_MAX = 2
#: host dispatches the resilience health sentinels may ADD to any fused
#: program — pinned to 0: every stage-boundary ``is_finite`` verdict is
#: traced into an existing program (``resilience.health``), so the
#: dispatch budgets below hold UNCHANGED with sentinels active. The
#: auditor enforces both sides: ``min_isfinite_sites`` proves the
#: sentinel is present, this constant proves it is free.
SENTINEL_EXTRA_DISPATCHES = 0


#: dtypes the mixed-precision (fp32 compute) pipelines may mention on top
#: of the fp64 set: the demoted GEMM stages and the fp32 LU of the
#: refinement corrector
MIXED_ALLOWED_DTYPES: Tuple[str, ...] = DEFAULT_ALLOWED_DTYPES + ("float32",)
#: the fast (bf16 storage / fp32 accumulation) pipelines additionally
#: carry bfloat16 operands
FAST_ALLOWED_DTYPES: Tuple[str, ...] = MIXED_ALLOWED_DTYPES + ("bfloat16",)


def ke_dispatch_budget(n_restart: int) -> int:
    """Host dispatches of the fused distributed Krylov stage: one program
    per thick restart, plus prep (bounds probe / Chebyshev filter) and the
    final Ritz extraction."""
    return n_restart + 2


def lanczos_block_dispatch_budget(n_restart: int) -> int:
    """Host dispatches of the local fused-restart driver
    (``lanczos_solve``): segment+restart fused per restart, one extra
    final segment + one Ritz extraction."""
    return 2 * n_restart + 2


def lanczos_single_dispatch_budget(n_restart: int) -> int:
    """Host dispatches of the legacy per-stage local driver: segment,
    restart math and convergence check each restart, plus startup/finish."""
    return 3 * n_restart + 4


def tt3_dist_collectives(iters: int) -> int:
    """Static collective total of the spectrum-partitioned TT3: ONE lam
    all_gather + one Z all_gather per inverse-iteration round."""
    return 1 + iters


# ------------------------------------------------------------------------
# canonical audit spec
# ------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AuditSpec:
    """The shape bucket every contract is lowered on. Small on purpose —
    the contracts are structural, so tracing stays cheap in CI."""
    n: int = 64
    s: int = 4
    w: int = 8
    p: int = 4            # Lanczos block size
    m: int = 24           # Lanczos subspace
    kb: int = 12          # Chebyshev bound-probe steps
    filter_degree: int = 8
    tt3_iters: int = 3    # inverse-iteration rounds
    tt3_max_iters: int = 80
    batch: int = 2        # solve_batched bucket batch
    dtype_name: str = "float64"

    @property
    def dtype(self):
        return jnp.dtype(self.dtype_name)

    def as_json_dict(self) -> dict:
        return dataclasses.asdict(self)


def _sds(*shape, dtype=jnp.float64):
    return jax.ShapeDtypeStruct(shape, dtype)


def make_mesh_2dev(shape: Tuple[int, int] = (2, 1)):
    """The audit mesh: data=2 so the row collectives are real, not no-ops.
    Requires >= 2 visible devices (``launch/audit.py`` forces host devices
    before importing jax, the ``launch/eigsolve.py`` idiom)."""
    return jax.make_mesh(shape, ("data", "model"))


# ------------------------------------------------------------------------
# entry builders
# ------------------------------------------------------------------------

def _build_reduce_to_band(spec: AuditSpec):
    from repro.core.sbr import _reduce_to_band_program, default_n_chunks
    n, w = spec.n, spec.w
    C = _sds(n, n, dtype=spec.dtype)
    return [ProgramSpec(
        name="_reduce_to_band_program", fn=_reduce_to_band_program,
        args=(C,), kwargs=dict(w=w, n_chunks=default_n_chunks(n, w)))]


def _build_band_chase(spec: AuditSpec):
    from repro.core.sbr import band_chase
    Wb = _sds(spec.w + 1, spec.n, dtype=spec.dtype)
    return [ProgramSpec(name="band_chase", fn=partial(band_chase, w=spec.w),
                        args=(Wb,))]


def _chase_shapes(spec: AuditSpec):
    from repro.core.sbr import band_chase
    Wb = _sds(spec.w + 1, spec.n, dtype=spec.dtype)
    return jax.eval_shape(partial(band_chase, w=spec.w), Wb)


def _build_apply_q2(spec: AuditSpec):
    from repro.core.sbr import apply_q2
    chase = _chase_shapes(spec)
    Z = _sds(spec.n, spec.s, dtype=spec.dtype)
    return [ProgramSpec(name="apply_q2", fn=partial(apply_q2, w=spec.w),
                        args=(chase, Z))]


def _build_tridiag_eig_batched(spec: AuditSpec):
    from repro.core.tridiag_eig import eigh_tridiag_selected
    n, s = spec.n, spec.s
    d = _sds(n, dtype=spec.dtype)
    e = _sds(n - 1, dtype=spec.dtype)
    ks = jnp.arange(s)
    key = jax.random.PRNGKey(0)

    def prog(d, e, ks, key):
        return eigh_tridiag_selected(d, e, ks, key, method="batched")

    return [ProgramSpec(name="tridiag_eig_batched", fn=prog,
                        args=(d, e, ks, key))]


def _build_lanczos_solve_jit(spec: AuditSpec):
    from repro.core.lanczos import lanczos_solve_jit
    from repro.core.operators import ExplicitC
    n, s, m, p = spec.n, spec.s, spec.m, spec.p
    C = _sds(n, n, dtype=spec.dtype)
    v0 = _sds(n, p, dtype=spec.dtype)

    def prog(C, v0):
        return lanczos_solve_jit(ExplicitC(C), v0, s, m, which="SA",
                                 max_restarts=8, p=p)

    return [ProgramSpec(name="lanczos_solve_jit", fn=prog, args=(C, v0),
                        with_hlo=False)]


def _build_solve_batched(spec: AuditSpec, variant: str,
                         precision: str = "fp64"):
    from repro.core.batched import get_pipeline
    n, s, batch = spec.n // 2, spec.s, spec.batch
    fn, _ = get_pipeline(n, s, variant, "smallest", band_width=4,
                         p=spec.p if variant in ("KE", "KI") else 1,
                         max_restarts=8, precision=precision)
    A = _sds(batch, n, n, dtype=spec.dtype)
    B = _sds(batch, n, n, dtype=spec.dtype)
    keys = jax.random.split(jax.random.PRNGKey(0), batch)
    suffix = "" if precision == "fp64" else f"_{precision}"
    return [ProgramSpec(name=f"solve_batched_{variant}{suffix}", fn=fn,
                        args=(A, B, keys), with_hlo=False)]


def _build_band_sweep(spec: AuditSpec, mesh):
    from repro.core.sbr import _jit_pack
    from repro.dist.sharded_la import band_sweep_program
    n, w = spec.n, spec.w
    prog = band_sweep_program(mesh, n, w, spec.dtype_name)
    M = _sds(n, n, dtype=spec.dtype)
    Q = _sds(n, n, dtype=spec.dtype)
    return [
        ProgramSpec(name="band_sweep_program", fn=prog, args=(M, Q)),
        ProgramSpec(name="_jit_pack", fn=_jit_pack, args=(M,),
                    kwargs=dict(w=w), with_hlo=False),
    ]


def _build_ke_restart(spec: AuditSpec, mesh):
    from repro.core.lanczos import restart_schedule
    from repro.dist.eigensolver import ke_restart_program
    n, s, p, m = spec.n, spec.s, spec.p, spec.m
    keep = restart_schedule(s, m, p)[0]
    prog = ke_restart_program(mesh, n, p, m, s, keep, "LA", spec.dtype_name)
    C = _sds(n, n, dtype=spec.dtype)
    V = _sds(n, m + p, dtype=spec.dtype)
    T = _sds(m + p, m + p, dtype=spec.dtype)
    j0 = jnp.asarray(0)
    tol = jnp.asarray(1e-9, spec.dtype)
    return [ProgramSpec(name="ke_restart_program", fn=prog,
                        args=(C, V, T, j0, tol))]


def _build_ke_prep(spec: AuditSpec, mesh):
    from repro.dist.eigensolver import ke_prep_program
    n, s, p = spec.n, spec.s, spec.p
    prog = ke_prep_program(mesh, n, p, spec.kb, spec.filter_degree, s,
                           "LA", spec.dtype_name)
    C = _sds(n, n, dtype=spec.dtype)
    X0 = _sds(n, p, dtype=spec.dtype)
    return [ProgramSpec(name="ke_prep_program", fn=prog, args=(C, X0))]


def _build_tt3(spec: AuditSpec, mesh):
    from repro.dist.eigensolver import tt3_program
    from repro.kernels.tridiag_eig.ops import SCAN_UNROLL
    n = spec.n
    s_pad = -(-spec.s // int(mesh.devices.size)) * int(mesh.devices.size)
    prog = tt3_program(mesh, n, s_pad, spec.tt3_max_iters, spec.tt3_iters,
                       SCAN_UNROLL, spec.dtype_name)
    d = _sds(n, dtype=spec.dtype)
    e = _sds(n - 1, dtype=spec.dtype)
    ks = jnp.arange(s_pad)
    X0 = _sds(n, s_pad, dtype=spec.dtype)
    return [ProgramSpec(name="tt3_program", fn=prog, args=(d, e, ks, X0))]


# kernel wrapper entries: (name, builder) — each forces the Pallas path
# off-TPU (interpret mode) so the lowered jaxpr contains the real
# pallas_call with its GridMapping for the kernel lint

def _build_stage_sentinels(spec: AuditSpec):
    """The standalone fused stage programs of ``gsyeig``: Cholesky + its
    health verdict (GS1) and the TRSM congruence + its finiteness verdict
    (GS2) each lower to ONE program whose sentinel is part of the trace."""
    from repro.core.gsyeig import _jit_chol, _jit_gs2_trsm
    n = spec.n
    B = _sds(n, n, dtype=spec.dtype)
    A = _sds(n, n, dtype=spec.dtype)
    U = _sds(n, n, dtype=spec.dtype)
    return [
        ProgramSpec(name="gs1_chol_sentinel", fn=_jit_chol, args=(B,),
                    with_hlo=False),
        ProgramSpec(name="gs2_trsm_sentinel", fn=_jit_gs2_trsm,
                    args=(A, U), with_hlo=False),
    ]


def _build_kernel_gemm(spec: AuditSpec):
    from repro.kernels.gemm.ops import gemm
    A = _sds(96, 64, dtype=spec.dtype)
    B = _sds(64, 96, dtype=spec.dtype)
    return [ProgramSpec(name="gemm", fn=gemm, args=(A, B),
                        kwargs=dict(force_interpret=True), with_hlo=False)]


def _build_kernel_symv(spec: AuditSpec):
    from repro.kernels.symv.ops import symv
    n = spec.n
    return [ProgramSpec(name="symv", fn=symv,
                        args=(_sds(n, n, dtype=spec.dtype),
                              _sds(n, dtype=spec.dtype)),
                        kwargs=dict(force_interpret=True), with_hlo=False)]


def _build_kernel_syr2k(spec: AuditSpec):
    from repro.kernels.syr2k.ops import syr2k
    n, k = spec.n, spec.w
    return [ProgramSpec(name="syr2k", fn=syr2k,
                        args=(_sds(n, n, dtype=spec.dtype),
                              _sds(n, k, dtype=spec.dtype),
                              _sds(n, k, dtype=spec.dtype)),
                        kwargs=dict(force_interpret=True), with_hlo=False)]


def _build_kernel_trsm(spec: AuditSpec):
    from repro.kernels.trsm.ops import trsm
    n, s = spec.n, spec.s
    return [ProgramSpec(name="trsm", fn=trsm,
                        args=(_sds(n, n, dtype=spec.dtype),
                              _sds(n, s, dtype=spec.dtype)),
                        kwargs=dict(force_interpret=True), with_hlo=False)]


def _build_kernel_band_mv(spec: AuditSpec):
    from repro.kernels.band_mv.ops import band_mv
    n, w = spec.n, spec.w

    def prog(band, x):
        return band_mv(band, x, w=w, force_interpret=True)

    return [ProgramSpec(name="band_mv", fn=prog,
                        args=(_sds(n, w + 1, dtype=spec.dtype),
                              _sds(n, dtype=spec.dtype)), with_hlo=False)]


def _build_kernel_rot_apply(spec: AuditSpec):
    from repro.kernels.rot_apply.ops import rot_apply
    G, L = 8, spec.n

    def prog(pairs, cs):
        return rot_apply(pairs, cs, force_kernel=True, force_interpret=True)

    return [ProgramSpec(name="rot_apply", fn=prog,
                        args=(_sds(G, 2, L, dtype=spec.dtype),
                              _sds(G, 2, dtype=spec.dtype)), with_hlo=False)]


def _build_kernel_house_panel(spec: AuditSpec):
    from repro.kernels.house_panel.ops import house_panel
    n, w = spec.n, spec.w

    def prog(E):
        return house_panel(E, w, force_kernel=True, force_interpret=True)

    return [ProgramSpec(name="house_panel", fn=prog,
                        args=(_sds(n, w, dtype=spec.dtype),),
                        with_hlo=False)]


def _build_kernel_tridiag_eig(spec: AuditSpec):
    from repro.kernels.tridiag_eig.ops import bisect_sturm
    n, s = spec.n, spec.s

    def prog(d, e):
        return bisect_sturm(d, e, jnp.arange(s), force_kernel=True,
                            force_interpret=True)

    return [ProgramSpec(name="bisect_sturm", fn=prog,
                        args=(_sds(n, dtype=spec.dtype),
                              _sds(n - 1, dtype=spec.dtype)),
                        with_hlo=False)]


# ------------------------------------------------------------------------
# registration
# ------------------------------------------------------------------------

def _n_panels(n: int, w: int) -> int:
    from repro.core.sbr import _n_panels as f
    return f(n, w)


_NO_COMM = dict(exact_collectives=0, max_dynamic_whiles=0)


def register_all(spec: Optional[AuditSpec] = None,
                 mesh=None) -> AuditSpec:
    """Populate the registry for ``spec`` (idempotent: re-registering
    replaces). ``mesh=None`` still registers the mesh entries; they are
    skipped at check time when fewer than 2 devices are visible."""
    spec = spec or AuditSpec()

    def _mesh():
        return mesh if mesh is not None else make_mesh_2dev()

    register(AuditEntry(
        name="core/reduce_to_band",
        build=partial(_build_reduce_to_band, spec),
        contract=BudgetContract(
            max_dispatches=TT1_FUSED_MAX_DISPATCHES, **_NO_COMM,
            notes="local fused TT1: whole window ladder is ONE program"),
        tags=("core", "quick")))

    register(AuditEntry(
        name="core/band_chase",
        build=partial(_build_band_chase, spec),
        contract=BudgetContract(
            max_dispatches=1, **_NO_COMM,
            notes="TT2 wavefront chase: one program, static fori ladder"),
        tags=("core", "quick")))

    register(AuditEntry(
        name="core/apply_q2",
        build=partial(_build_apply_q2, spec),
        contract=BudgetContract(
            max_dispatches=1, **_NO_COMM,
            notes="TT4 rotation replay onto the (n, s) Ritz slab"),
        tags=("core", "quick")))

    register(AuditEntry(
        name="core/tridiag_eig_batched",
        build=partial(_build_tridiag_eig_batched, spec),
        contract=BudgetContract(
            max_dispatches=1, **_NO_COMM,
            notes="TT3/TD2 fused bisection + inverse iteration"),
        tags=("core", "quick")))

    register(AuditEntry(
        name="core/lanczos_solve_jit",
        build=partial(_build_lanczos_solve_jit, spec),
        contract=BudgetContract(
            max_dispatches=1, exact_collectives=0, max_dynamic_whiles=1,
            min_isfinite_sites=1,
            sentinel_extra_dispatches=SENTINEL_EXTRA_DISPATCHES,
            notes="fully jitted Krylov driver: ONE dynamic restart while; "
                  "the restart-health sentinel is fused into it"),
        tags=("core", "quick")))

    for variant in ("TD", "TT", "KE", "KI"):
        register(AuditEntry(
            name=f"serve/solve_batched_{variant}",
            build=partial(_build_solve_batched, spec, variant),
            contract=BudgetContract(
                max_dispatches=1, exact_collectives=0,
                max_dynamic_whiles=0 if variant in ("TD", "TT") else 1,
                min_isfinite_sites=1,
                sentinel_extra_dispatches=SENTINEL_EXTRA_DISPATCHES,
                notes="one vmapped program per shape bucket (per-pencil "
                      "output sentinel fused in)"),
            tags=("serve", "quick")))

    # mixed/fast precision policies: the same bucketed pipelines with the
    # GEMM stages demoted + fused fp64 refinement. The contract DECLARES
    # the policy's downcast edges (core.precision.declared_downcasts) and
    # widens the dtype set; any demotion outside the declaration is still
    # a leak, and the budget shape must not change with precision.
    from repro.core.precision import declared_downcasts
    precision_allowed = {"mixed": MIXED_ALLOWED_DTYPES,
                         "fast": FAST_ALLOWED_DTYPES}
    for variant, precision in (("TD", "mixed"), ("TT", "mixed"),
                               ("KE", "mixed"), ("KI", "mixed"),
                               ("TT", "fast"), ("KE", "fast")):
        register(AuditEntry(
            name=f"serve/solve_batched_{variant}_{precision}",
            build=partial(_build_solve_batched, spec, variant, precision),
            contract=BudgetContract(
                max_dispatches=1, exact_collectives=0,
                max_dynamic_whiles=0 if variant in ("TD", "TT") else 1,
                min_isfinite_sites=1,
                sentinel_extra_dispatches=SENTINEL_EXTRA_DISPATCHES,
                allowed_dtypes=precision_allowed[precision],
                declared_downcasts=declared_downcasts(precision),
                notes=f"{precision} pipeline: declared GEMM-stage "
                      "demotions + fused fp64 refinement, same budget "
                      "shape as the fp64 bucket"),
            tags=("serve", "precision", "quick")))

    register(AuditEntry(
        name="dist/band_sweep_program",
        build=lambda: _build_band_sweep(spec, _mesh()),
        contract=BudgetContract(
            max_dispatches=TT1_FUSED_MAX_DISPATCHES,
            max_collectives_per_step=TT1_COLLECTIVES_PER_PANEL,
            exact_collectives=TT1_COLLECTIVES_PER_PANEL
                * _n_panels(spec.n, spec.w),
            max_dynamic_whiles=0,
            notes="dist TT1: gather(panel) + psum(coupling) + gather(Z) "
                  "per panel, all inside ONE fori_loop program"),
        needs_mesh=True, tags=("dist", "quick")))

    register(AuditEntry(
        name="dist/ke_restart_program",
        build=lambda: _build_ke_restart(spec, _mesh()),
        contract=BudgetContract(
            max_dispatches=1,
            max_collectives_per_step=KE_COLLECTIVES_PER_BLOCK_STEP,
            exact_collectives=KE_COLLECTIVES_PER_BLOCK_STEP
                * (spec.m // spec.p),
            max_dynamic_whiles=0,
            min_isfinite_sites=1,
            sentinel_extra_dispatches=SENTINEL_EXTRA_DISPATCHES,
            notes="ONE dispatch per thick restart; psum + all_gather per "
                  "p-column block step of the fused matvec; the restart "
                  "health verdict rides in the same program"),
        needs_mesh=True, tags=("dist", "quick")))

    register(AuditEntry(
        name="dist/ke_prep_program",
        build=lambda: _build_ke_prep(spec, _mesh()),
        contract=BudgetContract(
            max_dispatches=1,
            max_collectives_per_step=KE_COLLECTIVES_PER_BLOCK_STEP,
            max_collectives=KE_COLLECTIVES_PER_BLOCK_STEP
                * (spec.kb + spec.filter_degree + 2),
            max_dynamic_whiles=0,
            notes="bounds probe + Chebyshev filter, fused matvec budget"),
        needs_mesh=True, tags=("dist", "quick")))

    register(AuditEntry(
        name="dist/tt3_program",
        build=lambda: _build_tt3(spec, _mesh()),
        contract=BudgetContract(
            max_dispatches=1,
            max_collectives_per_step=1,
            exact_collectives=tt3_dist_collectives(spec.tt3_iters),
            max_dynamic_whiles=0,
            notes="spectrum-partitioned TT3: 1 lam all_gather + one Z "
                  "all_gather per inverse-iteration round"),
        needs_mesh=True, tags=("dist", "quick")))

    register(AuditEntry(
        name="resilience/stage_sentinels",
        build=partial(_build_stage_sentinels, spec),
        contract=BudgetContract(
            max_dispatches=2, exact_collectives=0, max_dynamic_whiles=0,
            min_isfinite_sites=2,
            sentinel_extra_dispatches=SENTINEL_EXTRA_DISPATCHES,
            notes="GS1 Cholesky + GS2 TRSM with their health verdicts "
                  "fused in: the stage programs gsyeig.solve dispatches "
                  "anyway, so the sentinels are dispatch-free"),
        tags=("resilience", "quick")))

    kernel_builders = {
        "gemm": _build_kernel_gemm, "symv": _build_kernel_symv,
        "syr2k": _build_kernel_syr2k, "trsm": _build_kernel_trsm,
        "band_mv": _build_kernel_band_mv,
        "rot_apply": _build_kernel_rot_apply,
        "house_panel": _build_kernel_house_panel,
        "tridiag_eig": _build_kernel_tridiag_eig,
    }
    for kname, builder in kernel_builders.items():
        register(AuditEntry(
            name=f"kernels/{kname}",
            build=partial(builder, spec),
            contract=BudgetContract(
                max_dispatches=1, exact_collectives=0,
                max_dynamic_whiles=0, min_pallas_calls=1,
                notes="wrapper pads to tile multiples and launches the "
                      "Pallas kernel (interpret mode off-TPU)"),
            tags=("kernels", "quick")))

    return spec


__all__ = [
    "AuditSpec", "register_all", "make_mesh_2dev",
    "MIXED_ALLOWED_DTYPES", "FAST_ALLOWED_DTYPES",
    "TT1_FUSED_MAX_DISPATCHES", "TT1_COLLECTIVES_PER_PANEL",
    "TT1_STEPWISE_DISPATCHES_PER_PANEL", "KE_COLLECTIVES_PER_BLOCK_STEP",
    "KE_HLO_ALL_REDUCE_MAX", "KE_HLO_ALL_GATHER_MAX",
    "TT3_HLO_ALL_GATHER_MAX", "SENTINEL_EXTRA_DISPATCHES",
    "ke_dispatch_budget",
    "lanczos_block_dispatch_budget", "lanczos_single_dispatch_budget",
    "tt3_dist_collectives",
]
