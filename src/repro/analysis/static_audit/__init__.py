"""Static program auditor: budget contracts for every solver path.

Lower (never run) each solver program, walk its jaxpr/StableHLO into a
:class:`~repro.analysis.static_audit.profile.ProgramProfile`, and enforce
the declarative budget contracts of :mod:`contracts` — the single source
of truth for the repo's published invariants (TT1 fused sweep <= 3
dispatches, KE <= 2 collectives per block step, dist TT3 exactly
``1 + iters`` collectives, ...). ``launch/audit.py`` is the CLI;
``assert_program_budget`` (tests/conftest.py) is the pytest fixture.
"""
from .contracts import (AuditSpec, KE_COLLECTIVES_PER_BLOCK_STEP,
                        KE_HLO_ALL_GATHER_MAX, KE_HLO_ALL_REDUCE_MAX,
                        TT1_COLLECTIVES_PER_PANEL,
                        TT1_FUSED_MAX_DISPATCHES,
                        TT1_STEPWISE_DISPATCHES_PER_PANEL,
                        TT3_HLO_ALL_GATHER_MAX, ke_dispatch_budget,
                        lanczos_block_dispatch_budget,
                        lanczos_single_dispatch_budget, make_mesh_2dev,
                        register_all, tt3_dist_collectives)
from .crosscheck import CrossCheck, all_ok, crosscheck_stagecosts
from .dtype_lint import find_precision_leaks, lint_reports
from .pallas_lint import (LintFinding, errors, lint_pallas_profiles,
                          lint_signature_parity)
from .profile import (CollectiveSite, LoopInfo, PallasCallInfo,
                      ProgramProfile, hlo_counts, profile_fn, profile_jaxpr)
from .registry import (AuditEntry, BudgetContract, EntryReport, ProgramSpec,
                       check_all, check_entry, clear_registry, entries,
                       get_entry, register)

__all__ = [
    "AuditSpec", "register_all", "make_mesh_2dev",
    "ProgramProfile", "CollectiveSite", "LoopInfo", "PallasCallInfo",
    "profile_fn", "profile_jaxpr", "hlo_counts",
    "AuditEntry", "BudgetContract", "EntryReport", "ProgramSpec",
    "register", "get_entry", "entries", "clear_registry", "check_entry",
    "check_all",
    "CrossCheck", "crosscheck_stagecosts", "all_ok",
    "LintFinding", "lint_pallas_profiles", "lint_signature_parity", "errors",
    "find_precision_leaks", "lint_reports",
    "TT1_FUSED_MAX_DISPATCHES", "TT1_COLLECTIVES_PER_PANEL",
    "TT1_STEPWISE_DISPATCHES_PER_PANEL", "KE_COLLECTIVES_PER_BLOCK_STEP",
    "KE_HLO_ALL_REDUCE_MAX", "KE_HLO_ALL_GATHER_MAX",
    "TT3_HLO_ALL_GATHER_MAX", "ke_dispatch_budget",
    "lanczos_block_dispatch_budget", "lanczos_single_dispatch_budget",
    "tt3_dist_collectives",
]
