"""Pallas kernel lint: BlockSpec tiling, VMEM footprint, signature parity.

Three static checks over every ``kernels/*`` subpackage, none of which
execute a kernel:

* **tile multiples** — TPU fp32 tiling is (8, 128) sublane x lane (see
  the accelerator guide); a block whose sublane dim exceeds 8 without
  being a multiple of 8, or whose lane dim exceeds 128 without being a
  multiple of 128, forces Mosaic into strided relayouts. Sub-tile blocks
  (lane < 128) are *warnings*, not errors: the wrappers deliberately
  clamp tiles for small operands and Mosaic pads them — fine for the
  audit spec's toy shapes, worth seeing in AUDIT.json.
* **VMEM footprint** — double-buffered residency of all blocks must fit
  the ~16 MiB/core budget (blocks x itemsize x 2).
* **ref-vs-kernel signature parity** — every public wrapper ``X`` with a
  reference ``X_ref`` must accept the ref's required array arguments as
  its leading parameters (wrapper-only tuning knobs — ``bm``, ``block``,
  ``force_interpret`` — must come after, with defaults), so tests and
  callers can swap implementations without shims.
"""
from __future__ import annotations

import dataclasses
import importlib
import inspect
import pkgutil
from typing import Dict, List, Optional

from .registry import EntryReport

SUBLANE = 8
LANE = 128
VMEM_BUDGET_BYTES = 16 * 1024 * 1024   # ~16 MiB/core
DTYPE_BYTES = 8                        # fp64 pipelines (worst case)

#: wrapper -> ref pairs that don't follow the ``X`` / ``X_ref`` convention
_REF_ALIASES = {"invit_batched": "invit_ref",
                "tridiag_eig_batched": None,   # composite: no single ref
                "symm_block": "symm_block_ref"}


@dataclasses.dataclass
class LintFinding:
    kernel: str
    check: str       # "tile" | "vmem" | "signature"
    severity: str    # "error" | "warn"
    detail: str

    def as_json_dict(self) -> dict:
        return dataclasses.asdict(self)


def _lint_block_shape(kernel: str, shape) -> List[LintFinding]:
    out: List[LintFinding] = []
    if not shape:
        return out
    lane = shape[-1]
    if lane > LANE and lane % LANE:
        out.append(LintFinding(kernel, "tile", "error",
                               f"lane dim {lane} > {LANE} and not a "
                               f"multiple of {LANE} (block {shape})"))
    elif lane < LANE and lane % SUBLANE:
        out.append(LintFinding(kernel, "tile", "warn",
                               f"lane dim {lane} not {SUBLANE}-aligned "
                               f"(block {shape}; Mosaic pads)"))
    elif lane < LANE:
        out.append(LintFinding(kernel, "tile", "warn",
                               f"sub-lane-width tile {lane} < {LANE} "
                               f"(block {shape}; padded, fine for small "
                               "operands)"))
    if len(shape) >= 2:
        sub = shape[-2]
        if sub > SUBLANE and sub % SUBLANE:
            out.append(LintFinding(kernel, "tile", "error",
                                   f"sublane dim {sub} > {SUBLANE} and not "
                                   f"a multiple of {SUBLANE} "
                                   f"(block {shape})"))
    return out


def lint_pallas_profiles(reports: Dict[str, EntryReport]
                         ) -> List[LintFinding]:
    """Tile + VMEM lint over every pallas_call the profiled entries launch."""
    findings: List[LintFinding] = []
    seen = set()
    for name, rep in reports.items():
        if rep.skipped:
            continue
        for prof in rep.profiles:
            for pc in prof.pallas_calls:
                for shape in pc.block_shapes:
                    for f in _lint_block_shape(name, shape):
                        key = (f.kernel, f.check, f.severity, f.detail)
                        if key not in seen:
                            seen.add(key)
                            findings.append(f)
                vmem = sum(_prod(s) for s in pc.block_shapes) \
                    * DTYPE_BYTES * 2
                if vmem > VMEM_BUDGET_BYTES:
                    findings.append(LintFinding(
                        name, "vmem", "error",
                        f"double-buffered block residency ~{vmem} B "
                        f"exceeds {VMEM_BUDGET_BYTES} B "
                        f"(blocks {pc.block_shapes})"))
    return findings


def _prod(shape) -> int:
    out = 1
    for d in shape:
        out *= int(d)
    return out


def _required_params(fn) -> List[str]:
    sig = inspect.signature(fn)
    return [p.name for p in sig.parameters.values()
            if p.default is inspect.Parameter.empty
            and p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)]


def _all_params(fn) -> List[str]:
    return list(inspect.signature(fn).parameters)


def lint_signature_parity(package: str = "repro.kernels"
                          ) -> List[LintFinding]:
    """Wrapper-vs-ref parity across every ``kernels/*`` ops module."""
    findings: List[LintFinding] = []
    pkg = importlib.import_module(package)
    for info in pkgutil.iter_modules(pkg.__path__):
        if not info.ispkg:
            continue
        try:
            ops = importlib.import_module(f"{package}.{info.name}.ops")
        except ImportError as exc:
            findings.append(LintFinding(info.name, "signature", "error",
                                        f"ops module failed to import: "
                                        f"{exc}"))
            continue
        pairs = 0
        for attr in getattr(ops, "__all__", dir(ops)):
            fn = getattr(ops, attr, None)
            if not callable(fn) or attr.endswith("_ref"):
                continue
            ref_name = _REF_ALIASES.get(attr, f"{attr}_ref")
            if ref_name is None:
                continue
            ref = getattr(ops, ref_name, None)
            if ref is None:
                continue
            pairs += 1
            try:
                req = _required_params(ref)
                wrapper_params = _all_params(fn)
            except (TypeError, ValueError):
                continue
            head = wrapper_params[:len(req)]
            if head != req:
                findings.append(LintFinding(
                    info.name, "signature", "error",
                    f"{attr}({', '.join(wrapper_params)}) does not lead "
                    f"with {ref_name}'s required args ({', '.join(req)})"))
        if pairs == 0:
            findings.append(LintFinding(
                info.name, "signature", "warn",
                "no wrapper/ref pair found to compare"))
    return findings


def errors(findings: List[LintFinding]) -> List[LintFinding]:
    return [f for f in findings if f.severity == "error"]


__all__ = ["LintFinding", "lint_pallas_profiles", "lint_signature_parity",
           "errors", "SUBLANE", "LANE", "VMEM_BUDGET_BYTES"]
