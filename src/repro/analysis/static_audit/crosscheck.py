"""Cost-model cross-check: StageCost fields vs statically counted reality.

``analysis.variant_model`` prices each stage as ``(flops, bytes,
collective_bytes, dispatches, collectives, loop_steps)`` and the router
trusts those numbers. This module closes the loop *at lint time*: for
every stage with a registered audit entry it compares the model's
``dispatches`` / ``collectives`` / ``loop_steps`` against the values the
:mod:`profile` walker counts in the lowered program, so router drift
(model says 2 collectives per panel, program does 3) is caught by
``launch/audit.py`` instead of by a benchmark regression weeks later.

Relations are *exact* wherever the implementation is exactly countable
(collectives per block step, dispatch structure, the TT2/TT4 fori-ladder
trip counts) and tolerance-based where the model is a smooth formula over
a discrete schedule (total panel count ``3 n/w`` vs ``3 n_panels``; the
TT3 trip count, where the model omits the outer fori wrappers and the
O(1) setup scans the walker also sees).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

from repro.analysis import variant_model as vm

from .contracts import AuditSpec, KE_COLLECTIVES_PER_BLOCK_STEP, \
    TT1_COLLECTIVES_PER_PANEL
from .registry import EntryReport


@dataclasses.dataclass
class CrossCheck:
    stage: str
    field: str
    model_value: float
    counted_value: float
    relation: str          # "exact" | "rel<=tol"
    tol: float
    ok: bool
    note: str = ""

    def as_json_dict(self) -> dict:
        return dataclasses.asdict(self)


def _exact(stage, field, model, counted, note="") -> CrossCheck:
    return CrossCheck(stage, field, float(model), float(counted), "exact",
                      0.0, float(model) == float(counted), note)


def _rel(stage, field, model, counted, tol, note="") -> CrossCheck:
    denom = max(abs(float(counted)), 1.0)
    err = abs(float(model) - float(counted)) / denom
    return CrossCheck(stage, field, float(model), float(counted),
                      f"rel<={tol}", tol, err <= tol, note)


def crosscheck_stagecosts(reports: Dict[str, EntryReport],
                          spec: Optional[AuditSpec] = None
                          ) -> List[CrossCheck]:
    """Compare StageCost fields to the statically counted program shape.

    ``reports`` is ``{entry_name: EntryReport}`` from ``registry.check_all``
    (mesh entries may be absent on a single device — their checks are
    simply omitted)."""
    spec = spec or AuditSpec()
    n, s, w, p, m = spec.n, spec.s, spec.w, spec.p, spec.m
    tt = vm.stage_costs("TT", n, s, band_width=w)
    checks: List[CrossCheck] = []

    # ---- TT1: the fused panel sweep (distributed entry) ------------------
    r = reports.get("dist/band_sweep_program")
    if r is not None and not r.skipped:
        checks.append(_exact(
            "TT1", "dispatches", tt["TT1"].dispatches, r.dispatches,
            "sweep program + band repack"))
        model_per_panel = tt["TT1"].collectives / (n / max(w, 1))
        checks.append(_exact(
            "TT1", "collectives_per_panel", model_per_panel,
            r.max_collectives_per_step,
            f"gather+psum+gather = {TT1_COLLECTIVES_PER_PANEL}"))
        checks.append(_rel(
            "TT1", "collectives", tt["TT1"].collectives,
            r.total_collectives, 0.35,
            "model 3 n/w vs counted 3 n_panels (discrete panel schedule)"))

    # ---- TT2: the wavefront bulge chase ----------------------------------
    r = reports.get("core/band_chase")
    if r is not None and not r.skipped:
        counted_steps = sum(p_.loop_steps_static for p_ in r.profiles)
        checks.append(_exact(
            "TT2", "dispatches", tt["TT2"].dispatches, r.dispatches))
        checks.append(_exact(
            "TT2", "loop_steps", tt["TT2"].loop_steps, counted_steps,
            "_chase_loop_steps mirrors the pass schedule exactly"))

    # ---- TT3: fused bisection + inverse iteration ------------------------
    r = reports.get("core/tridiag_eig_batched")
    if r is not None and not r.skipped:
        counted_steps = sum(p_.loop_steps_static for p_ in r.profiles)
        checks.append(_exact(
            "TT3", "dispatches", tt["TT3"].dispatches, r.dispatches))
        checks.append(_rel(
            "TT3", "loop_steps", tt["TT3"].loop_steps, counted_steps, 0.15,
            "model omits outer fori wrappers and O(1) setup scans"))

    # ---- TT4: rotation replay --------------------------------------------
    r = reports.get("core/apply_q2")
    if r is not None and not r.skipped:
        counted_steps = sum(p_.loop_steps_static for p_ in r.profiles)
        checks.append(_exact(
            "TT4", "loop_steps", tt["TT4"].loop_steps, counted_steps,
            "_replay_loop_steps mirrors the replay schedule exactly"))

    # ---- KE: communication-avoiding block Lanczos ------------------------
    r = reports.get("dist/ke_restart_program")
    if r is not None and not r.skipped:
        n_iter = vm.estimate_lanczos_iters(n, s, m, p=p)
        ke = vm.stage_costs("KE", n, s, m=m, p=p, n_iter=n_iter)["KE_iter"]
        n_restart = vm.estimate_lanczos_restarts(n_iter, s, m, p)
        n_block_steps = -(-n_iter // p)
        checks.append(_exact(
            "KE", "dispatches_per_restart", 1, r.dispatches,
            "ONE fused program per thick restart"))
        checks.append(_exact(
            "KE", "dispatches", ke.dispatches, n_restart + 2,
            "model restart+2 == registry restart x 1 + prep + extraction"))
        checks.append(_exact(
            "KE", "collectives_per_block_step",
            ke.collectives / n_block_steps, r.max_collectives_per_step,
            f"psum + all_gather = {KE_COLLECTIVES_PER_BLOCK_STEP}"))
        checks.append(_exact(
            "KE", "collectives_per_restart_segment",
            KE_COLLECTIVES_PER_BLOCK_STEP * (m // p), r.total_collectives,
            "2 collectives x (m/p) block steps in the fused segment"))

    return checks


def all_ok(checks: List[CrossCheck]) -> bool:
    return all(c.ok for c in checks)


__all__ = ["CrossCheck", "crosscheck_stagecosts", "all_ok"]
