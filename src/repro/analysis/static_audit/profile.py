"""Static ProgramProfile extraction: lower a solver program, never run it.

Two complementary views of the same program feed the auditor:

* the **jaxpr walk** (`profile_fn` / `profile_jaxpr`) recurses through
  every sub-jaxpr (pjit bodies, shard_map regions, scan/while/cond
  branches) and produces *static* counts: a ``scan`` with a Python-static
  ``length`` multiplies everything inside it, so a collective inside a
  ``fori_loop`` over panels counts once per panel — exactly the number the
  paper-level contracts are written in ("3 collectives per panel",
  "1 + iters all_gathers");
* the **StableHLO text** (`hlo_counts`) counts each op once per loop
  *body* — the view PR 6's hand-grepped assertions used — kept as a
  cross-reference and because some structure (``custom_call`` targets)
  only exists post-lowering.

Nothing here executes device code: ``jax.make_jaxpr`` and ``.lower()``
trace with abstract values, so the audit of a 2-device mesh program runs
fine on forced host devices in CI.

Counting semantics worth pinning down:

* ``cond`` branches are **summed** — a collective present in either branch
  counts. This is a deliberate upper bound: the KE segment guards its
  block step behind ``lax.cond(j >= j0)`` and the contract must hold for
  the branch that communicates.
* ``while`` loops with traced bounds have no static trip count; their
  bodies count **once** and the loop is reported in ``dynamic_whiles`` so
  a contract can cap how many dynamic loops a program is allowed.
* ``scan`` respects ``unroll``: effective sequential steps are
  ``ceil(length / unroll)`` — the quantity ``variant_model`` prices as
  ``loop_steps`` (the unroll is the fused TT3 path's whole speedup).
* ``pallas_call`` bodies are *not* recursed into for the op counts (they
  are device kernels, not HLO); their grid/BlockSpec structure is captured
  in ``pallas_calls`` for the kernel lint.
"""
from __future__ import annotations

import dataclasses
import math
from collections import Counter
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
from jax.core import ClosedJaxpr, Jaxpr

# jaxpr primitive name -> canonical collective kind (the HLO-level name)
COLLECTIVE_KINDS: Dict[str, str] = {
    "psum": "all_reduce",
    "pmax": "all_reduce",
    "pmin": "all_reduce",
    "all_gather": "all_gather",
    "psum_scatter": "reduce_scatter",
    "reduce_scatter": "reduce_scatter",
    "ppermute": "collective_permute",
    "pshuffle": "collective_permute",
    "all_to_all": "all_to_all",
}

#: StableHLO ops counted in the lowered text (once per loop body).
HLO_OPS: Tuple[str, ...] = (
    "stablehlo.all_reduce", "stablehlo.all_gather",
    "stablehlo.reduce_scatter", "stablehlo.collective_permute",
    "stablehlo.all_to_all", "stablehlo.while", "stablehlo.custom_call",
    "stablehlo.dynamic_slice", "stablehlo.convert",
)

_DOWNCAST_TARGETS = ("float32", "bfloat16", "float16")


@dataclasses.dataclass
class CollectiveSite:
    """One collective equation, with its static (loop-multiplied) count."""
    kind: str               # all_reduce / all_gather / ...
    primitive: str          # the jaxpr primitive (psum, all_gather, ...)
    shape: Tuple[int, ...]
    dtype: str
    bytes_per_call: int
    static_count: int       # times this site executes per program dispatch

    def as_json_dict(self) -> dict:
        return {"kind": self.kind, "primitive": self.primitive,
                "shape": list(self.shape), "dtype": self.dtype,
                "bytes_per_call": self.bytes_per_call,
                "static_count": self.static_count}


@dataclasses.dataclass
class LoopInfo:
    """One scan/while equation (a fori_loop lowers to one of these)."""
    kind: str                       # "scan" | "while"
    length: Optional[int]           # static trip count (None for while)
    unroll: int
    steps: Optional[int]            # ceil(length/unroll) * outer multiplier
    collectives_per_trip: int       # collectives one trip executes
    depth: int                      # loop nesting depth (0 = top level)

    def as_json_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class PallasCallInfo:
    name: str
    grid: Tuple[int, ...]
    block_shapes: Tuple[Tuple[int, ...], ...]
    static_count: int
    vmem_bytes_estimate: int        # sum of blocks x itemsize x 2 (dbl-buf)

    def as_json_dict(self) -> dict:
        return {"name": self.name, "grid": list(self.grid),
                "block_shapes": [list(b) for b in self.block_shapes],
                "static_count": self.static_count,
                "vmem_bytes_estimate": self.vmem_bytes_estimate}


@dataclasses.dataclass
class ProgramProfile:
    name: str
    primitive_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    collectives: List[CollectiveSite] = dataclasses.field(default_factory=list)
    loops: List[LoopInfo] = dataclasses.field(default_factory=list)
    pallas_calls: List[PallasCallInfo] = dataclasses.field(default_factory=list)
    converts: Dict[str, int] = dataclasses.field(default_factory=dict)
    input_dtypes: List[str] = dataclasses.field(default_factory=list)
    output_dtypes: List[str] = dataclasses.field(default_factory=list)
    weak_type_inputs: int = 0
    dynamic_whiles: int = 0
    dynamic_slices: int = 0
    gathers: int = 0
    callbacks: int = 0
    loop_steps_static: int = 0
    hlo_counts: Optional[Dict[str, int]] = None

    # ---- derived views ---------------------------------------------------
    def collective_counts(self) -> Dict[str, int]:
        c: Counter = Counter()
        for site in self.collectives:
            c[site.kind] += site.static_count
        return dict(c)

    def total_collectives(self) -> int:
        return sum(s.static_count for s in self.collectives)

    def collective_bytes(self) -> int:
        return sum(s.bytes_per_call * s.static_count
                   for s in self.collectives)

    def max_collectives_per_loop_trip(self) -> int:
        """Collectives a single trip of the busiest loop executes — the
        'per block step' / 'per panel' number the contracts are written in.
        """
        return max((lp.collectives_per_trip for lp in self.loops), default=0)

    def f64_downcasts(self) -> Dict[str, int]:
        """convert_element_type sites demoting float64 — precision leaks."""
        return {k: v for k, v in self.converts.items()
                if k.startswith("float64->")
                and k.split("->")[1] in _DOWNCAST_TARGETS}

    def dtypes_seen(self) -> List[str]:
        seen = set(self.input_dtypes) | set(self.output_dtypes)
        for k in self.converts:
            seen.update(k.split("->"))
        return sorted(seen)

    def as_json_dict(self) -> dict:
        return {
            "name": self.name,
            "collective_counts": self.collective_counts(),
            "total_collectives": self.total_collectives(),
            "collective_bytes": self.collective_bytes(),
            "max_collectives_per_loop_trip":
                self.max_collectives_per_loop_trip(),
            "collectives": [s.as_json_dict() for s in self.collectives],
            "loops": [lp.as_json_dict() for lp in self.loops],
            "loop_steps_static": self.loop_steps_static,
            "dynamic_whiles": self.dynamic_whiles,
            "dynamic_slices": self.dynamic_slices,
            "gathers": self.gathers,
            "callbacks": self.callbacks,
            "pallas_calls": [p.as_json_dict() for p in self.pallas_calls],
            "converts": dict(self.converts),
            "f64_downcasts": self.f64_downcasts(),
            "input_dtypes": self.input_dtypes,
            "output_dtypes": self.output_dtypes,
            "weak_type_inputs": self.weak_type_inputs,
            "dtypes_seen": self.dtypes_seen(),
            "hlo_counts": self.hlo_counts,
        }


# --------------------------------------------------------------------------
# jaxpr walking
# --------------------------------------------------------------------------

def _subjaxprs(eqn):
    """Every jaxpr reachable from an equation's params.

    pjit/scan/while store ClosedJaxpr; shard_map stores a bare Jaxpr;
    cond stores a list of branches — yield them all.
    """
    for v in eqn.params.values():
        vs = v if isinstance(v, (list, tuple)) else (v,)
        for sub in vs:
            if isinstance(sub, ClosedJaxpr):
                yield sub.jaxpr
            elif isinstance(sub, Jaxpr):
                yield sub


def _aval_bytes(aval) -> int:
    try:
        return int(math.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def _scan_length(eqn) -> Tuple[int, int]:
    length = int(eqn.params.get("length", 0) or 0)
    unroll = eqn.params.get("unroll", 1)
    unroll = int(unroll) if isinstance(unroll, int) and unroll else 1
    return length, max(unroll, 1)


def _count_body_collectives(jx: Jaxpr) -> int:
    """Collectives ONE trip of a loop body executes (nested loops
    multiplied by their static lengths; cond branches summed)."""
    total = 0
    for eqn in jx.eqns:
        name = eqn.primitive.name
        if name in COLLECTIVE_KINDS:
            total += 1
        if name == "pallas_call":
            continue
        mult = 1
        if name == "scan":
            length, _ = _scan_length(eqn)
            mult = max(length, 1)
        for sub in _subjaxprs(eqn):
            total += mult * _count_body_collectives(sub)
    return total


def _pallas_info(eqn, mult: int) -> PallasCallInfo:
    name = str(eqn.params.get("name", "")) or "pallas_call"
    grid: Tuple[int, ...] = ()
    blocks: List[Tuple[int, ...]] = []
    vmem = 0
    gm = eqn.params.get("grid_mapping")
    if gm is not None:
        try:
            grid = tuple(int(g) for g in gm.grid)
        except Exception:
            grid = ()
        for bm in getattr(gm, "block_mappings", ()) or ():
            if bm is None:
                continue
            shape = tuple(int(d) for d in getattr(bm, "block_shape", ())
                          if isinstance(d, int))
            if shape:
                blocks.append(shape)
                # double-buffered block residency, fp32 floor of 4 B/elt —
                # refined per-dtype by the kernel lint when avals are known
                vmem += int(math.prod(shape)) * 4 * 2
    return PallasCallInfo(name=name, grid=grid, block_shapes=tuple(blocks),
                          static_count=mult, vmem_bytes_estimate=vmem)


def _walk(jx: Jaxpr, mult: int, depth: int, prof: ProgramProfile) -> None:
    for eqn in jx.eqns:
        name = eqn.primitive.name
        prof.primitive_counts[name] = (
            prof.primitive_counts.get(name, 0) + mult)
        if name in COLLECTIVE_KINDS:
            out = eqn.outvars[0].aval
            prof.collectives.append(CollectiveSite(
                kind=COLLECTIVE_KINDS[name], primitive=name,
                shape=tuple(int(d) for d in out.shape),
                dtype=str(out.dtype), bytes_per_call=_aval_bytes(out),
                static_count=mult))
        elif name == "convert_element_type":
            src = str(eqn.invars[0].aval.dtype)
            dst = str(eqn.params.get("new_dtype"))
            key = f"{src}->{dst}"
            prof.converts[key] = prof.converts.get(key, 0) + mult
        elif name in ("dynamic_slice", "dynamic_update_slice"):
            prof.dynamic_slices += mult
        elif name == "gather":
            prof.gathers += mult
        elif "callback" in name:
            prof.callbacks += mult
        elif name == "pallas_call":
            prof.pallas_calls.append(_pallas_info(eqn, mult))
            continue                       # device kernel: don't recurse

        inner = mult
        if name == "scan":
            length, unroll = _scan_length(eqn)
            steps = math.ceil(length / unroll) if length else 0
            body_coll = sum(_count_body_collectives(s)
                            for s in _subjaxprs(eqn))
            prof.loops.append(LoopInfo(
                kind="scan", length=length, unroll=unroll,
                steps=steps * mult, collectives_per_trip=body_coll,
                depth=depth))
            prof.loop_steps_static += steps * mult
            inner = mult * max(length, 1)
            depth_inner = depth + 1
        elif name == "while":
            body_coll = sum(_count_body_collectives(s)
                            for s in _subjaxprs(eqn))
            prof.loops.append(LoopInfo(
                kind="while", length=None, unroll=1, steps=None,
                collectives_per_trip=body_coll, depth=depth))
            prof.dynamic_whiles += mult
            depth_inner = depth + 1
        else:
            depth_inner = depth + 1 if name == "cond" else depth
        for sub in _subjaxprs(eqn):
            _walk(sub, inner, depth_inner, prof)


def profile_jaxpr(closed: ClosedJaxpr, name: str = "") -> ProgramProfile:
    prof = ProgramProfile(name=name)
    jx = closed.jaxpr
    prof.input_dtypes = [str(v.aval.dtype) for v in jx.invars
                         if hasattr(v.aval, "dtype")]
    prof.output_dtypes = [str(v.aval.dtype) for v in jx.outvars
                          if hasattr(v.aval, "dtype")]
    prof.weak_type_inputs = sum(
        1 for v in jx.invars if getattr(v.aval, "weak_type", False))
    _walk(jx, 1, 0, prof)
    return prof


def hlo_counts(text: str) -> Dict[str, int]:
    """Occurrences of each audited StableHLO op in lowered module text
    (once per loop body — the PR-6-era grep view, kept for cross-ref)."""
    return {op: text.count(op) for op in HLO_OPS}


def profile_fn(fn: Callable, *args: Any, name: str = "",
               with_hlo: bool = True, **kwargs: Any) -> ProgramProfile:
    """Lower ``fn`` on abstract args (ShapeDtypeStructs work) — never run it.

    ``fn`` may be a plain traceable callable or an already-jitted program;
    the jaxpr walk uses ``jax.make_jaxpr`` either way, and the StableHLO
    view uses ``fn.lower`` when available (falling back to ``jax.jit``).
    ``kwargs`` are treated as *static* (bound before tracing, so a jitted
    fn's ``static_argnames`` stay hashable); array operands go in ``args``.
    """
    import functools
    trace_fn = functools.partial(fn, **kwargs) if kwargs else fn
    closed = jax.make_jaxpr(trace_fn)(*args)
    prof = profile_jaxpr(closed, name=name or getattr(fn, "__name__", "fn"))
    if with_hlo:
        lower = getattr(fn, "lower", None)
        if lower is None:
            lower = jax.jit(fn).lower
        prof.hlo_counts = hlo_counts(lower(*args, **kwargs).as_text())
    return prof


__all__ = ["ProgramProfile", "CollectiveSite", "LoopInfo", "PallasCallInfo",
           "profile_fn", "profile_jaxpr", "hlo_counts", "COLLECTIVE_KINDS",
           "HLO_OPS"]
