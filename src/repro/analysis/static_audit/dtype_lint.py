"""Dtype policy lint: precision leaks and recompile hazards.

Two failure modes the fp64 pipelines must never pick up silently:

* **precision leaks** — a ``convert_element_type`` demoting float64 to
  float32/bf16/fp16 that the program's contract did not DECLARE (a Python
  ``float32`` literal, an fp32 intermediate from a library helper). The
  walker records every conversion with its static count;
  ``find_precision_leaks`` surfaces the demotions not covered by the
  contract's ``declared_downcasts`` policy — the mixed/fast pipelines
  declare their on-purpose GEMM-stage demotions, the fp64 contracts
  declare nothing, so for them every downcast stays a leak. Each
  registered contract also forbids undeclared ones
  (``forbid_f64_downcasts``), so the CLI fails on one.
* **recompile hazards** — weak-typed inputs to a cached program: a
  Python scalar passed where an array is expected traces a *different*
  program than a committed-dtype array of the same value, so alternating
  call styles silently double-compiles a bucket. The profile counts
  weak-typed inputs; entries meant to be served from a shape-bucket cache
  should show zero. (The dynamic side of this — same bucket shape must
  hit the jit cache — is pinned by ``tests/test_static_audit.py``.)
"""
from __future__ import annotations

from typing import Dict, List, Sequence

from .profile import ProgramProfile
from .registry import EntryReport


def find_precision_leaks(profile: ProgramProfile,
                         declared: Sequence[str] = ()) -> List[str]:
    """Human-readable leak descriptions for one profiled program.

    ``declared`` lists the downcast edges the owning contract's precision
    policy permits (``BudgetContract.declared_downcasts``); only the
    demotions outside it are leaks.
    """
    allowed = set(declared)
    return [f"{profile.name}: {conv} x{count}"
            for conv, count in sorted(profile.f64_downcasts().items())
            if conv not in allowed]


def lint_reports(reports: Dict[str, EntryReport]) -> dict:
    """Aggregate dtype findings across entries for AUDIT.json."""
    leaks: List[str] = []
    weak: Dict[str, int] = {}
    converts: Dict[str, int] = {}
    for name, rep in reports.items():
        if rep.skipped:
            continue
        declared = rep.contract.declared_downcasts
        for prof in rep.profiles:
            leaks.extend(f"{name}/{leak}"
                         for leak in find_precision_leaks(prof, declared))
            if prof.weak_type_inputs:
                weak[f"{name}/{prof.name}"] = prof.weak_type_inputs
            for conv, count in prof.converts.items():
                converts[conv] = converts.get(conv, 0) + count
    return {"precision_leaks": leaks,
            "weak_type_inputs": weak,
            "convert_counts": converts,
            "ok": not leaks}


__all__ = ["find_precision_leaks", "lint_reports"]
