"""Three-term roofline analysis from the dry-run's compiled artifacts.

Terms (per (arch, shape, mesh) cell, TPU v5e constants):
    compute    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = collective_bytes_per_chip / link_bw_effective

Semantics notes (important — see EXPERIMENTS.md §Roofline):
  * XLA's SPMD program IS the per-chip program, so cost_analysis() flops /
    bytes and the HLO-parsed collective bytes are already per-chip. The
    system-prompt formula divides a *global* total by `chips`; per-chip
    numbers and global/chips are the same quantity.
  * XLA counts while-loop (scan) bodies ONCE. The dry-run therefore lowers
    each cell twice — at R repeats and at R'=1 of the layer scan — and
    solves flops = A + R*B (two-point extrapolation). The same correction
    applies to bytes and collective bytes.
  * link_bw_effective: ~50 GB/s per ICI link; a v5e chip has links on 2
    axes usable concurrently for the dominant ring collectives, but we use
    ONE link conservatively (report both if it changes the bottleneck).
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
from typing import Optional

# ---- TPU v5e hardware constants (per chip) --------------------------------
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_LINK_BW = 50e9              # B/s per link (conservative single-link)


def cost_analysis_dict(compiled) -> dict:
    """Normalized ``compiled.cost_analysis()``.

    jax has returned a dict, a list of one dict per computation, or None
    across versions; every consumer here wants a flat dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca or {})


@dataclasses.dataclass
class RooflineCell:
    arch: str
    shape: str
    mesh: str
    kind: str
    flops: float                # per-chip, loop-corrected
    bytes_accessed: float       # per-chip, loop-corrected
    collective_bytes: float     # per-chip, loop-corrected
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    bottleneck: str = ""
    model_flops: float = 0.0    # 6*N*D analytic
    useful_ratio: float = 0.0   # MODEL_FLOPS / (chips * HLO_FLOPs)
    chips: int = 256
    note: str = ""

    def finalize(self) -> "RooflineCell":
        self.t_compute = self.flops / PEAK_FLOPS_BF16
        self.t_memory = self.bytes_accessed / HBM_BW
        self.t_collective = self.collective_bytes / ICI_LINK_BW
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        self.bottleneck = max(terms, key=terms.get)
        if self.model_flops and self.flops:
            self.useful_ratio = self.model_flops / (self.chips * self.flops)
        return self


def model_flops_for(arch: str, shape_name: str) -> float:
    """Analytic MODEL_FLOPS: 6*N_active*D (train) or 2*N_active*B (decode)."""
    from repro.configs import get_config
    from repro.models.config import shape_by_name
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens
    # decode: one new token per sequence
    return 2.0 * n_active * shape.global_batch


def load_cell(path: str) -> Optional[dict]:
    with open(path) as f:
        rec = json.load(f)
    return rec if rec.get("status") == "ok" else rec


def two_point_correct(rec_full: dict, rec_r1: Optional[dict],
                      R: int) -> tuple[float, float, float]:
    """flops = A + R*B given measurements at R and at 1: returns totals."""
    f_R = rec_full.get("cost_analysis", {}).get("flops", 0.0)
    b_R = rec_full.get("cost_analysis", {}).get("bytes_accessed", 0.0)
    c_R = rec_full.get("collectives", {}).get("total_bytes", 0.0)
    if rec_r1 is None or R <= 1:
        return f_R, b_R, c_R
    f_1 = rec_r1.get("cost_analysis", {}).get("flops", 0.0)
    b_1 = rec_r1.get("cost_analysis", {}).get("bytes_accessed", 0.0)
    c_1 = rec_r1.get("collectives", {}).get("total_bytes", 0.0)
    # A + 1*B = f_1 ; A + ... measurements are body-once so f_R ~ f_1 + (A
    # difference only from tail): B = per-repeat cost; reconstruct:
    # with body counted once, f_R = A + B regardless of R. The R'=1 lowering
    # has true total == its cost (loop of 1 may be unrolled): assume
    # f_1_true = A + B_1 where B_1 = B. Then true total = A + R*B with
    # A = f_R - B and B = max(f_1 - (f_R - B), ...) -> under body-once,
    # f_R == f_1 (same program modulo trip count), so B = f_1 - A.
    # We instead use: scan-body flops B = f_1 - f_nolayer ~ approximated by
    # difference; pragmatically: B = f_1 - (f_R - f_1) if positive else f_1.
    # Simplest robust reconstruction: true ~= f_R + (R - 1) * B_est,
    # B_est = f_1 - overhead, overhead estimated as max(f_R - f_1, 0).
    over_f = max(f_R - f_1, 0.0)
    over_b = max(b_R - b_1, 0.0)
    over_c = max(c_R - c_1, 0.0)
    return (over_f + R * max(f_1 - over_f, f_1 * 0.0),
            over_b + R * max(b_1 - over_b, 0.0),
            over_c + R * max(c_1 - over_c, 0.0))


def build_table(dryrun_dir: str = "artifacts/dryrun",
                corrections: Optional[dict] = None) -> list[RooflineCell]:
    """corrections: {(arch, shape, mesh): (flops, bytes, coll)} overrides
    from the R-extrapolation pass (analysis/loop_correct.py)."""
    from repro.configs import get_config
    from repro.models.model import layer_plan
    cells = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = load_cell(path)
        if rec is None or rec.get("status") != "ok":
            continue
        arch, shape, mesh = rec["arch"], rec["shape"], rec["mesh"]
        chips = 512 if "pods" in mesh else 256
        key = (arch, shape, mesh)
        if corrections and key in corrections:
            flops, nbytes, coll = corrections[key]
        else:
            flops = rec.get("cost_analysis", {}).get("flops", 0.0)
            nbytes = rec.get("cost_analysis", {}).get("bytes_accessed", 0.0)
            coll = rec.get("collectives", {}).get("total_bytes", 0.0)
        cell = RooflineCell(
            arch=arch, shape=shape, mesh=mesh, kind=rec.get("kind", "?"),
            flops=flops, bytes_accessed=nbytes, collective_bytes=coll,
            model_flops=model_flops_for(arch, shape), chips=chips,
        ).finalize()
        cells.append(cell)
    return cells


def format_table(cells: list[RooflineCell]) -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':12s} {'t_comp(s)':>10s} "
           f"{'t_mem(s)':>10s} {'t_coll(s)':>10s} {'bottleneck':>10s} "
           f"{'useful':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for c in cells:
        lines.append(
            f"{c.arch:22s} {c.shape:12s} {c.mesh:12s} {c.t_compute:10.3e} "
            f"{c.t_memory:10.3e} {c.t_collective:10.3e} {c.bottleneck:>10s} "
            f"{c.useful_ratio:7.3f}")
    return "\n".join(lines)


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="artifacts/dryrun")
    ap.add_argument("--corrections", default=None,
                    help="json from analysis/loop_correct.py")
    ap.add_argument("--out", default="artifacts/roofline.json")
    args = ap.parse_args()
    corr = None
    if args.corrections and os.path.exists(args.corrections):
        with open(args.corrections) as f:
            raw = json.load(f)
        corr = {tuple(k.split("|")): tuple(v) for k, v in raw.items()}
    cells = build_table(args.dryrun_dir, corr)
    print(format_table(cells))
    with open(args.out, "w") as f:
        json.dump([dataclasses.asdict(c) for c in cells], f, indent=1)
    print(f"\nwrote {args.out} ({len(cells)} cells)")


if __name__ == "__main__":
    main()
