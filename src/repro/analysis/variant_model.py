"""Flop/bandwidth cost model for the four GSYEIG variants + variant router.

Predicts per-stage times for TD/TT/KE/KI from ``(n, s, band_width,
estimated Lanczos iterations, mesh shape)`` and exposes
``choose_variant(...)`` — the production feature Imachi & Hoshi
(arXiv:1504.06443) argue for: hybrid selection between the direct
(reduction) and iterative (Krylov) paths.

Model: every stage is (flops, bytes, collective_bytes, dispatches,
collectives); its time is the roofline ``max(flops / (P * peak_flops),
bytes / (P * mem_bw)) + collective_bytes / link_bw + dispatches *
t_dispatch + collectives * t_collective`` with P = number of devices. The
first three terms are exactly the split of ``analysis.roofline``; the
fourth charges each host->device program dispatch a fixed latency — the
term that closed the 19us-predicted / 14s-measured gap of the PR-4-era
race artifact: a host-CPU mesh pays O(10ms) per shard_map dispatch, so
the old 3-dispatches-per-restart Lanczos driver was dispatch-bound no
matter what the flops say. The fifth charges each cross-device collective
a fixed latency on top of its bandwidth term — the term that
distinguishes the communication-avoiding block Lanczos (2 collectives per
p-column block step) from the single-vector driver it replaced (2 per
matvec). The default ``MachineParams`` are the paper's multicore regime
(flop:byte ratio ~5, ``t_dispatch = t_collective = 0`` — a real
accelerator queue hides launch latency at this granularity) and
``MachineParams.tpu_v5e()`` reuses the roofline constants. Measured
calibration points can be folded in from a compiled executable via
``MachineParams.from_compiled`` (which reads
``roofline.cost_analysis_dict``) or from a benchmark artifact via
``MachineParams.from_artifact`` (which also fits ``t_dispatch`` and
``t_collective``).

The qualitative predictions reproduce the paper's Tables: TD1 is
memory-bound (BLAS-2), TT converts it to compute-bound BLAS-3 at the cost
of ~2x the flops, and KE/KI win exactly when the estimated iteration count
is small relative to n (MD-like separated spectra) but lose on clustered
DFT-like spectra that push Lanczos to thousands of iterations.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, Optional, Sequence

from repro.core.lanczos import default_subspace, restart_schedule
from repro.kernels.tridiag_eig.ops import SCAN_UNROLL as _TT3_UNROLL

from .roofline import HBM_BW, ICI_LINK_BW, PEAK_FLOPS_BF16, cost_analysis_dict

VARIANTS = ("TD", "TT", "KE", "KI")
#: variants with a distributed implementation (``mesh=`` dispatch targets)
DISTRIBUTED_VARIANTS = ("TT", "KE")

#: relative matmul throughput per compute dtype (fp32 doubles the fp64
#: rate on both the paper's AVX cores and the MXU; bf16 doubles again)
DTYPE_FLOP_SPEEDUP = {"float64": 1.0, "float32": 2.0, "bfloat16": 4.0}
DTYPE_BYTES = {"float64": 8, "float32": 4, "bfloat16": 2}

#: the GEMM-heavy stages each precision level demotes (mirror of what
#: ``core.gsyeig`` / ``core.batched`` actually cast; everything else —
#: Cholesky/standard form, tridiagonal eigensolve, refinement — is fp64)
DEMOTED_STAGES = ("TD1", "TD3", "TT1", "TT2", "TT4", "KE_iter", "KI_iter")

_PRECISION_DTYPE = {"fp64": "float64", "mixed": "float32",
                    "fast": "bfloat16"}


@dataclasses.dataclass(frozen=True)
class MachineParams:
    """Per-device throughput model. Defaults: the paper's multicore regime."""
    peak_flops: float = 500e9      # FLOP/s per device
    mem_bw: float = 100e9          # B/s per device
    link_bw: float = 25e9          # B/s inter-device
    dtype_bytes: int = 8
    t_dispatch: float = 0.0        # s per host->device program dispatch
    t_collective: float = 0.0      # s per cross-device collective launch
    t_loop_step: float = 0.0       # s per sequential while/fori loop step

    @classmethod
    def tpu_v5e(cls) -> "MachineParams":
        return cls(peak_flops=PEAK_FLOPS_BF16, mem_bw=HBM_BW,
                   link_bw=ICI_LINK_BW, dtype_bytes=4)

    @classmethod
    def from_compiled(cls, compiled, wall_s: float,
                      base: Optional["MachineParams"] = None) -> "MachineParams":
        """Calibrate the effective flop rate from one measured executable.

        ``compiled`` is a lowered-and-compiled jax executable;
        ``roofline.cost_analysis_dict`` normalizes its cost analysis across
        jax versions. The effective rate folds every unmodeled overhead
        (dispatch, layout, fusion quality) into ``peak_flops`` while keeping
        the modeled flop:byte ratio of ``base``.
        """
        base = base or cls()
        ca = cost_analysis_dict(compiled)
        flops = float(ca.get("flops", 0.0))
        if flops <= 0.0 or wall_s <= 0.0:
            return base
        eff = flops / wall_s
        scale = eff / base.peak_flops
        return dataclasses.replace(base, peak_flops=eff,
                                   mem_bw=base.mem_bw * scale)

    @classmethod
    def from_artifact(cls, path: str,
                      base: Optional["MachineParams"] = None,
                      n_fit_iters: int = 12) -> "MachineParams":
        """Calibrate effective throughputs from a measured benchmark artifact.

        ``path`` is a ``BENCH_variant_race.json``-schema artifact: top-level
        ``n``/``s``/``n_devices`` plus ``races[].measured[]`` records with
        per-stage wall-clock (``stage_times_s``). Every measured stage is
        matched to its modeled ``(flops, bytes, dispatches, collectives,
        loop_steps)`` from :func:`stage_costs` (for Krylov stages the
        *measured* ``n_matvec`` replaces the heuristic iteration
        estimate), then the fit recovers the effective
        ``peak_flops`` / ``mem_bw`` AND the three overhead terms:
        (1) against the base roofline (whose terms are microseconds on a
        host mesh, so residual ~= wall), take the median
        residual-per-loop-step over the serial wavefront stages as
        ``t_loop_step`` — the TT2 chase and TT4 replay are thousands of
        sequential ``fori_loop`` steps, the off-roofline wall that would
        otherwise masquerade as a collapsed "effective bandwidth" and
        zero every other term in a least-squares fit — then the median
        leftover-per-dispatch as ``t_dispatch`` and leftover-per-
        collective as ``t_collective``, each clamped nonnegative;
        (2) classify each stage by its currently-dominant roofline term
        and refit each rate as total-work / total-time of its class
        after subtracting the overhead share; iterate (the overheads are
        fit once, not re-entered, precisely so refitted rates cannot
        erode them). Unlike a single uniform rescale, this moves the
        flop:byte ratio and splits serial overhead out of throughput —
        the terms that let the calibrated router price the host-mesh
        loop/dispatch/collective round trips the raw flops hide.
        """
        base = base or cls()
        with open(path) as f:
            art = json.load(f)
        n, s = int(art["n"]), int(art["s"])
        p = max(int(art.get("n_devices", 1)), 1)
        samples = []
        for race in art.get("races", [art]):
            for rec in race.get("measured", []):
                v = rec.get("variant")
                if v not in VARIANTS:
                    continue
                kw = {"band_width": int(rec.get("band_width", 8)),
                      "p": int(rec.get("krylov_block", 1)),
                      "filter_degree": int(rec.get("filter_degree", 0))}
                if "n_matvec" in rec:
                    kw["n_iter"] = int(rec["n_matvec"])
                costs = stage_costs(v, n, s, machine=base, **kw)
                for st, t in rec.get("stage_times_s", {}).items():
                    c = costs.get(st)
                    if c is not None and t > 0.0:
                        samples.append((c.flops, c.bytes, c.collective_bytes,
                                        c.dispatches, c.collectives,
                                        c.loop_steps, float(t)))
        if not samples:
            return base
        pf, pm = base.peak_flops, base.mem_bw
        td, tc = base.t_dispatch, base.t_collective
        def _median(xs):
            xs = sorted(xs)
            return xs[len(xs) // 2] if xs else 0.0

        # (1) overhead terms, once, against the BASE roofline (whose
        # terms are microseconds here, so residual ~= wall): robust
        # medians, clamped nonnegative. Fitting overheads before
        # throughput — and not re-entering with the refitted rates —
        # keeps outlier stages from zeroing a term out via an
        # ever-shrinking "effective bandwidth". Order matters: the
        # per-loop-step overhead comes from the serial wavefront stages
        # (thousands of steps, residual ~= wall), then per-dispatch
        # latency from the remaining residuals, then per-collective.
        def _roof(F, B, Cb):
            return (max(F / (p * base.peak_flops), B / (p * base.mem_bw))
                    + (Cb / base.link_bw if p > 1 else 0.0))
        per_step = [(t - _roof(F, B, Cb)) / L
                    for F, B, Cb, D, K, L, t in samples if L > 0.0]
        ts = max(_median(per_step), 0.0) if per_step else base.t_loop_step
        per_disp = [(t - _roof(F, B, Cb) - L * ts) / D
                    for F, B, Cb, D, K, L, t in samples if D > 0.0]
        td = max(_median(per_disp), 0.0) if per_disp else td
        per_coll = [(t - _roof(F, B, Cb) - L * ts - D * td) / K
                    for F, B, Cb, D, K, L, t in samples if K > 0.0 and p > 1]
        tc = max(_median(per_coll), 0.0) if per_coll else 0.0

        for _ in range(n_fit_iters):
            # (2) throughputs on the post-overhead residual
            work = {"f": 0.0, "b": 0.0}
            wall = {"f": 0.0, "b": 0.0}
            for F, B, Cb, D, K, L, t in samples:
                t_lat = L * ts + D * td + (K * tc if p > 1 else 0.0)
                t_eff = max(t - (Cb / base.link_bw if p > 1 else 0.0)
                            - t_lat, 0.05 * t)
                cls_key = "f" if F / pf >= B / pm else "b"
                work[cls_key] += (F if cls_key == "f" else B) / p
                wall[cls_key] += t_eff
            new_pf = work["f"] / wall["f"] if wall["f"] > 0 else pf
            new_pm = work["b"] / wall["b"] if wall["b"] > 0 else pm
            if (abs(new_pf - pf) <= 1e-9 * pf
                    and abs(new_pm - pm) <= 1e-9 * pm):
                break
            pf, pm = new_pf, new_pm
        link_scale = math.sqrt((pf / base.peak_flops) * (pm / base.mem_bw))
        return dataclasses.replace(base, peak_flops=pf, mem_bw=pm,
                                   link_bw=base.link_bw * link_scale,
                                   t_dispatch=td, t_collective=tc,
                                   t_loop_step=ts)


@dataclasses.dataclass(frozen=True)
class StageCost:
    flops: float
    bytes: float
    collective_bytes: float = 0.0
    #: host->device program dispatches the stage's implementation issues
    #: (NOT divided by device count: dispatch latency is serial on the host)
    dispatches: float = 0.0
    #: cross-device collective launches (psum / all_gather) the stage's
    #: distributed implementation issues; each pays a fixed latency on top
    #: of the bandwidth term (only charged on a multi-device mesh)
    collectives: float = 0.0
    #: sequential ``fori_loop``/``while_loop`` trip count of the stage's
    #: implementation (NOT divided by device count: a replicated wavefront
    #: loop is serialized regardless of mesh size, each step paying the
    #: runtime's per-iteration overhead)
    loop_steps: float = 0.0
    #: compute dtype of the stage's dominant contractions; scales the flop
    #: rate by ``DTYPE_FLOP_SPEEDUP`` and the byte traffic by the itemsize
    #: ratio against ``machine.dtype_bytes`` (how the router prices the
    #: mixed-precision variants without re-deriving every byte count)
    compute_dtype: str = "float64"

    def seconds(self, machine: MachineParams, n_devices: int) -> float:
        p = max(int(n_devices), 1)
        speedup = DTYPE_FLOP_SPEEDUP.get(self.compute_dtype, 1.0)
        byte_scale = (DTYPE_BYTES.get(self.compute_dtype, 8)
                      / max(machine.dtype_bytes, 1))
        t_comp = self.flops / (p * machine.peak_flops * speedup)
        t_mem = self.bytes * min(byte_scale, 1.0) / (p * machine.mem_bw)
        t_coll = ((self.collective_bytes * min(byte_scale, 1.0)
                   / machine.link_bw
                   + self.collectives * machine.t_collective)
                  if p > 1 else 0.0)
        return (max(t_comp, t_mem) + t_coll
                + self.dispatches * machine.t_dispatch
                + self.loop_steps * machine.t_loop_step)


def estimate_lanczos_iters(n: int, s: int, m: Optional[int] = None,
                           clustered: bool = False, p: int = 1,
                           filter_degree: int = 0) -> int:
    """Matvec-count heuristic for thick-restart Lanczos on the paper's
    workloads: well-separated MD spectra converge in a few sweeps of the
    restart subspace; clustered DFT valence bands take ~10x longer
    (the paper's Experiment 2 hit ~4k iterations at s=448).

    A Chebyshev-filtered start block (``filter_degree > 0``) damps the
    unwanted end of a clustered spectrum before the first sweep, cutting
    the restart count to roughly a third; the probe + filter matvecs it
    spends up front are added back in. ``p`` is the Lanczos block size —
    it only enters through the p-scaled default subspace (each block step
    still does p matvecs, so the matvec count itself is p-free)."""
    if m is None:
        m = default_subspace(s, n, p)
    per_restart = max(m - s, 1)
    n_restarts = 24 if clustered else 4
    extra = 0
    if filter_degree > 0:
        if clustered:
            n_restarts = max(n_restarts // 3, 4)
        # bounds probe (a short single-vector Lanczos run) + the filter
        # itself (degree matvecs on each of the p start columns)
        extra = min(max(2 * s, 12), n - 1) + filter_degree * p
    return int(min(n * 2, m + n_restarts * per_restart + extra))


def estimate_lanczos_restarts(n_iter: int, s: int, m: int,
                              p: int = 1) -> int:
    """Thick-restart count implied by a matvec budget: the first sweep does
    m matvecs, every later restart extends by ``per_restart`` more (the
    ``core.lanczos.restart_schedule`` the drivers themselves use — for a
    block driver the schedule is p-aligned, so ``per_restart`` is already
    a whole number of p-column block steps)."""
    _, per_restart = restart_schedule(s, m, p)
    return max(1, -(-(max(n_iter - m, 0)) // per_restart) + 1)


def _mesh_devices(mesh_shape: Optional[Sequence[int]]) -> int:
    if not mesh_shape:
        return 1
    p = 1
    for d in mesh_shape:
        p *= int(d)
    return p


def _tridiag_eig_cost(n: int, s: int, b: int, bisect_iters: int = 80,
                      invit_rounds: int = 3,
                      unroll: int = _TT3_UNROLL) -> StageCost:
    """TT3/TD2: Sturm bisection + shifted inverse iteration, modeling the
    fused 'batched' path of ``core.tridiag_eig`` (the default both direct
    pipelines run) instead of the old flat ``60 n s`` placeholder.

    Flops: ``bisect_iters`` interval-halving sweeps at ~5 flops per
    (row, index lane), then per inverse-iteration round the pivoted
    tridiagonal factor+solve (~12 flops per (row, shift)) and the
    cluster-wise MGS (~4 n s per column). Bytes: each sweep streams the
    O(n) diagonals across all lanes; each round streams the O(n s)
    iterate a small number of times. The serial trip count is what the
    measured wall is made of on a host backend: each bisection sweep is
    one Sturm scan of ``ceil(n / unroll)`` steps (the unroll is the
    fused path's whole speedup — it divides this term and only this
    term), and each round pays the three length-n solve scans
    (factor / forward / backward) plus the per-column MGS loop. One
    fused program, hence one dispatch.
    """
    bisect_flops = bisect_iters * 5.0 * n * s
    invit_flops = invit_rounds * (12.0 * n * s + 4.0 * n * s * s)
    bisect_bytes = bisect_iters * (n + s) * b
    invit_bytes = invit_rounds * 6.0 * n * s * b
    loop_steps = (bisect_iters * math.ceil(n / max(unroll, 1))
                  + invit_rounds * (3.0 * n + s))
    return StageCost(bisect_flops + invit_flops,
                     bisect_bytes + invit_bytes, 0.0, 1,
                     0.0, float(loop_steps))


def _chase_loop_steps(n: int, w: int) -> float:
    """Sequential wavefront steps of the TT2 bulge chase (core.sbr).

    One pass per bandwidth ``b = w..2``; a pass's ``fori_loop`` runs
    ``T_pass = g (J - 1) + 1`` steps with ``J = n - b`` columns and sweep
    stagger ``g = 2 + ceil(5 / b)`` — mirrors ``sbr._pass_schedule``.
    """
    total = 0
    for bb in range(int(w), 1, -1):
        J = n - bb
        if J <= 0:
            continue
        g = 2 + -(-5 // bb)
        total += g * (J - 1) + 1
    return float(total)


def _replay_loop_steps(n: int, w: int) -> float:
    """Sequential sweep-replay steps of the TT4 back-transform: each pass
    replays its ``J = n - b`` recorded column sweeps one fused rotation
    batch at a time (``sbr._replay_pass``)."""
    return float(sum(n - bb for bb in range(int(w), 1, -1) if n - bb > 0))


def _refinement_cost(n: int, s: int, b: int, steps: int) -> StageCost:
    """RF: one fp32 LU of the shifted pencil (half-rate vs fp64 — modeled
    by tagging the stage float32 and halving the flop count accordingly)
    plus ``steps`` fp64 correction/Cholesky-QR/Rayleigh-Ritz sweeps over
    the guarded (n, q) slab — see ``core.refinement``. The LU dominates,
    so the whole stage is priced at the fp32 rate; the per-step GEMMs are
    ~10 n^2 q fp64 flops, folded in at 2x to keep the single-dtype tag."""
    from repro.core.refinement import default_guard
    q = s + default_guard(s, n)
    n2 = float(n) ** 2
    lu_flops = 2.0 * float(n) ** 3 / 3.0
    step_flops = steps * 10.0 * n2 * q * 2.0   # fp64 work at the fp32 tag
    step_bytes = steps * 6.0 * n2 * b
    return StageCost(lu_flops + step_flops, n2 * b + step_bytes, 0.0,
                     1 + 2.0 * steps, 0.0, 0.0, compute_dtype="float32")


def stage_costs(variant: str, n: int, s: int, band_width: int = 8,
                m: Optional[int] = None, n_iter: Optional[int] = None,
                clustered: bool = False,
                machine: Optional[MachineParams] = None,
                p: int = 1, filter_degree: int = 0,
                precision: str = "fp64",
                ) -> Dict[str, StageCost]:
    """Per-stage (flops, bytes, collective_bytes, dispatches, collectives)
    per variant.

    Flop counts are the standard LAPACK/SBR operation counts; byte counts
    encode each stage's BLAS level (BLAS-2 stages stream the trailing
    matrix once per reflector — the n^3-bytes signature of DSYTRD — while
    BLAS-3 stages touch each operand O(n/block) times, modeled as a small
    constant number of passes). Dispatch counts model the CURRENT
    implementations: every direct stage is a single (or a couple of)
    jitted program(s) — in particular TT1 is the fused one-program panel
    sweep, NOT the old O(n/w)-dispatch host loop — and the distributed
    Krylov driver runs each thick restart (segment + restart math +
    convergence flag) as ONE fused shard_map program, so it pays
    ``restarts + 2`` dispatches total (the +2: bounds-probe/filter prep
    and the final Ritz extraction), not the old 3-per-restart host loop.
    Collective counts charge the communication-avoiding block matvec its
    exact budget: 2 collectives (one psum + one all_gather) per p-column
    block step, so raising ``p`` divides the collective-latency term by p
    while leaving the matvec flops unchanged — the knob that makes
    distributed KE competitive again.
    """
    assert variant in VARIANTS, variant
    machine = machine or MachineParams()
    b = machine.dtype_bytes
    n3, n2 = float(n) ** 3, float(n) ** 2
    w = band_width
    p_blk = max(int(p), 1)
    if m is None:
        m = default_subspace(s, n, p_blk)
    if n_iter is None:
        n_iter = estimate_lanczos_iters(n, s, m, clustered=clustered,
                                        p=p_blk, filter_degree=filter_degree)
    coll_panel = n2 * b  # O(n w) panel broadcast x (n / w) panels

    costs: Dict[str, StageCost] = {}
    # GS1: blocked Cholesky — BLAS-3
    costs["GS1"] = StageCost(n3 / 3.0, 3 * n2 * b, coll_panel / 2, 1)
    # GS2: two full-matrix TRSMs (the paper's 2n^3 pick) — BLAS-3
    if variant != "KI":
        costs["GS2"] = StageCost(2 * n3, 6 * n2 * b, coll_panel, 2)

    if variant == "TD":
        # TD1: BLAS-2 tridiagonalization — 4/3 n^3 flops but the trailing
        # matrix is streamed once per reflector: ~n^3/3 elements read.
        costs["TD1"] = StageCost(4 * n3 / 3.0, (n3 / 3.0) * b, 0.0, 1)
        costs["TD2"] = _tridiag_eig_cost(n, s, b)
        costs["TD3"] = StageCost(4 * n2 * s, 3 * n2 * b, 0.0, 1)
    elif variant == "TT":
        # TT1: band reduction 4/3 n^3 + explicit Q1 accumulation 2 n^3,
        # all GEMMs (BLAS-3: the trailing matrix streams once per panel,
        # n/w passes — the 1/w factor is what makes TT compute-bound).
        # The whole sweep is ONE fused program + the band repack: 2
        # dispatches, NOT n/w (see core.sbr.reduce_to_band /
        # dist.sharded_la.band_sweep_program). Each panel iteration of the
        # distributed sweep issues exactly 3 collectives — all_gather of
        # the panel (doubling as its broadcast), psum of the (w, w)
        # coupling, all_gather of the Z panel — a count the static auditor
        # cross-checks against the lowered program (the old 2/panel here
        # was model drift, caught by exactly that check).
        costs["TT1"] = StageCost(4 * n3 / 3.0 + 2 * n3,
                                 (n3 / max(w, 1)) * b, coll_panel, 2,
                                 3.0 * n / max(w, 1))
        # TT2: wavefront bulge chasing over packed (w+1, n) band storage —
        # O(n^2 w) flops touching only the O(n w) band. The rotation stream
        # is recorded, NOT accumulated into an (n, n) Q2 (that would cost
        # 3 n^3 sum_{2..w} 1/b extra flops — the unmodeled cost behind the
        # old 19us-predicted / 16s-measured gap); the stream replays onto
        # the thin slab in TT4.
        h_w = sum(1.0 / bb for bb in range(2, max(w, 2) + 1))
        # The chase is ONE dispatched program, but inside it the wavefront
        # schedule is a genuinely sequential fori_loop — ~g n steps per
        # bandwidth pass — and each step pays the runtime's per-iteration
        # overhead. On a host mesh that serial term (~100us x thousands of
        # steps), not the O(n w) byte traffic, is what the measured TT2
        # wall is made of; modeling it as bytes is the fit-distorting
        # outlier behind the old calibration failures.
        costs["TT2"] = StageCost(6 * n2 * w, 6 * n2 * w * b / 8, 0.0, 1,
                                 0.0, _chase_loop_steps(n, w))
        costs["TT3"] = _tridiag_eig_cost(n, s, b)
        # TT4: replay the ~n^2/2 sum 1/b recorded rotations over the (n, s)
        # Ritz slab (6s flops each), then one GEMM against the explicit Q1.
        # The replay shares TT2's serial character: one fused rotation
        # batch per recorded column sweep, ~(w-1) n sequential steps.
        costs["TT4"] = StageCost(
            2 * n2 * s + 2 * n * s * s + 3 * n2 * s * h_w,
            3 * n2 * b + (n2 / 2) * h_w * b, n * s * b, 2,
            0.0, _replay_loop_steps(n, w))
    else:
        # Krylov iteration: each matvec streams the n^2 operand (memory
        # bound); re-orthogonalization adds 8 n m flops per step. KI's
        # implicit operator is two triangular solves + one SYMV. The
        # distributed driver fuses each thick restart (m-step block
        # segment + restart math + convergence flag) into ONE shard_map
        # program — ``restarts + 2`` dispatches total, the +2 being the
        # filter/seed prep and final Ritz-vector extraction — and the
        # communication-avoiding block matvec pays exactly 2 collectives
        # (psum + all_gather) per p-column block step. At O(ms) per
        # dispatch/collective on a host mesh these latency terms, not the
        # flops, decide the race; p divides the collective term.
        mv_flops = (2 * n2 if variant == "KE" else 4 * n2) + 8.0 * n * m
        mv_bytes = (n2 if variant == "KE" else 2 * n2) * b + 2.0 * n * m * b
        n_restart = estimate_lanczos_restarts(n_iter, s, m, p_blk)
        n_block_steps = -(-int(n_iter) // p_blk)
        costs[f"{variant}_iter"] = StageCost(
            n_iter * mv_flops, n_iter * mv_bytes, n_iter * n * b,
            n_restart + 2, 2.0 * n_block_steps)

    # BT1: X = U^{-1} Y, one TRSM on an (n, s) slab
    costs["BT1"] = StageCost(n2 * s, 2 * n2 * b, n * s * b, 1)

    cdtype = _PRECISION_DTYPE.get(precision)
    if cdtype is None:
        raise ValueError(f"precision must be one of "
                         f"{tuple(_PRECISION_DTYPE)}, got {precision!r}")
    if cdtype != "float64":
        # demote exactly the stages the solvers demote, and append the
        # fp64 refinement stage that buys the accuracy back
        for st in DEMOTED_STAGES:
            if st in costs:
                costs[st] = dataclasses.replace(costs[st],
                                                compute_dtype=cdtype)
        from repro.core.precision import default_refine_steps
        costs["RF"] = _refinement_cost(n, s, b,
                                       default_refine_steps(precision))
    return costs


def predict_stage_times(variant: str, n: int, s: int,
                        machine: Optional[MachineParams] = None,
                        mesh_shape: Optional[Sequence[int]] = None,
                        **kw) -> Dict[str, float]:
    """Predicted seconds per stage (plus 'Tot.') for one variant."""
    machine = machine or MachineParams()
    p = _mesh_devices(mesh_shape)
    costs = stage_costs(variant, n, s, machine=machine, **kw)
    times = {k: c.seconds(machine, p) for k, c in costs.items()}
    times["Tot."] = sum(times.values())
    return times


@dataclasses.dataclass(frozen=True)
class VariantChoice:
    variant: str
    predicted_s: float
    table: Dict[str, float]          # variant -> predicted total seconds
    n_devices: int

    def as_json_dict(self) -> dict:
        return {"variant": self.variant,
                "predicted_s": float(self.predicted_s),
                "table": {k: float(v) for k, v in self.table.items()},
                "n_devices": int(self.n_devices)}


def choose_variant(n: int, s: int, band_width: int = 8,
                   m: Optional[int] = None, n_iter: Optional[int] = None,
                   clustered: bool = False,
                   machine: Optional[MachineParams] = None,
                   mesh_shape: Optional[Sequence[int]] = None,
                   allow: Optional[Sequence[str]] = None,
                   krylov_block: int = 1,
                   filter_degree: int = 0,
                   precision: str = "fp64") -> VariantChoice:
    """Pick the fastest variant under the cost model.

    With a multi-device ``mesh_shape`` the candidate set narrows to the
    variants that actually have a distributed implementation (TT, KE);
    ties break toward the earlier entry of ``VARIANTS`` for determinism.
    ``krylov_block`` / ``filter_degree`` describe the Krylov pipelines the
    KE/KI candidates would actually run (block size p divides the
    collective-latency term; a Chebyshev filter cuts the clustered-spectrum
    iteration estimate) — they do not affect the direct variants.
    ``precision`` prices the mixed pipelines: the demoted stages run at
    the reduced-dtype rate and the fp64 refinement stage is added back,
    so the router can decide when demotion actually pays per variant.
    """
    p = _mesh_devices(mesh_shape)
    if allow is None:
        allow = DISTRIBUTED_VARIANTS if p > 1 else VARIANTS
    table: Dict[str, float] = {}
    for v in VARIANTS:
        if v not in allow:
            continue
        kkw = ({"p": krylov_block, "filter_degree": filter_degree}
               if v in ("KE", "KI") else {})
        table[v] = predict_stage_times(
            v, n, s, machine=machine, mesh_shape=mesh_shape,
            band_width=band_width, m=m, n_iter=n_iter,
            clustered=clustered, precision=precision, **kkw)["Tot."]
    best = min(table, key=lambda v: (table[v], VARIANTS.index(v)))
    return VariantChoice(variant=best, predicted_s=table[best], table=table,
                         n_devices=p)
