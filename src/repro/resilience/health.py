"""Stage-boundary health sentinels.

Two flavors, chosen per stage so the sentinels cost **zero extra
dispatches** (the static auditor pins this, see
``analysis/static_audit/contracts``):

* *Fused* — ``array_finite`` / ``chol_health`` are traceable reductions
  folded into an already-jitted stage program (the GS1/GS2 module jits,
  the batched bucket pipelines, the thick-restart segment, the
  distributed KE restart program).  The scalar verdict rides out with
  the stage outputs the host was fetching anyway.
* *Host* — composite stages (the TT1 sweep, the TT2 chase, the TD
  reflector loop) already hand small arrays back to the host between
  their fused programs; ``host_finite`` runs ``np.isfinite`` on those,
  which is free of device dispatches by construction.

The per-stage booleans are folded into a ``HealthVerdict`` carried in
``info["health"]`` — a plain dataclass whose ``as_json_dict`` output
survives ``json.dumps`` (the ``test_info_json`` contract).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

__all__ = ["HealthVerdict", "array_finite", "chol_health", "host_finite",
           "verdict_from_stages"]


@dataclass
class HealthVerdict:
    """Per-stage finite/converged verdict for one solve.

    ``stages`` maps stage name (GS1, GS2, TT1, ..., OUT) to a bool;
    ``first_unhealthy_stage`` is the earliest failing stage in pipeline
    order, or None.  JSON-clean via ``as_json_dict``.
    """

    healthy: bool = True
    stages: Dict[str, bool] = field(default_factory=dict)
    first_unhealthy_stage: Optional[str] = None
    detail: str = ""

    def record(self, stage: str, ok) -> bool:
        ok = bool(ok)
        self.stages[stage] = ok
        if not ok and self.healthy:
            self.healthy = False
            self.first_unhealthy_stage = stage
        return ok

    def as_json_dict(self) -> dict:
        return {
            "healthy": bool(self.healthy),
            "stages": {k: bool(v) for k, v in self.stages.items()},
            "first_unhealthy_stage": self.first_unhealthy_stage,
            "detail": self.detail,
        }


def array_finite(*arrays):
    """Traceable all-finite reduction over one or more arrays.

    Fuses into whatever program it is traced in; returns a bool scalar.
    """
    ok = jnp.asarray(True)
    for a in arrays:
        ok = ok & jnp.isfinite(a).all()
    return ok


def chol_health(U):
    """Fused GS1 sentinel: finite factor with a positive diagonal.

    ``jnp.linalg.cholesky`` reports breakdown as NaN rows, so finiteness
    alone catches a non-SPD B; ``min_diag`` additionally exposes the
    near-breakdown margin for diagnosis.
    """
    d = jnp.diagonal(U)
    finite = jnp.isfinite(U).all()
    return finite & (d > 0).all(), jnp.min(jnp.where(jnp.isfinite(d), d, 0.0))


def host_finite(*arrays) -> bool:
    """Host-side all-finite check on already-fetched (small) outputs."""
    return all(bool(np.isfinite(np.asarray(a)).all()) for a in arrays)


def verdict_from_stages(stages: Dict[str, bool], detail: str = "",
                        ) -> HealthVerdict:
    v = HealthVerdict(detail=detail)
    for name, ok in stages.items():
        v.record(name, ok)
    return v
