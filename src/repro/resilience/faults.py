"""Seeded fault injection for the chaos test suite.

Faults are armed with the ``inject`` context manager and consulted by
the solver seams (``core.gsyeig``, ``dist.eigensolver``) — the
production code pays one dict lookup per stage when no fault is active.
Everything is deterministic: NaN positions come from a seeded
``np.random.Generator``, nonconvergence is forced by clamping the
tolerance, preemption raises at a fixed restart index.

This module deliberately imports nothing from ``repro.core`` /
``repro.dist`` (they import *it*), so it can also synthesize the
adversarial pencils used by the regression tests.
"""
from __future__ import annotations

import contextlib
from typing import Dict, Iterator, List, Tuple

import numpy as np

__all__ = ["inject", "active", "poison_stage", "force_nonconverge",
           "NanPoison", "ForceNonconverge", "SimulatedPreemption",
           "nonspd_pencil", "near_breakdown_pencil", "slow_then_lost_trace"]

# the armed faults, keyed by kind ("nan" | "nonconverge")
_ACTIVE: Dict[str, object] = {}


class NanPoison:
    """Poison ``frac`` of the named stage's input with NaN, seeded.

    ``once=True`` disarms after the first hit — the *transient* fault
    the recover ladder's retry rung is for; ``once=False`` models a
    persistent corruption that must end in a diagnosed ``SolverError``.
    """

    kind = "nan"

    def __init__(self, stage: str, frac: float = 0.01, seed: int = 0,
                 once: bool = False):
        self.stage = stage
        self.frac = frac
        self.seed = seed
        self.once = once
        self.hits = 0

    def apply(self, stage: str, x):
        if stage != self.stage or (self.once and self.hits > 0):
            return x
        self.hits += 1
        arr = np.array(np.asarray(x), dtype=np.float64, copy=True)
        rng = np.random.default_rng(self.seed)
        k = max(1, int(self.frac * arr.size))
        idx = rng.choice(arr.size, size=k, replace=False)
        arr.reshape(-1)[idx] = np.nan
        return arr


class ForceNonconverge:
    """Make the Krylov path fail its restart budget, fast.

    Clamps the residual tolerance to an unreachable value and caps
    ``max_restarts`` so the failure is cheap to reach in tests.  Direct
    (TD/TT) solves are untouched, so the ladder's TT fallback succeeds
    while the fault is still armed.
    """

    kind = "nonconverge"

    def __init__(self, max_restarts_cap: int = 3):
        self.max_restarts_cap = max_restarts_cap
        self.hits = 0

    def apply_knobs(self, tol: float, max_restarts: int
                    ) -> Tuple[float, int]:
        self.hits += 1
        return 1e-300, min(max_restarts, self.max_restarts_cap)


class SimulatedPreemption(RuntimeError):
    """Raised by the distributed driver's preemption drill hook."""

    def __init__(self, at_restart: int):
        super().__init__(f"simulated host preemption at restart "
                         f"{at_restart}")
        self.at_restart = at_restart


@contextlib.contextmanager
def inject(*faults) -> Iterator[None]:
    """Arm faults for the duration of the block (not thread-safe)."""
    prev = dict(_ACTIVE)
    try:
        for f in faults:
            _ACTIVE[f.kind] = f
        yield
    finally:
        _ACTIVE.clear()
        _ACTIVE.update(prev)


def active(kind: str):
    return _ACTIVE.get(kind)


def poison_stage(stage: str, x):
    """Solver seam: pass a stage input through the armed NaN fault."""
    f = _ACTIVE.get("nan")
    return x if f is None else f.apply(stage, x)


def force_nonconverge(tol: float, max_restarts: int) -> Tuple[float, int]:
    """Solver seam: let the armed nonconvergence fault clamp the knobs."""
    f = _ACTIVE.get("nonconverge")
    return (tol, max_restarts) if f is None else f.apply_knobs(
        tol, max_restarts)


def nonspd_pencil(n: int, seed: int = 0, min_eig: float = -0.1):
    """A pencil whose B is symmetric but indefinite (min eig ~ min_eig).

    Far enough from SPD that the diagonal-shift rungs cannot rescue it —
    the regression tests want the diagnosed ``SolverError`` path.
    """
    rng = np.random.default_rng(seed)
    M = rng.standard_normal((n, n))
    A = 0.5 * (M + M.T)
    Q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    evals = np.linspace(1.0, 2.0, n)
    evals[0] = min_eig
    B = (Q * evals) @ Q.T
    B = 0.5 * (B + B.T)
    return A, B


def near_breakdown_pencil(n: int, cond: float = 1e10, seed: int = 1):
    """SPD pencil with cond(B) ~ ``cond`` — the shift-rung's territory."""
    rng = np.random.default_rng(seed)
    M = rng.standard_normal((n, n))
    A = 0.5 * (M + M.T)
    Q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    evals = np.geomspace(1.0 / cond, 1.0, n)
    B = (Q * evals) @ Q.T
    B = 0.5 * (B + B.T)
    return A, B


def slow_then_lost_trace(n_hosts: int = 4, slow_host: int = 2,
                         n_steps: int = 16, slowdown: float = 3.0
                         ) -> List[dict]:
    """Per-step host timing trace: one host degrades, then disappears.

    Each entry: ``{"times": [s per host], "lost": [host ids]}``; the
    slow host takes ``slowdown`` x the base step time for the first
    half, then drops out.  Feeds the StragglerMonitor + plan_remesh
    compose test.
    """
    base = 0.1
    trace: List[dict] = []
    for step in range(n_steps):
        times = [base] * n_hosts
        lost: List[int] = []
        if step < n_steps // 2:
            times[slow_host] = base * slowdown
        else:
            lost = [slow_host]
            times[slow_host] = float("nan")
        trace.append({"times": times, "lost": lost})
    return trace
