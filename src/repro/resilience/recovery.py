"""The degradation ladder: declarative, bounded, observable recovery.

Policies (applied by ``core.gsyeig.solve`` and ``serve.eigen_engine``):

* Cholesky breakdown (GS1 NaN / nonpositive pivot): retry with a
  relative diagonal shift ``tau * max|diag B|`` for each rung in
  ``cholesky_shift_taus()``; refinement still targets the *original*
  pencil, so a successful rung reports the shift it used instead of
  silently changing the problem.  Exhausted -> diagnosed
  ``SolverError``.
* KE/KI unconverged inside the restart budget: under
  ``on_failure="recover"``, escalate (restarts x4, Chebyshev filter
  degree up), then fall back to the direct TT variant.
* mixed/fast refinement stalling above tolerance: rerun at fp64.
* Non-finite stage or output: one transient retry (fresh key) under
  ``recover``, else raise ``SolverError`` with the failing stage.

Every rung taken is appended to ``info["recovery"]`` as a plain dict
(action, stage, params, outcome) so retries are observable and
deterministic; ``on_failure="ignore"`` restores the old silent behavior
but still records the verdict.
"""
from __future__ import annotations

from typing import Tuple

__all__ = ["SolverError", "ON_FAILURE", "validate_on_failure",
           "cholesky_shift_taus", "rung"]

ON_FAILURE = ("recover", "warn", "ignore")

# relative diagonal shifts tried on GS1 breakdown, weakest first —
# 1e-14 rescues roundoff-level indefiniteness without moving converged
# eigenvalues past the 1e-12 Table-3 tolerances; 1e-6 is the last rung
# before we declare the pencil non-SPD
_SHIFT_TAUS = (1e-14, 1e-10, 1e-6)


class SolverError(RuntimeError):
    """A diagnosed solver failure.

    ``diagnosis`` is a JSON-clean dict: ``stage`` (pipeline stage that
    failed), ``reason`` (``cholesky_breakdown`` | ``nonfinite_stage`` |
    ``nonfinite_output`` | ``retries_exhausted``), ``hint`` (what to
    try), and the ``recovery`` trail of rungs already attempted.
    """

    def __init__(self, message: str, *, stage: str, reason: str,
                 hint: str = "", recovery=None, health=None):
        super().__init__(message)
        self.diagnosis = {
            "stage": stage,
            "reason": reason,
            "hint": hint,
            "recovery": list(recovery or []),
        }
        if health is not None:
            self.diagnosis["health"] = health


def validate_on_failure(on_failure: str) -> str:
    if on_failure not in ON_FAILURE:
        raise ValueError(
            f"on_failure must be one of {ON_FAILURE}, got {on_failure!r}")
    return on_failure


def cholesky_shift_taus() -> Tuple[float, ...]:
    return _SHIFT_TAUS


def rung(action: str, stage: str, outcome: str, **params) -> dict:
    """One recovery-ladder entry for ``info['recovery']``."""
    entry = {"action": action, "stage": stage, "outcome": outcome}
    if params:
        entry["params"] = {k: (float(v) if isinstance(v, float) else v)
                           for k, v in params.items()}
    return entry
