"""Failure containment and recovery for the eigensolver stack.

Three layers, threaded through every solver path:

``health``    stage-boundary sentinels: ``isfinite`` reductions fused
              into the existing one-program pipelines (zero extra
              dispatches) plus host-side checks on composite-stage
              outputs, summarized as a JSON-clean ``HealthVerdict``
              carried in ``info["health"]``.
``recovery``  the declarative degradation ladder (Cholesky breakdown ->
              diagonal-shift retry -> diagnosed ``SolverError``; KE/KI
              unconverged -> escalate restarts/filter -> TT fallback;
              refinement stall on mixed/fast -> fp64 rerun), every rung
              recorded in ``info["recovery"]``.
``faults``    the seeded fault-injection harness behind the chaos test
              suite (NaN stage poisoning, non-SPD pencils, forced
              nonconvergence, simulated preemption / host loss).
"""
from repro.resilience.health import (HealthVerdict, array_finite,
                                     host_finite, verdict_from_stages)
from repro.resilience.recovery import (ON_FAILURE, SolverError,
                                       cholesky_shift_taus,
                                       validate_on_failure)

__all__ = [
    "HealthVerdict", "array_finite", "host_finite", "verdict_from_stages",
    "ON_FAILURE", "SolverError", "cholesky_shift_taus",
    "validate_on_failure",
]
